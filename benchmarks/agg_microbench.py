"""Aggregation-rule microbenchmark (the paper's complexity table,
Section IV): wall-time per aggregation call vs (K, d), for every rule,
plus the Pallas kernel paths (interpret mode on CPU — correctness-grade
timing, the TPU number comes from the roofline).

The derived column reports bytes touched per call / wall time = effective
CPU bandwidth, a sanity proxy for the O(dK log K) complexity claim.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_lib
from repro.core import wfagg as wf


def _timeit(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_rules(K: int, d: int) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    updates = jax.random.normal(key, (K, d), jnp.float32)
    local = updates[0]
    rows = []

    cases = {
        "mean": jax.jit(lambda u: agg_lib.mean_agg(u)[0]),
        "median": jax.jit(lambda u: agg_lib.median_agg(u)[0]),
        "trimmed_mean": jax.jit(lambda u: agg_lib.trimmed_mean_agg(u)[0]),
        "krum": jax.jit(lambda u: agg_lib.krum_agg(u)[0]),
        "multi_krum": jax.jit(lambda u: agg_lib.multi_krum_agg(u)[0]),
        "clustering": jax.jit(lambda u: agg_lib.clustering_agg(u)[0]),
        "wfagg_d": jax.jit(lambda u: wf.wfagg_d_agg(u)[0]),
        "wfagg_c": jax.jit(lambda u: wf.wfagg_c_agg(u)[0]),
        "wfagg_e": jax.jit(lambda u: wf.wfagg_e_agg(local, u)),
    }
    for name, fn in cases.items():
        us = _timeit(fn, updates) * 1e6
        rows.append({
            "rule": name, "K": K, "d": d, "us_per_call": round(us, 1),
            "GBps": round(4e-3 * K * d / max(us, 1e-9), 2),
        })

    # full WFAgg (3 filters + weighting + smoothing)
    wcfg = wf.WFAggConfig()
    tstate = wf.init_temporal_state(K, d, wcfg.window)
    fn = jax.jit(lambda loc, u, ts: wf.wfagg(loc, u, ts, wcfg)[0])
    us = _timeit(fn, local, updates, tstate) * 1e6
    rows.append({"rule": "wfagg", "K": K, "d": d, "us_per_call": round(us, 1),
                 "GBps": round(4e-3 * K * d / max(us, 1e-9), 2)})
    return rows


def bench_kernels(K: int, d: int) -> List[Dict]:
    from repro.kernels.pairwise_dist.ops import pairwise_sq_dists
    from repro.kernels.robust_stats.ops import robust_stats
    from repro.kernels.weighted_agg.ops import weighted_agg

    key = jax.random.PRNGKey(1)
    updates = jax.random.normal(key, (K, d), jnp.float32)
    local = updates[0]
    weights = jnp.ones((K,), jnp.float32)
    rows = []
    for name, fn in (
        ("robust_stats[pallas-interp]", lambda: robust_stats(updates)),
        ("robust_stats[jnp-ref]", lambda: robust_stats(updates, use_kernel=False)),
        ("pairwise[pallas-interp]", lambda: pairwise_sq_dists(updates)),
        ("pairwise[jnp-ref]", lambda: pairwise_sq_dists(updates, use_kernel=False)),
        ("weighted_agg[pallas-interp]", lambda: weighted_agg(local, updates, weights)),
        ("weighted_agg[jnp-ref]", lambda: weighted_agg(local, updates, weights, use_kernel=False)),
    ):
        us = _timeit(fn, reps=3) * 1e6
        rows.append({"rule": name, "K": K, "d": d, "us_per_call": round(us, 1),
                     "GBps": round(4e-3 * K * d / max(us, 1e-9), 2)})
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8x100000,16x100000,16x1000000")
    ap.add_argument("--kernels", action="store_true", help="include Pallas paths")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    rows: List[Dict] = []
    for tok in args.sizes.split(","):
        K, d = (int(x) for x in tok.split("x"))
        rows += bench_rules(K, d)
        if args.kernels:
            rows += bench_kernels(K, min(d, 200_000))
    for r in rows:
        print(f"{r['rule']:28s} K={r['K']:3d} d={r['d']:8d} "
              f"{r['us_per_call']:10.1f} us  {r['GBps']:7.2f} GB/s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
