"""Aggregation-rule microbenchmark (the paper's complexity table,
Section IV): wall-time per aggregation call vs (K, d), for every rule,
plus the Pallas kernel paths (interpret mode on CPU — correctness-grade
timing, the TPU number comes from the roofline).

Each row carries the execution ``backend`` and the analytic ``passes``
column — the number of (K, d)-sized HBM passes per aggregation (see
src/repro/kernels/README.md for the accounting).  The full-WFAgg rule is
measured under BOTH backends so the fused-vs-reference pass-count win is
visible in every run, and every invocation appends its rows to the
``BENCH_agg.json`` trajectory so later PRs can regress against it.

Timing methodology (shared with ``repro.obs.profile``): the FIRST call
— trace + compile + one execution — is reported as its own
``compile_us`` column; ``us_per_call`` (and the GBps derived from it) is
the MEDIAN of ``reps`` further calls, each synchronized with its own
``block_until_ready``.  The old mean-with-one-final-block loop let the
async dispatch queue smear compile time and cross-call overlap into the
throughput number.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_lib
from repro.core import wfagg as wf

HERE = os.path.dirname(__file__)
TRAJECTORY = os.path.join(HERE, "BENCH_agg.json")


def _timeit(fn, *args, reps: int = 5) -> Tuple[float, float]:
    """(first-call seconds, median steady-state seconds).  Every call is
    individually synchronized with ``block_until_ready`` so no sample
    absorbs its neighbors' device time."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_s = time.perf_counter() - t0
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return compile_s, statistics.median(samples)


def _row(rule: str, K: int, d: int, us: float, backend: str,
         passes: int | None = None, read_factor: float = 1.0,
         compile_us: float | None = None) -> Dict:
    """``read_factor`` scales the bytes-touched estimate for calls that
    stream more than one (K, d) tensor (batched launch, +prev input).
    ``us`` must be the steady-state (post-compile) median; the first
    call goes in ``compile_us``."""
    r = {
        "rule": rule, "K": K, "d": d, "us_per_call": round(us, 1),
        "backend": backend,
        "GBps": round(read_factor * 4e-3 * K * d / max(us, 1e-9), 2),
    }
    if compile_us is not None:
        r["compile_us"] = round(compile_us, 1)
    if passes is not None:
        r["passes"] = passes
    return r


def bench_rules(K: int, d: int) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    updates = jax.random.normal(key, (K, d), jnp.float32)
    local = updates[0]
    rows = []

    cases = {
        "mean": jax.jit(lambda u: agg_lib.mean_agg(u)[0]),
        "median": jax.jit(lambda u: agg_lib.median_agg(u)[0]),
        "trimmed_mean": jax.jit(lambda u: agg_lib.trimmed_mean_agg(u)[0]),
        "krum": jax.jit(lambda u: agg_lib.krum_agg(u)[0]),
        "multi_krum": jax.jit(lambda u: agg_lib.multi_krum_agg(u)[0]),
        "clustering": jax.jit(lambda u: agg_lib.clustering_agg(u)[0]),
        "wfagg_d": jax.jit(lambda u: wf.wfagg_d_agg(u)[0]),
        "wfagg_c": jax.jit(lambda u: wf.wfagg_c_agg(u)[0]),
        "wfagg_e": jax.jit(lambda u: wf.wfagg_e_agg(local, u)),
    }
    for name, fn in cases.items():
        comp_s, med_s = _timeit(fn, updates)
        rows.append(_row(name, K, d, med_s * 1e6, "reference",
                         compile_us=comp_s * 1e6))

    # full WFAgg (3 filters + weighting + smoothing), both backends
    for backend in ("reference", "fused"):
        wcfg = wf.WFAggConfig(backend=backend)
        tstate = wf.init_temporal_state(K, d, wcfg.window)
        fn = jax.jit(lambda loc, u, ts, w=wcfg: wf.wfagg(loc, u, ts, w)[0])
        comp_s, med_s = _timeit(fn, local, updates, tstate)
        rows.append(_row(f"wfagg[{backend}]", K, d, med_s * 1e6, backend,
                         passes=wf.memory_passes(wcfg),
                         compile_us=comp_s * 1e6))

    # batched gossip round over an (N, d) model matrix: the gathered
    # launch materializes the (N, Kb, d) tensor first, the indexed one
    # DMAs neighbor blocks straight from the matrix (one pass less,
    # K-fold less HBM) — the `passes` column counts the gather
    N = 4
    models = jax.random.normal(jax.random.PRNGKey(5), (N, d), jnp.float32)
    Kb = min(K, N - 1)
    nidx = jnp.asarray(
        [[(n + o) % N for o in range(1, Kb + 1)] for n in range(N)], jnp.int32)
    wcfg = wf.WFAggConfig(backend="fused", use_temporal=False)
    for name, indexed, fn in (
        ("wfagg_batch[gathered]", False,
         jax.jit(lambda m: wf.wfagg_batch(m, m[nidx], None, wcfg)[0])),
        ("wfagg_batch[indexed]", True,
         jax.jit(lambda m: wf.wfagg_batch(m, m, None, wcfg,
                                          neighbor_idx=nidx)[0])),
    ):
        comp_s, med_s = _timeit(fn, models)
        rows.append(_row(name, Kb, d, med_s * 1e6, "fused",
                         passes=wf.memory_passes(wcfg, include_gather=True,
                                                 indexed=indexed),
                         read_factor=float(N), compile_us=comp_s * 1e6))
    return rows


def bench_dynamic(K: int, d: int, rounds: int = 4) -> List[Dict]:
    """Schedule-swap cost: a jitted lax.scan of ``rounds`` gather-free
    WFAgg gossip aggregations, once with a STATIC schedule (the same
    (N, K) neighbor table every round) and once with a DYNAMIC one (a
    different table + valid mask per round).  The delta is what a
    round-varying topology actually costs through the indexed path —
    the kernels take the table as a traced input, so it should be the
    price of an (N, K) index upload, not a recompile or a regather.
    us_per_call is normalized PER ROUND."""
    import numpy as np

    N = 8
    models = jax.random.normal(jax.random.PRNGKey(7), (N, d), jnp.float32)
    Kb = min(K, N - 1)
    wcfg = wf.WFAggConfig(backend="fused", use_temporal=False)
    rng = np.random.default_rng(0)
    idx = np.zeros((rounds, N, Kb), np.int32)
    val = np.zeros((rounds, N, Kb), bool)
    for r in range(rounds):
        for n in range(N):
            v = int(rng.integers(max(1, Kb - 2), Kb + 1))
            nb = rng.choice([i for i in range(N) if i != n], size=v,
                            replace=False)
            idx[r, n, :v] = nb
            idx[r, n, v:] = n
            val[r, n, :v] = True
    dyn_sched = (jnp.asarray(idx), jnp.asarray(val))
    static_sched = (jnp.broadcast_to(dyn_sched[0][0], dyn_sched[0].shape),
                    jnp.broadcast_to(dyn_sched[1][0], dyn_sched[1].shape))

    @jax.jit
    def run(m, sched_idx, sched_val):
        def body(m, xs):
            i, v = xs
            out, _, _ = wf.wfagg_batch(m, m, None, wcfg,
                                       neighbor_idx=i, valid=v)
            return out, ()
        m, _ = jax.lax.scan(body, m, (sched_idx, sched_val))
        return m

    rows = []
    for name, sched in (("wfagg_round[sched-static]", static_sched),
                        ("wfagg_round[sched-dynamic]", dyn_sched)):
        comp_s, med_s = _timeit(run, models, *sched, reps=3)
        rows.append(_row(name, Kb, d, med_s * 1e6 / rounds, "fused",
                         passes=wf.memory_passes(wcfg, include_gather=True,
                                                 indexed=True),
                         read_factor=float(N),
                         compile_us=comp_s * 1e6))
    return rows


def bench_one_launch(K: int, d: int, rounds: int = 4) -> List[Dict]:
    """Single-launch vs two-launch gossip round: the same jitted scan of
    gather-free WFAgg aggregations, once through the one-launch round
    kernel (backend="fused": stats + in-kernel weights + combine in one
    pallas_call) and once through the two-launch fallback
    (backend="fused_two_launch").  Outputs are parity-exact (fp32); the
    delta is the second kernel launch + the host scoring round-trip.
    us_per_call is normalized PER ROUND.

    Interpret-mode caveat: the one-launch kernel has more per-step
    inputs/outputs, so at smoke sizes (d ~ 4k) the interpreter's fixed
    per-step cost dominates and the one-launch row can come out SLOWER;
    its d-proportional cost is the lower one (fewer d-sized buffer
    carries), so at the baseline sizes (d >= ~100k, where the candidate
    traffic the kernel exists for actually dominates) one-launch wins —
    that is the comparison BENCH_agg.json records."""
    N = 8
    models = jax.random.normal(jax.random.PRNGKey(11), (N, d), jnp.float32)
    Kb = min(K, N - 1)
    nidx = jnp.asarray(
        [[(n + o) % N for o in range(1, Kb + 1)] for n in range(N)], jnp.int32)

    rows = []
    for name, backend in (("wfagg_round[one-launch]", "fused"),
                          ("wfagg_round[two-launch]", "fused_two_launch")):
        wcfg = wf.WFAggConfig(backend=backend, use_temporal=False)

        @jax.jit
        def run(m, w=wcfg):
            def body(m, _):
                out, _, _ = wf.wfagg_batch(m, m, None, w, neighbor_idx=nidx)
                return out, ()
            m, _ = jax.lax.scan(body, m, jnp.arange(rounds))
            return m

        # interpret-mode timings are noisy right after the heavier bench
        # sections (allocator churn): the median over per-call-blocked
        # reps keeps the one-vs-two-launch comparison honest
        comp_s, med_s = _timeit(run, models, reps=5)
        rows.append(_row(name, Kb, d, med_s * 1e6 / rounds, backend,
                         passes=wf.memory_passes(wcfg, include_gather=True,
                                                 indexed=True),
                         read_factor=float(N),
                         compile_us=comp_s * 1e6))
    return rows


def _bench_sharded_worker(shards: int, K: int, d: int) -> Dict:
    """One sharded-round timing row, run INSIDE a subprocess whose
    XLA_FLAGS already forced ``shards`` virtual host devices (the flag
    must be set before jax imports, hence the subprocess)."""
    from repro.distributed import spmd

    N = 8
    Kb = min(K, N - 1)
    d_pad = spmd.shard_padded_d(d, max(shards, 1))
    wcfg = wf.WFAggConfig(backend="fused_two_launch", use_temporal=False)
    nidx = jnp.asarray(
        [[(n + o) % N for o in range(1, Kb + 1)] for n in range(N)], jnp.int32)
    models = jax.random.normal(jax.random.PRNGKey(13), (N, d_pad), jnp.float32)
    if shards > 1:
        mesh = spmd.aggregation_mesh(shards)
        fn = jax.jit(lambda m: spmd.wfagg_batch_sharded(
            m, m, None, wcfg, nidx, mesh=mesh)[0])
    else:
        fn = jax.jit(lambda m: wf.wfagg_batch(
            m, m, None, wcfg, neighbor_idx=nidx)[0])
    comp_s, med_s = _timeit(fn, models, reps=3)
    return _row(f"wfagg_round[sharded-{shards}dev]", Kb, d_pad,
                med_s * 1e6, "fused_two_launch",
                passes=wf.memory_passes(wcfg, include_gather=True,
                                        indexed=True),
                read_factor=float(N), compile_us=comp_s * 1e6)


def bench_sharded(K: int, d: int, shards: int = 8) -> List[Dict]:
    """The d-sharded gossip round (distributed/spmd.py) vs the same
    two-launch round single-process, each in its own subprocess so
    ``--xla_force_host_platform_device_count`` lands before jax loads.
    Interpret-mode caveat applies: on virtual CPU devices the sharded
    row measures the shard_map + psum orchestration overhead, not a
    speedup — the wire-traffic win is what ``python -m repro.analysis``
    verifies statically."""
    import subprocess
    import sys

    rows = []
    for s in (1, shards):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={s}"
                            ).strip()
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--sharded-worker", str(s), "--sizes", f"{K}x{d}"],
            capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            print(f"sharded worker ({s} dev) failed:\n{proc.stderr}")
            continue
        rows.append(json.loads(proc.stdout.splitlines()[-1]))
    return rows


def bench_kernels(K: int, d: int) -> List[Dict]:
    from repro.kernels.pairwise_dist.ops import pairwise_sq_dists
    from repro.kernels.robust_stats.ops import (
        robust_stats, robust_stats_batch, robust_stats_indexed)
    from repro.kernels.weighted_agg.ops import weighted_agg, weighted_agg_indexed

    key = jax.random.PRNGKey(1)
    updates = jax.random.normal(key, (K, d), jnp.float32)
    prev = jax.random.normal(jax.random.PRNGKey(2), (K, d), jnp.float32)
    batch = jnp.stack([updates] * 4)
    local = updates[0]
    weights = jnp.ones((K,), jnp.float32)
    # gather-free rows: N=4 nodes exchanging over an (M, d) model matrix
    # through a neighbor table — same aggregate work as the batch4 row,
    # minus the (N, K, d) gossip tensor (indexed DMA instead of gather).
    # M = K + 1 model rows so every slate is K DISTINCT non-self rows —
    # with fewer rows the GBps column would credit re-reads of the same
    # few vectors as distinct HBM traffic.
    N, M = 4, K + 1
    models = jax.random.normal(jax.random.PRNGKey(3), (M, d), jnp.float32)
    nidx = jnp.asarray(
        [[(n + o) % M for o in range(1, K + 1)] for n in range(N)], jnp.int32)
    wbatch = jnp.ones((N, K), jnp.float32)
    rows = []
    for name, backend, factor, fn in (
        ("robust_stats[pallas]", "fused", 1.0, lambda: robust_stats(updates)),
        ("robust_stats+prev[pallas]", "fused", 2.0, lambda: robust_stats(updates, prev)),
        ("robust_stats_batch4[pallas]", "fused", 4.0, lambda: robust_stats_batch(batch)),
        ("robust_stats_idx4[pallas]", "fused", 4.0,
         lambda: robust_stats_indexed(models, nidx)),
        ("robust_stats_idx4+prev[pallas]", "fused", 8.0,
         lambda: robust_stats_indexed(models, nidx, prev=models)),
        ("robust_stats[jnp-ref]", "reference", 1.0, lambda: robust_stats(updates, use_kernel=False)),
        ("pairwise[pallas]", "fused", 1.0, lambda: pairwise_sq_dists(updates)),
        ("pairwise[jnp-ref]", "reference", 1.0, lambda: pairwise_sq_dists(updates, use_kernel=False)),
        ("weighted_agg[pallas]", "fused", 1.0, lambda: weighted_agg(local, updates, weights)),
        ("weighted_agg_idx4[pallas]", "fused", 4.0,
         lambda: weighted_agg_indexed(models[:N], models, nidx, wbatch)),
        ("weighted_agg[jnp-ref]", "reference", 1.0, lambda: weighted_agg(local, updates, weights, use_kernel=False)),
    ):
        comp_s, med_s = _timeit(fn, reps=3)
        rows.append(_row(name, K, d, med_s * 1e6, backend,
                         read_factor=factor, compile_us=comp_s * 1e6))
    return rows


def append_trajectory(rows: List[Dict], path: str = TRAJECTORY) -> None:
    """Append one benchmark snapshot to the BENCH_agg.json trajectory."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append({
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_backend": jax.default_backend(),
        "rows": rows,
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=1)


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8x100000,16x100000,16x1000000")
    ap.add_argument("--kernels", action="store_true", help="include Pallas paths")
    ap.add_argument("--sharded", action="store_true",
                    help="include the d-sharded gossip round (1 vs 8 "
                         "virtual devices, subprocesses)")
    ap.add_argument("--sharded-worker", type=int, default=0,
                    help=argparse.SUPPRESS)  # bench_sharded internal
    ap.add_argument("--out", default="")
    ap.add_argument("--bench-json", default="",
                    help="trajectory file to append to (opt-in — "
                         "benchmarks/run.py passes benchmarks/BENCH_agg.json; "
                         "ad-hoc/smoke runs default to not touching the "
                         "committed baseline)")
    args = ap.parse_args(argv)
    if args.sharded_worker:
        K, d = (int(x) for x in args.sizes.split(",")[0].split("x"))
        print(json.dumps(_bench_sharded_worker(args.sharded_worker, K, d)))
        return []
    rows: List[Dict] = []
    for tok in args.sizes.split(","):
        K, d = (int(x) for x in tok.split("x"))
        rows += bench_rules(K, d)
        if args.kernels:
            rows += bench_kernels(K, min(d, 200_000))
            rows += bench_dynamic(K, min(d, 200_000))
            rows += bench_one_launch(K, min(d, 200_000))
        if args.sharded:
            rows += bench_sharded(K, min(d, 200_000))
    for r in rows:
        passes = f" passes={r['passes']}" if "passes" in r else ""
        comp = (f" compile={r['compile_us'] / 1e3:8.1f} ms"
                if "compile_us" in r else "")
        print(f"{r['rule']:28s} K={r['K']:3d} d={r['d']:8d} "
              f"{r['us_per_call']:10.1f} us  {r['GBps']:7.2f} GB/s{comp}"
              f"  [{r['backend']}]{passes}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.bench_json:
        append_trajectory(rows, args.bench_json)
    return rows


if __name__ == "__main__":
    main()
