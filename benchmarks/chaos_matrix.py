"""Gated fault x intensity x attack x aggregator chaos matrix.

The degradation-curve companion to ``benchmarks/robustness_matrix.py``:
every transport-fault kind of ``repro.dfl.faults`` (drop, stale,
duplicate, corrupt, crash_restart, and the combined chaos mix) is swept
over an intensity axis, crossed with attacks and aggregators, and every
cell runs the SAME one-jit chaos scan ``run_dynamic_experiment`` uses —
the fault schedules ride the scan as five extra stacks, so a whole
faulty run still costs one compile (pinned by the ``chaos_scan`` lint
entry).  Each (fault, attack, aggregator) triple yields a degradation
curve: final benign accuracy as a function of fault intensity, anchored
at the shared fault-free cell.

The graceful-degradation claim this pins (docs/FAULTS.md): WFAgg's
sanitizer + staleness pricing + retry-as-redundancy keep accuracy flat
under transport faults that measurably hurt plain mean — the committed
``benchmarks/BENCH_robustness.json`` carries the gate cells under its
``"chaos"`` key and ``scripts/robustness_gate.py`` re-runs and enforces
them in CI.

    PYTHONPATH=src python -m benchmarks.chaos_matrix --out chaos.json
    PYTHONPATH=src python -m benchmarks.chaos_matrix --smoke
    PYTHONPATH=src python -m benchmarks.chaos_matrix --gate-grid \
        --out /tmp/chaos_gate.json   # regenerate the "chaos" baseline
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl import faults as flt
from repro.dfl.dynamics import SCENARIO_NAMES, make_schedule
from repro.dfl.engine import DFLConfig, run_dynamic_experiment

DEFAULT_FAULTS = ("drop", "stale", "duplicate", "corrupt",
                  "crash_restart", "chaos")
DEFAULT_INTENSITIES = (0.15, 0.3, 0.5)
DEFAULT_ATTACKS = ("none", "ipm_100", "band_rider")
DEFAULT_AGGREGATORS = ("mean", "wfagg")

# The gate subgrid: the cells the graceful-degradation claims live in —
# the drop curve at the claimed 0.3 rate, the corrupt curve (the
# sanitizer's cell: any non-finite payload must be demoted before filter
# statistics), and the combined chaos mix, each against the fault-free
# anchor.  The shape is deliberately the LEAN regime — churn scenario,
# 10 nodes at degree 4, a 3-round horizon: on a dense static graph with
# rounds to spare, plain mean shrugs off 30% drops (enough fresh
# neighbors always remain, and the stale-ring redelivery that is part of
# the shared transport covers the rest), so the degradation claims only
# have teeth where the topology layer is also taking edges and
# convergence time actually matters — exactly the paper's "adverse
# conditions" regime.  scripts/robustness_gate.py re-runs EXACTLY this
# dict; keep it in sync with the "chaos" block of BENCH_robustness.json.
CHAOS_GATE = dict(
    faults=("drop", "corrupt", "chaos"),
    intensities=(0.3,),
    attacks=("none", "ipm_100"),
    aggregators=("mean", "wfagg"),
    scenario="churn", rounds=3, nodes=10, degree=4, malicious=2,
    topology="ring", placement="close", backend="fused", model="mlp",
    seed=0, fault_seed=0, n_test=256,
)

SMOKE_GRID = dict(
    faults=("drop", "chaos"),
    intensities=(0.3,),
    attacks=("none", "alie"),
    aggregators=("mean", "wfagg"),
    scenario="churn", rounds=3, nodes=10, degree=4, malicious=2,
    topology="ring", placement="close", backend="fused", model="mlp",
    seed=0, fault_seed=0, n_test=64,
)


def cell_key(fault: str, intensity: float, attack: str,
             aggregator: str) -> str:
    return f"{fault}@{intensity:g}|{attack}|{aggregator}"


def base_key(attack: str, aggregator: str) -> str:
    """The shared fault-free anchor cell of every curve."""
    return cell_key("none", 0.0, attack, aggregator)


def run_matrix(faults=DEFAULT_FAULTS, intensities=DEFAULT_INTENSITIES,
               attacks=DEFAULT_ATTACKS, aggregators=DEFAULT_AGGREGATORS,
               *, scenario: str = "churn", rounds: int = 6, nodes: int = 20,
               degree: int = 8, malicious: int = 2, topology: str = "ring",
               placement: str = "close", backend: str = "fused",
               model: str = "mlp", seed: int = 0, fault_seed: int = 0,
               n_test: int = 256, verbose: bool = True) -> dict:
    """Run the grid; returns ``{"meta": ..., "cells": {key: cell}}``.

    Every (attack, aggregator) pair first runs ONE fault-free anchor
    cell (``none@0``, through the same chaos scan with an all-quiet
    fault schedule — the fault-none == clean equivalence is a tested
    invariant), then each fault kind at each intensity.  Cells record
    final benign accuracy, final consistency R^2, per-round minimum
    accuracy, the scheduled fault rates
    (:meth:`~repro.dfl.faults.FaultSchedule.summary`), and — for wfagg
    cells, which run with telemetry on — the OBSERVED per-fault
    attribution off the packed verdict bits
    (:func:`repro.obs.report.fault_rates`): scheduled vs observed is the
    cross-check that the injection actually reached the filters.
    """
    from repro.obs import report as obs_report

    topo = make_topology(n_nodes=nodes, degree=degree,
                         n_malicious=malicious, kind=topology,
                         placement=placement, seed=seed)
    data = SyntheticImages(seed=seed)
    sched = make_schedule(scenario, topo, rounds, seed=seed)
    cells = {}
    t_start = time.time()

    def run_cell(key, fault, intensity, attack, aggregator):
        cfg = DFLConfig(aggregator=aggregator, attack=attack, model=model,
                        seed=seed, wfagg_backend=backend)
        fs = flt.make_fault_schedule(fault, sched, intensity,
                                     seed=fault_seed)
        telemetry = aggregator in ("wfagg", "alt_wfagg")
        t0 = time.time()
        out = run_dynamic_experiment(cfg, topo, data, sched, n_test=n_test,
                                     telemetry=telemetry, faults=fs)
        acc_series = out["series"]["acc_benign_mean"]
        cell = {
            "final_acc": out["final"]["acc_benign_mean"],
            "final_r2": out["final"]["r_squared"],
            "min_acc": min(acc_series),
            "scheduled": out["faults"],
        }
        if telemetry:
            frates = obs_report.fault_rates(out["telemetry"]["verdict"])
            cell["fault_attribution"] = obs_report.fault_attribution(frates)
        cells[key] = cell
        if verbose:
            print(f"  {key:36s} acc {100 * cell['final_acc']:6.2f}%"
                  f"  R2 {cell['final_r2']:7.4f}"
                  f"  [{time.time() - t0:5.1f}s]", flush=True)
        return cell

    for aggregator in aggregators:
        for attack in attacks:
            run_cell(base_key(attack, aggregator), "none", 0.0, attack,
                     aggregator)
            for fault in faults:
                for intensity in intensities:
                    run_cell(cell_key(fault, intensity, attack, aggregator),
                             fault, intensity, attack, aggregator)

    meta = dict(faults=tuple(faults), intensities=tuple(intensities),
                attacks=tuple(attacks), aggregators=tuple(aggregators),
                scenario=scenario, rounds=rounds, nodes=nodes, degree=degree,
                malicious=malicious, topology=topology, placement=placement,
                backend=backend, model=model, seed=seed,
                fault_seed=fault_seed, n_test=n_test,
                wall_s=round(time.time() - t_start, 1))
    return {"meta": meta, "cells": cells}


def degradation_curves(result: dict) -> dict:
    """``{fault|attack|aggregator: {"intensities": [0, ...], "acc":
    [...], "r2": [...]}}`` — each curve anchored at the fault-free cell
    (intensity 0), accuracy falling (or not) as intensity rises.  This
    is the JSON artifact the chaos-smoke CI job uploads."""
    meta, cells = result["meta"], result["cells"]
    curves = {}
    for aggregator in meta["aggregators"]:
        for attack in meta["attacks"]:
            anchor = cells[base_key(attack, aggregator)]
            for fault in meta["faults"]:
                xs, acc, r2 = [0.0], [anchor["final_acc"]], [anchor["final_r2"]]
                for intensity in meta["intensities"]:
                    c = cells[cell_key(fault, intensity, attack, aggregator)]
                    xs.append(float(intensity))
                    acc.append(c["final_acc"])
                    r2.append(c["final_r2"])
                curves[f"{fault}|{attack}|{aggregator}"] = {
                    "intensities": xs, "acc": acc, "r2": r2}
    return curves


def print_curves(result: dict) -> None:
    meta = result["meta"]
    curves = degradation_curves(result)
    print("\ndegradation curves (final benign accuracy % by fault "
          "intensity; 0 = fault-free anchor)")
    xs = [0.0] + [float(i) for i in meta["intensities"]]
    head = f"{'fault | attack | aggregator':>40s}" + "".join(
        f"{x:>9g}" for x in xs)
    print(head)
    for key, curve in curves.items():
        row = f"{key:>40s}"
        for a in curve["acc"]:
            row += f"{100 * a:9.2f}"
        print(row)


def _axis(value, default, universe=None, cast=str):
    if value == "default":
        return default
    names = tuple(cast(v.strip()) for v in str(value).split(",") if v.strip())
    if universe is not None:
        for v in names:
            if v not in universe:
                raise SystemExit(
                    f"unknown axis entry {v!r}; choose from {universe}")
    return names


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--faults", default="default",
                    help=f"comma list from {flt.FAULT_NAMES}")
    ap.add_argument("--intensities", default="default",
                    help="comma list of floats in [0, 1]")
    ap.add_argument("--attacks", default="default", help="comma list")
    ap.add_argument("--aggregators", default="default", help="comma list")
    ap.add_argument("--scenario", default="churn", choices=SCENARIO_NAMES)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--malicious", type=int, default=2)
    ap.add_argument("--topology", default="ring",
                    choices=("ring", "complete", "erdos_renyi"))
    ap.add_argument("--backend", default="fused",
                    choices=("fused", "fused_two_launch", "reference"))
    ap.add_argument("--model", default="mlp", choices=("mlp", "lenet"))
    ap.add_argument("--placement", default="close",
                    choices=("spaced", "close"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--n-test", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed grid (the CI chaos-smoke job)")
    ap.add_argument("--gate-grid", action="store_true",
                    help="run exactly the gate subgrid (regenerates the "
                         "'chaos' block of BENCH_robustness.json)")
    ap.add_argument("--out", default="",
                    help="write {'meta', 'cells', 'curves'} JSON here")
    args = ap.parse_args(argv)

    if args.smoke or args.gate_grid:
        grid = dict(SMOKE_GRID if args.smoke else CHAOS_GATE)
    else:
        grid = dict(
            faults=_axis(args.faults, DEFAULT_FAULTS, flt.FAULT_NAMES),
            intensities=_axis(args.intensities, DEFAULT_INTENSITIES,
                              cast=float),
            attacks=_axis(args.attacks, DEFAULT_ATTACKS),
            aggregators=_axis(args.aggregators, DEFAULT_AGGREGATORS),
            scenario=args.scenario, rounds=args.rounds, nodes=args.nodes,
            degree=args.degree, malicious=args.malicious,
            topology=args.topology, placement=args.placement,
            backend=args.backend, model=args.model, seed=args.seed,
            fault_seed=args.fault_seed, n_test=args.n_test,
        )
    result = run_matrix(**grid)
    result["curves"] = degradation_curves(result)
    print_curves(result)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
