"""Paper Figures 4/6: R-squared model-consistency among benign nodes in the
decentralized scenario, last federation round, per aggregator x attack."""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.engine import DFLConfig, run_experiment

AGGS = ("mean", "median", "multi_krum", "clustering", "wfagg_d", "wfagg")
ATTACKS = ("none", "noise", "sign_flip", "ipm_100", "alie")


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--model", default="mlp")
    ap.add_argument("--aggs", default=",".join(AGGS))
    ap.add_argument("--attacks", default=",".join(ATTACKS))
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    rows = []
    data = SyntheticImages()
    topo = make_topology(kind="ring")
    for agg in args.aggs.split(","):
        for attack in args.attacks.split(","):
            cfg = DFLConfig(aggregator=agg, attack=attack, model=args.model)
            out = run_experiment(cfg, topo, data, rounds=args.rounds,
                                 eval_every=max(1, args.rounds))
            r2 = out["final"]["r_squared"]
            rows.append({"aggregator": agg, "attack": attack,
                         "r_squared": round(float(r2), 4)})
            print(f"{agg:12s} {attack:10s} R2={r2:8.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
