"""DART-style per-round robustness report across dynamic-topology
scenarios (cf. arXiv 2407.08652 / 2407.05141: Byzantine robustness under
round-varying graphs).

For every scenario in ``repro.dfl.dynamics.SCENARIOS`` this runs the
same federation (WFAgg fused backend, configurable attack) under a
round-varying schedule and prints the per-round accuracy / consistency
time series side by side, plus the per-round degree statistics and edge
churn — the table the "dynamic decentralized topologies" claim of the
paper is judged by.

    PYTHONPATH=src python -m benchmarks.dynamic_report \
        --rounds 8 --attack ipm_100 --out report.json
"""
from __future__ import annotations

import argparse
import json

from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.dynamics import SCENARIO_NAMES, make_schedule
from repro.dfl.engine import DFLConfig, run_dynamic_experiment


def run_report(aggregator: str = "wfagg", attack: str = "ipm_100",
               rounds: int = 8, nodes: int = 20, degree: int = 8,
               malicious: int = 2, seed: int = 0, n_test: int = 256):
    topo = make_topology(n_nodes=nodes, degree=degree,
                         n_malicious=malicious, kind="ring",
                         placement="close", seed=seed)
    data = SyntheticImages(seed=seed)
    cfg = DFLConfig(aggregator=aggregator, attack=attack, model="mlp",
                    seed=seed)
    report = {}
    for name in SCENARIO_NAMES:
        sched = make_schedule(name, topo, rounds, seed=seed)
        out = run_dynamic_experiment(cfg, topo, data, sched, n_test=n_test)
        s = out["series"]
        report[name] = {
            "acc_benign_mean": s["acc_benign_mean"],
            "r_squared": s["r_squared"],
            "degree_min_mean_max": s["degree_min_mean_max"],
            "edge_churn": sched.diff().tolist(),
            "malicious_per_round": sched.malicious.sum(axis=1).tolist(),
            "final_acc": out["final"]["acc_benign_mean"],
            "final_r2": out["final"]["r_squared"],
        }
    return report


def print_report(report) -> None:
    rounds = len(next(iter(report.values()))["acc_benign_mean"])
    print("\nper-round benign accuracy (%)")
    head = "round " + "".join(f"{name:>14s}" for name in report)
    print(head)
    for r in range(rounds):
        row = f"{r + 1:5d} "
        for name in report:
            row += f"{100 * report[name]['acc_benign_mean'][r]:14.2f}"
        print(row)
    print("\nper-round consistency R^2")
    print(head)
    for r in range(rounds):
        row = f"{r + 1:5d} "
        for name in report:
            row += f"{report[name]['r_squared'][r]:14.4f}"
        print(row)
    print("\nscenario summary (final round)")
    for name, rep in report.items():
        deg = rep["degree_min_mean_max"][-1]
        churn = sum(a + r for a, r in rep["edge_churn"]) or 0
        print(f"  {name:14s} acc {100 * rep['final_acc']:6.2f}%  "
              f"R2 {rep['final_r2']:7.4f}  "
              f"deg {deg[0]:.0f}/{deg[1]:.1f}/{deg[2]:.0f}  "
              f"total edge churn {churn}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--aggregator", default="wfagg",
                    choices=("wfagg", "alt_wfagg"))
    ap.add_argument("--attack", default="ipm_100")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--malicious", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    report = run_report(aggregator=args.aggregator, attack=args.attack,
                        rounds=args.rounds, nodes=args.nodes,
                        degree=args.degree, malicious=args.malicious,
                        seed=args.seed)
    print(f"aggregator={args.aggregator} attack={args.attack} "
          f"rounds={args.rounds} nodes={args.nodes}")
    print_report(report)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return report


if __name__ == "__main__":
    main()
