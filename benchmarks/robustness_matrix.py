"""Gated attack x scenario x aggregator robustness matrix.

The DART-style evaluation (arXiv 2407.08652 / 2407.05141) the paper
never runs: every attack (oblivious, omniscient AND the defense-aware
adaptive adversaries of ``core.attacks``) crossed with every topology
condition (including the eclipse/dos/collusion topology ATTACKS of
``repro.dfl.dynamics``) crossed with every aggregation rule — the
baselines ride the valid-mask-aware ``DYN_AGGREGATORS`` path, so
mean/median/multi_krum/clustering fill their rows of the grid under
dynamic graphs too, not just wfagg/alt_wfagg.

Every cell runs the SAME federation (one ``run_dynamic_experiment``
scan; the static scenario is a constant schedule, so a single code path
produces the whole grid) and records final benign accuracy + model
consistency R^2.  The committed ``benchmarks/BENCH_robustness.json``
pins the gate subgrid; ``scripts/robustness_gate.py`` re-runs it in CI
and fails on regression — the executable form of the robustness claims.

    PYTHONPATH=src python -m benchmarks.robustness_matrix \
        --rounds 6 --out matrix.json            # default grid
    PYTHONPATH=src python -m benchmarks.robustness_matrix --smoke
    PYTHONPATH=src python -m benchmarks.robustness_matrix --gate-grid \
        --out benchmarks/BENCH_robustness.json  # regenerate the baseline

Supersedes ``benchmarks/dynamic_report.py`` (one attack x one
aggregator across scenarios — the scenario axis of this grid).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import attacks as atk
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.dynamics import SCENARIO_NAMES, make_schedule
from repro.dfl.engine import (
    AGGREGATOR_NAMES,
    DFLConfig,
    run_dynamic_experiment,
)

# Default grid: every adversary class x every topology class x the
# paper's aggregator lineup.  (The full SCENARIO_NAMES x ATTACK_NAMES x
# AGGREGATOR_NAMES cube is available via --attacks all etc.)
DEFAULT_ATTACKS = ("none", "sign_flip", "ipm_100", "alie",
                   "band_rider", "min_max")
DEFAULT_SCENARIOS = ("static", "churn", "eclipse", "dos", "collusion")
DEFAULT_AGGREGATORS = ("mean", "median", "multi_krum", "clustering",
                       "wfagg", "alt_wfagg")

# The gate subgrid: small enough for CI, wide enough that the committed
# baseline pins (a) an adaptive and an omniscient attack, (b) a benign
# and an adversarial topology, (c) the weakest baseline next to WFAgg —
# the cells the acceptance claims live in.  scripts/robustness_gate.py
# re-runs EXACTLY this dict; keep it in sync with BENCH_robustness.json
# (regenerate via --gate-grid).
GATE_GRID = dict(
    attacks=("none", "ipm_100", "band_rider", "min_max"),
    scenarios=("static", "eclipse"),
    aggregators=("mean", "multi_krum", "wfagg"),
    rounds=6, nodes=20, degree=8, malicious=2, topology="ring",
    placement="close", backend="fused", model="mlp", seed=0, n_test=256,
)

SMOKE_GRID = dict(
    attacks=("none", "ipm_100", "band_rider"),
    scenarios=("static", "eclipse"),
    aggregators=("mean", "wfagg"),
    rounds=3, nodes=10, degree=4, malicious=2, topology="ring",
    placement="close", backend="fused", model="mlp", seed=0, n_test=64,
)


def cell_key(attack: str, scenario: str, aggregator: str) -> str:
    return f"{attack}|{scenario}|{aggregator}"


def run_matrix(attacks=DEFAULT_ATTACKS, scenarios=DEFAULT_SCENARIOS,
               aggregators=DEFAULT_AGGREGATORS, *, rounds: int = 6,
               nodes: int = 20, degree: int = 8, malicious: int = 2,
               topology: str = "ring", placement: str = "close",
               backend: str = "fused", model: str = "mlp", seed: int = 0,
               n_test: int = 256, verbose: bool = True) -> dict:
    """Run the grid; returns ``{"meta": ..., "cells": {key: cell}}``.

    ``meta`` records every knob (so the gate can re-run the exact grid
    from the committed JSON alone) and each cell keeps the final benign
    accuracy, the final consistency R^2 and the per-round minimum
    accuracy (transient collapse shows up there before it shows up in
    the final round).

    wfagg/alt_wfagg cells additionally run with the flight recorder's
    decision plane on (``telemetry=True`` — pure traced scan outputs,
    same launch count) and carry the per-cell FILTER ATTRIBUTION: each
    filter's mean true-catch / false-positive rates over the attacked
    rounds, ``carried_by`` (the filter with the best catch-minus-FP
    margin — which filter actually carried the defense in that attack x
    scenario cell), and the mean-fallback / degree-0 round counts.  The
    gate comparator only reads final_acc/final_r2, so the new columns
    are regression-gate-safe.  See docs/OBSERVABILITY.md.
    """
    from repro.obs import report as obs_report

    topo = make_topology(n_nodes=nodes, degree=degree,
                         n_malicious=malicious, kind=topology,
                         placement=placement, seed=seed)
    data = SyntheticImages(seed=seed)
    schedules = {s: make_schedule(s, topo, rounds, seed=seed)
                 for s in scenarios}
    cells = {}
    t_start = time.time()
    for scenario in scenarios:
        sched = schedules[scenario]
        for aggregator in aggregators:
            for attack in attacks:
                cfg = DFLConfig(aggregator=aggregator, attack=attack,
                                model=model, seed=seed,
                                wfagg_backend=backend)
                telemetry = aggregator in ("wfagg", "alt_wfagg")
                t0 = time.time()
                out = run_dynamic_experiment(cfg, topo, data, sched,
                                             n_test=n_test,
                                             telemetry=telemetry)
                acc_series = out["series"]["acc_benign_mean"]
                cell = {
                    "final_acc": out["final"]["acc_benign_mean"],
                    "final_r2": out["final"]["r_squared"],
                    "min_acc": min(acc_series),
                }
                if telemetry:
                    rates = obs_report.telemetry_rates(out["telemetry"])
                    attr = obs_report.attribution(rates)
                    cell["filter_attribution"] = attr
                    cell["mean_fallback_rounds"] = sum(
                        1 for c in out["series"]["mean_fallback_count"]
                        if c > 0)
                    cell["degree_zero_rounds"] = sum(
                        1 for c in out["series"]["degree_zero_count"]
                        if c > 0)
                cells[cell_key(attack, scenario, aggregator)] = cell
                if verbose:
                    carried = (f"  carried by {attr['carried_by'].upper()}"
                               if telemetry and attr.get("carried_by")
                               else "")
                    print(f"  {cell_key(attack, scenario, aggregator):40s}"
                          f" acc {100 * cell['final_acc']:6.2f}%"
                          f"  R2 {cell['final_r2']:7.4f}"
                          f"  [{time.time() - t0:5.1f}s]{carried}",
                          flush=True)
    meta = dict(attacks=tuple(attacks), scenarios=tuple(scenarios),
                aggregators=tuple(aggregators), rounds=rounds, nodes=nodes,
                degree=degree, malicious=malicious, topology=topology,
                placement=placement, backend=backend, model=model,
                seed=seed, n_test=n_test,
                wall_s=round(time.time() - t_start, 1))
    return {"meta": meta, "cells": cells}


def print_matrix(result: dict) -> None:
    meta, cells = result["meta"], result["cells"]
    for scenario in meta["scenarios"]:
        print(f"\nscenario: {scenario}  (final benign accuracy % / R^2)")
        head = f"{'attack':>12s} " + "".join(
            f"{a:>18s}" for a in meta["aggregators"])
        print(head)
        for attack in meta["attacks"]:
            row = f"{attack:>12s} "
            for agg in meta["aggregators"]:
                c = cells[cell_key(attack, scenario, agg)]
                row += f"{100 * c['final_acc']:8.2f}/{c['final_r2']:6.3f}   "
            print(row)


def _axis(value: str, default: tuple, universe: tuple) -> tuple:
    if value == "default":
        return default
    if value == "all":
        return universe
    names = tuple(v.strip() for v in value.split(",") if v.strip())
    for v in names:
        if v not in universe:
            raise SystemExit(f"unknown axis entry {v!r}; choose from "
                             f"{universe}")
    return names


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--attacks", default="default",
                    help="comma list | 'all' (from ATTACK_NAMES)")
    ap.add_argument("--scenarios", default="default",
                    help="comma list | 'all' (from SCENARIO_NAMES)")
    ap.add_argument("--aggregators", default="default",
                    help="comma list | 'all' (from AGGREGATOR_NAMES)")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--malicious", type=int, default=2)
    ap.add_argument("--topology", default="ring",
                    choices=("ring", "complete", "erdos_renyi"))
    ap.add_argument("--backend", default="fused",
                    choices=("fused", "fused_two_launch", "reference"),
                    help="WFAgg execution backend for wfagg/alt_wfagg cells")
    ap.add_argument("--model", default="mlp", choices=("mlp", "lenet"))
    ap.add_argument("--placement", default="close",
                    choices=("spaced", "close"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-test", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed grid (the CI robustness-matrix job)")
    ap.add_argument("--gate-grid", action="store_true",
                    help="run exactly the gate subgrid (regenerates the "
                         "committed BENCH_robustness.json baseline with "
                         "--out)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.smoke or args.gate_grid:
        grid = dict(SMOKE_GRID if args.smoke else GATE_GRID)
    else:
        grid = dict(
            attacks=_axis(args.attacks, DEFAULT_ATTACKS, atk.ATTACK_NAMES),
            scenarios=_axis(args.scenarios, DEFAULT_SCENARIOS,
                            SCENARIO_NAMES),
            aggregators=_axis(args.aggregators, DEFAULT_AGGREGATORS,
                              AGGREGATOR_NAMES),
            rounds=args.rounds, nodes=args.nodes, degree=args.degree,
            malicious=args.malicious, topology=args.topology,
            placement=args.placement, backend=args.backend,
            model=args.model, seed=args.seed, n_test=args.n_test,
        )
    result = run_matrix(**grid)
    print_matrix(result)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
