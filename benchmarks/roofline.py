"""Roofline report: reads the dry-run artifacts (benchmarks/artifacts/)
and renders the per-(arch x shape x mesh) table for EXPERIMENTS.md
Section Roofline — three terms, dominant bottleneck, MODEL_FLOPS ratio,
HBM fit, and a one-line remediation note per row.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

_NOTES = {
    ("collective", True): "overlap/shard the robust-agg gather (ring schedule, TP-sharded flat gradient)",
    ("collective", False): "reduce cross-device traffic: keep gradient TP-sharded through aggregation",
    ("memory", True): "cut HBM traffic: chunked attention / fused robust-stats pass",
    ("memory", False): "cut HBM traffic AND capacity: chunked attention, bf16 stats, sharded flat gradient",
    ("compute", True): "compute-bound: good; raise MFU via larger per-chip tiles",
    ("compute", False): "compute-bound but over HBM capacity: reshard weights",
}


def load(tag: str = "") -> List[Dict]:
    """Artifact names are {arch}.{shape}.{single|multi}[.{tag}].json (arch
    ids themselves contain dots, so match the structured suffix)."""
    recs = []
    if tag:
        pat = f"*.{tag}.json"
    else:
        pat = "*.json"
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, pat))):
        base = os.path.basename(path)
        parts = base[: -len(".json")].rsplit(".", 2)
        if tag:
            ok = len(parts) == 3 and parts[1] in ("single", "multi") and parts[2] == tag
        else:
            ok = parts[-1] in ("single", "multi")
        if not ok:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:6.1f}ms"
    return f"{x * 1e6:6.1f}us"


def render(recs: List[Dict], mesh: Optional[str] = "16x16") -> str:
    lines = [
        "| arch | shape | mode | compute | memory | collective | dominant | "
        "useful-FLOPs | HBM/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                         f"SKIP: {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | ERROR | | | | | | |")
            continue
        ro, mem = r["roofline"], r["memory"]
        mode = r["mode"].get("mode", "?") if isinstance(r["mode"], dict) else r["mode"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mode} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"**{ro['dominant']}** | {ro['useful_flops_ratio']:.3f} | "
            f"{mem['peak_bytes'] / 1e9:.1f}GB | {'Y' if mem['fits'] else 'N'} |"
        )
    return "\n".join(lines)


def summarize(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r["status"] == "ok"]
    worst_frac = None
    most_coll = None
    for r in ok:
        ro = r["roofline"]
        frac = ro["compute_s"] / max(ro["step_s_lower_bound"], 1e-30)
        r["_frac"] = frac
        cshare = ro["collective_s"] / max(ro["step_s_lower_bound"], 1e-30)
        r["_cshare"] = cshare
        if worst_frac is None or frac < worst_frac["_frac"]:
            worst_frac = r
        if most_coll is None or cshare > most_coll["_cshare"]:
            most_coll = r
    return {
        "n_ok": len(ok),
        "n_skip": sum(1 for r in recs if r["status"] == "skipped"),
        "n_err": sum(1 for r in recs if r["status"] not in ("ok", "skipped")),
        "worst_roofline_fraction": (worst_frac["arch"], worst_frac["shape"])
        if worst_frac else None,
        "most_collective_bound": (most_coll["arch"], most_coll["shape"])
        if most_coll else None,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all-meshes", action="store_true")
    args = ap.parse_args(argv)
    recs = load(args.tag)
    mesh = None if args.all_meshes else args.mesh
    print(render(recs, mesh))
    print()
    print(json.dumps(summarize([r for r in recs
                                if not mesh or r.get("mesh") == mesh]), indent=1))


if __name__ == "__main__":
    main()
