"""Benchmark driver — one section per paper table/figure.

  table1        paper Table I   (accuracy per aggregator x attack, CFL+DFL)
  r2            paper Figs 4/6  (R^2 model consistency, DFL)
  microbench    aggregation-rule complexity table (Section IV)
  roofline      Section Roofline report from dry-run artifacts

``python -m benchmarks.run`` runs the fast versions of everything;
``--only table1 --full`` etc. for the complete sweeps.
"""
from __future__ import annotations

import argparse
import os
import time

HERE = os.path.dirname(__file__)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list: table1,r2,microbench,roofline")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    selected = set(args.only.split(",")) if args.only else {
        "table1", "r2", "microbench", "roofline"}

    t0 = time.time()
    results = {}

    if "table1" in selected:
        print("=" * 72)
        print("== Table I: aggregator x attack accuracy (CFL + DFL) ==")
        from benchmarks import table1_attacks
        argv = ["--rounds", str(args.rounds),
                "--out", os.path.join(HERE, "out_table1.json")]
        if args.full:
            argv.append("--full")
        results["table1"] = table1_attacks.main(argv)

    if "r2" in selected:
        print("=" * 72)
        print("== R^2 model consistency (paper Figs 4/6) ==")
        from benchmarks import consistency_r2
        results["r2"] = consistency_r2.main(
            ["--rounds", str(args.rounds),
             "--out", os.path.join(HERE, "out_r2.json")])

    if "microbench" in selected:
        print("=" * 72)
        print("== aggregation microbenchmark ==")
        from benchmarks import agg_microbench
        # every run appends to the BENCH_agg.json trajectory so future
        # PRs have a perf baseline (rule, K, d, us_per_call, backend)
        argv = ["--out", os.path.join(HERE, "out_microbench.json"),
                "--bench-json", os.path.join(HERE, "BENCH_agg.json")]
        if args.full:
            argv.append("--kernels")
        results["microbench"] = agg_microbench.main(argv)

    if "roofline" in selected:
        print("=" * 72)
        print("== roofline report (from dry-run artifacts) ==")
        from benchmarks import roofline
        n = len(roofline.load())
        if n == 0:
            print("no artifacts found — run `python -m repro.launch.dryrun --all` first")
        else:
            roofline.main([])

    print("=" * 72)
    print(f"total benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
