"""Paper Table I: accuracy of every aggregation scheme under every attack,
in centralized AND decentralized scenarios (decentralized columns broken
down by the node's number of malicious neighbors: 0 / 1 / 2).

MNIST is not downloadable in this container (repro band 2/5), so the run
uses the synthetic MNIST-shaped task from ``repro.data.synthetic``; the
validation target is the qualitative Table-I structure — WHICH aggregator
collapses under WHICH attack — not the absolute MNIST accuracies.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.core.attacks import ATTACK_NAMES
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.engine import DFLConfig, run_experiment

AGGREGATORS = (
    "mean", "trimmed_mean", "median", "krum", "multi_krum", "clustering",
    "wfagg_d", "wfagg_c", "wfagg_t", "wfagg_e", "alt_wfagg", "wfagg",
)
# core.attacks.ATTACK_NAMES is the single source of attack-choice truth;
# the full table runs every registered attack (including the adaptive
# band_rider/min_max — a beyond-paper column), minus the redundant
# generic "ipm" (ipm_0.5/ipm_100 are the paper's two fixed-eps columns).
ATTACKS = tuple(a for a in ATTACK_NAMES if a != "ipm")

FAST_AGGREGATORS = ("mean", "median", "multi_krum", "clustering", "wfagg_d", "wfagg")
FAST_ATTACKS = tuple(a for a in ATTACK_NAMES
                     if a in ("none", "noise", "sign_flip", "ipm_0.5",
                              "ipm_100", "alie"))


def run_cell(agg: str, attack: str, centralized: bool, rounds: int,
             model: str = "lenet", seed: int = 0) -> Dict:
    cfg = DFLConfig(aggregator=agg, attack=attack, model=model,
                    centralized=centralized, seed=seed)
    topo = make_topology(n_nodes=cfg.paper.n_nodes, degree=cfg.paper.degree,
                         n_malicious=cfg.paper.n_malicious, kind="ring",
                         placement="close")  # populates the 0/1/2-m.n. columns
    data = SyntheticImages(seed=seed)
    out = run_experiment(cfg, topo, data, rounds=rounds,
                         eval_every=max(1, rounds))
    return out["final"]


def run_table(aggs, attacks, rounds: int, model: str) -> List[Dict]:
    rows = []
    for agg in aggs:
        for attack in attacks:
            t0 = time.time()
            cen = run_cell(agg, attack, True, rounds, model)
            dec = run_cell(agg, attack, False, rounds, model)
            row = {
                "aggregator": agg, "attack": attack,
                "centralized_acc": round(100 * cen["acc_benign_mean"], 2),
                "dec_acc_0mn": round(100 * dec["acc_by_malicious_neighbors"][0], 2),
                "dec_acc_1mn": round(100 * dec["acc_by_malicious_neighbors"][1], 2),
                "dec_acc_2mn": round(100 * dec["acc_by_malicious_neighbors"][2], 2),
                "dec_r2": round(dec["r_squared"], 4),
                "wall_s": round(time.time() - t0, 1),
            }
            rows.append(row)
            print(f"{agg:12s} {attack:10s} cen={row['centralized_acc']:6.2f} "
                  f"dec(0/1/2 m.n.)={row['dec_acc_0mn']:6.2f}/"
                  f"{row['dec_acc_1mn']:6.2f}/{row['dec_acc_2mn']:6.2f} "
                  f"R2={row['dec_r2']:7.4f}  [{row['wall_s']}s]")
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 12 aggregators x 7 attacks (paper Table I)")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--model", default="mlp", choices=("mlp", "lenet"))
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    aggs = AGGREGATORS if args.full else FAST_AGGREGATORS
    attacks = ATTACKS if args.full else FAST_ATTACKS
    rows = run_table(aggs, attacks, args.rounds, args.model)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
