"""The paper's full validation scenario (Section V) as a configurable
experiment — any aggregator x any attack x CFL/DFL, with the per-node
accuracy trace of the paper's Figure 7.

    PYTHONPATH=src python examples/dfl_paper_experiment.py \
        --aggregator wfagg --attack noise --rounds 10 --model lenet

Beyond-paper switches: ``--topology erdos_renyi`` runs the gather-free
irregular-degree path (padded neighbor tables), ``--backend
fused|fused_two_launch|reference`` selects the WFAgg execution backend
(fused = the single-launch round kernel, the default), and
``--scenario churn|link_failure|partition|mobility|sleeper|eclipse|dos|
collusion`` runs the whole experiment under a round-varying topology
schedule (one jit, lax.scan over the schedule — the graph and the
Byzantine set change every round with no retrace) and prints the
DART-style per-round robustness time series.  ``--telemetry`` turns on
the flight recorder's decision plane (repro.obs): per-round per-filter
true-catch/false-positive rates are printed after the trace, and
``--events-out``/``--trace-out`` write the JSONL event log and the
Perfetto trace_event JSON (docs/OBSERVABILITY.md; the full audit lives
in ``python -m repro.obs.report``).  Every backend handles
irregular topologies and dynamic scenarios: the fused paths in-kernel,
the reference backend via the valid-aware pure-jnp oracle — and the
baseline aggregators (mean/median/trimmed_mean/krum/multi_krum/
clustering) run scenarios too, through the valid-mask-aware
``DYN_AGGREGATORS`` variants.  ``--attack band_rider|min_max`` runs the
defense-aware adaptive adversaries (see docs/THREAT_MODEL.md).
"""
import argparse

import numpy as np

from repro.core.aggregators import DYN_AGGREGATORS
from repro.core.attacks import ATTACK_NAMES
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.dynamics import SCENARIO_NAMES, make_schedule
from repro.dfl.engine import (AGGREGATOR_NAMES, DFLConfig,
                              run_dynamic_experiment, run_experiment)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--aggregator", default="wfagg", choices=AGGREGATOR_NAMES)
    ap.add_argument("--attack", default="noise", choices=ATTACK_NAMES)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--model", default="mlp", choices=("mlp", "lenet"))
    ap.add_argument("--centralized", action="store_true")
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--malicious", type=int, default=2)
    ap.add_argument("--placement", default="close", choices=("close", "spaced"))
    ap.add_argument("--topology", default="ring",
                    choices=("ring", "complete", "erdos_renyi"),
                    help="gossip graph; erdos_renyi exercises the "
                         "irregular-degree (padded-table) path")
    ap.add_argument("--backend", default="fused",
                    choices=("fused", "fused_two_launch", "reference"),
                    help="WFAgg execution backend (fused = single-launch "
                         "gather-free round kernel; fused_two_launch = "
                         "separate stats + combine launches; reference = "
                         "multi-pass jnp, valid-aware)")
    ap.add_argument("--scenario", default="",
                    choices=("",) + SCENARIO_NAMES,
                    help="dynamic-topology scenario: the experiment runs "
                         "under a round-varying neighbor-table schedule "
                         "(see repro.dfl.dynamics.SCENARIOS)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="flight-recorder decision plane: per-filter "
                         "true-catch/false-positive audit after the "
                         "trace (docs/OBSERVABILITY.md)")
    ap.add_argument("--events-out", default="",
                    help="write the telemetry JSONL event log here "
                         "(implies --telemetry)")
    ap.add_argument("--trace-out", default="",
                    help="write Perfetto trace_event JSON here — load "
                         "at ui.perfetto.dev (implies --telemetry)")
    args = ap.parse_args()
    if args.events_out or args.trace_out:
        args.telemetry = True
    if args.telemetry and args.centralized:
        ap.error("--telemetry records per-edge gossip verdicts; the CFL "
                 "baseline has no edges")
    if args.scenario:
        if args.centralized:
            ap.error("--scenario is a decentralized (gossip) feature")
        if args.aggregator not in ("wfagg", "alt_wfagg") \
                and args.aggregator not in DYN_AGGREGATORS:
            ap.error(f"--scenario needs a valid-mask-aware aggregator: "
                     f"wfagg, alt_wfagg or one of "
                     f"{', '.join(DYN_AGGREGATORS)}")

    kind = "complete" if args.centralized else args.topology
    topo = make_topology(n_nodes=args.nodes, degree=args.degree,
                         n_malicious=args.malicious, kind=kind,
                         seed=args.seed, placement=args.placement)
    data = SyntheticImages(seed=args.seed)
    cfg = DFLConfig(aggregator=args.aggregator, attack=args.attack,
                    model=args.model, centralized=args.centralized,
                    seed=args.seed, wfagg_backend=args.backend)
    schedule = None
    if args.scenario:
        schedule = make_schedule(args.scenario, topo, args.rounds,
                                 seed=args.seed)
        out = run_dynamic_experiment(cfg, topo, data, schedule,
                                     telemetry=args.telemetry)
    else:
        out = run_experiment(cfg, topo, data, rounds=args.rounds,
                             eval_every=1, telemetry=args.telemetry)

    degs = topo.degrees
    print(f"aggregator={args.aggregator} attack={args.attack} "
          f"{'CFL' if args.centralized else 'DFL'} rounds={args.rounds} "
          f"topology={kind} backend={args.backend} "
          f"scenario={args.scenario or 'static'} "
          f"degrees={int(degs.min())}..{int(degs.max())}")
    mal = set(map(int, topo.malicious.nonzero()[0]))
    print(f"malicious nodes: {sorted(mal)}")
    if schedule is not None:
        dstats = schedule.degree_stats()
        diff = schedule.diff()
        for e in out["trace"]:
            r = e["round"] - 1
            churn = (f"  edges +{int(diff[r - 1][0])}/-{int(diff[r - 1][1])}"
                     if r > 0 else "")
            print(f"round {e['round']:2d}  benign acc "
                  f"{100 * e['acc_benign_mean']:6.2f}%  "
                  f"R2 {e['r_squared']:8.4f}  "
                  f"deg {dstats[r][0]:.0f}/{dstats[r][1]:.1f}/{dstats[r][2]:.0f}"
                  f"  mal {int(schedule.malicious[r].sum())}{churn}")
    else:
        for e in out["trace"]:
            print(f"round {e['round']:2d}  benign acc "
                  f"{100 * e['acc_benign_mean']:6.2f}%  "
                  f"R2 {e['r_squared']:8.4f}")

    # paper Fig. 7: per-node accuracy at the final round
    print("\nper-node final accuracy (x = malicious):")
    accs = out["final"]["acc_all"]
    for i, a in enumerate(accs):
        marker = " x" if i in mal else "  "
        print(f"  node {i:2d}{marker} {100 * a:6.2f}%  " + "#" * int(40 * a))

    if args.telemetry:
        from repro.obs import recorder as obs_recorder
        from repro.obs import report as obs_report
        from repro.obs import trace as obs_trace

        events = obs_report.events_from_telemetry(
            out["telemetry"],
            dict(aggregator=args.aggregator, attack=args.attack,
                 scenario=args.scenario or "static",
                 backend=args.backend))
        print()
        print(obs_report.render_audit(events))
        if args.events_out:
            obs_recorder.write_events(events, args.events_out)
            print(f"\nwrote event log:     {args.events_out}")
        if args.trace_out:
            obs_trace.write_trace(events, args.trace_out)
            print(f"wrote Perfetto trace: {args.trace_out}")


if __name__ == "__main__":
    main()
