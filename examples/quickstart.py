"""Quickstart: WFAgg vs plain Mean under a strong Byzantine attack.

Runs the paper's 20-node decentralized federation (8-regular ring, 2
Byzantine nodes) on the synthetic MNIST-shaped task, once with the
non-robust Mean aggregator and once with WFAgg, under the IPM-100 attack
— the attack that fully collapses the mean in the paper's Table I.
A final block repeats the WFAgg run on a DYNAMIC topology (node churn)
to show the scenario engine's 5-line entry point, then pits an ADAPTIVE
adversary (min_max — it observes the defense's filter radii, see
docs/THREAT_MODEL.md) against Multi-Krum and WFAgg.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.dynamics import make_schedule
from repro.dfl.engine import DFLConfig, run_dynamic_experiment, run_experiment


def main() -> None:
    topo = make_topology(n_nodes=20, degree=8, n_malicious=2, kind="ring",
                         placement="close")
    data = SyntheticImages()
    print(f"topology: {topo.n_nodes} nodes, degree {topo.degree}, "
          f"malicious: {list(map(int, topo.malicious.nonzero()[0]))}")

    for agg in ("mean", "wfagg"):
        cfg = DFLConfig(aggregator=agg, attack="ipm_100", model="mlp")
        out = run_experiment(cfg, topo, data, rounds=6, eval_every=2)
        print(f"\n=== aggregator: {agg}  (attack: IPM-100) ===")
        for e in out["trace"]:
            by = e["acc_by_malicious_neighbors"]
            print(f"  round {e['round']:2d}  benign acc {100 * e['acc_benign_mean']:6.2f}%  "
                  f"(0/1/2 m.n.: {100 * by[0]:.1f}/{100 * by[1]:.1f}/{100 * by[2]:.1f})  "
                  f"R2 {e['r_squared']:7.4f}")

    print("\nWFAgg holds accuracy where the mean collapses — the paper's "
          "central claim (Table I, IPM-100 row).")
    print("(Each WFAgg gossip round above ran as ONE kernel launch: the "
          "default backend fuses the filter statistics, the trust-weight "
          "derivation and the WFAgg-E combine into a single-launch "
          "Pallas kernel — ~1 candidate pass per round; see "
          "src/repro/kernels/README.md.  That single-launch claim, and "
          "every other structural invariant of the round, is pinned by "
          "the computation linter: PYTHONPATH=src python -m "
          "repro.analysis — docs/STATIC_ANALYSIS.md.)")

    # Dynamic topology in 5 lines: the same experiment under node churn —
    # the graph (and each node's neighbor slate) changes EVERY round,
    # through one compile of the gather-free round function.
    schedule = make_schedule("churn", topo, rounds=6, p_leave=0.2)
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    out = run_dynamic_experiment(cfg, topo, data, schedule)
    print("\n=== aggregator: wfagg  (attack: IPM-100, scenario: churn) ===")
    for e in out["trace"]:
        print(f"  round {e['round']:2d}  benign acc "
              f"{100 * e['acc_benign_mean']:6.2f}%  "
              f"R2 {e['r_squared']:7.4f}")

    # Adaptive adversary in 3 lines: attack="min_max" scales its
    # deviation to sit just inside the distance-filter acceptance radii
    # it observes (DefenseView) — it walks straight through Multi-Krum,
    # while WFAgg's 2-of-3 filter vote still contains it.
    print("\n=== adaptive attack: min_max (defense-aware) ===")
    for agg in ("multi_krum", "wfagg"):
        cfg = DFLConfig(aggregator=agg, attack="min_max", model="mlp")
        out = run_experiment(cfg, topo, data, rounds=6, eval_every=6)
        print(f"  {agg:11s} final benign acc "
              f"{100 * out['final']['acc_benign_mean']:6.2f}%")
    print("(The full attack x scenario x aggregator grid: "
          "PYTHONPATH=src python -m benchmarks.robustness_matrix.\n"
          " To see WHICH filter caught the attack — per-round per-filter "
          "true-catch/false-positive audit, JSONL event log, Perfetto "
          "trace — run the flight recorder: PYTHONPATH=src python -m "
          "repro.obs.report — docs/OBSERVABILITY.md.)")


if __name__ == "__main__":
    main()
