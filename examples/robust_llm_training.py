"""End-to-end driver: Byzantine-robust data-parallel LLM training (mode B).

Forces 8 host devices so the candidate axis is real, then trains a small
qwen-family decoder with WFAgg replacing the gradient-mean all-reduce,
with 2 of the 8 data-parallel workers running the IPM attack on their
gradients.  Compare the loss trace against --agg mean to watch the
non-robust baseline diverge.

    PYTHONPATH=src python examples/robust_llm_training.py                # robust
    PYTHONPATH=src python examples/robust_llm_training.py --agg mean    # collapses
    PYTHONPATH=src python examples/robust_llm_training.py --steps 300 --d-model 512

(~2M-param default so a few hundred steps complete on the CPU container;
on a TPU pod use repro.launch.train with --production-mesh and a full
--arch instead.)
"""
import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agg", default="wfagg")
    ap.add_argument("--attack", default="ipm_100")
    ap.add_argument("--n-malicious", type=int, default=2)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    from repro.launch import train as T

    T.main([
        "--arch", "qwen1.5-0.5b", "--reduced",
        "--d-model", str(args.d_model),
        "--n-layers", str(args.n_layers),
        "--vocab", str(args.vocab),
        "--mode", "robust_dp",
        "--agg", args.agg,
        "--f", "2",
        "--attack", args.attack,
        "--n-malicious", str(args.n_malicious),
        "--steps", str(args.steps),
        "--seq-len", str(args.seq_len),
        "--global-batch", "8",
        "--chunk-size", "65536",
        "--sketch-dim", "512",
        "--log-every", "10",
        "--lr", "1e-3",
    ])


if __name__ == "__main__":
    main()
