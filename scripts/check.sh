#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke: run before merging.
#
#   ./scripts/check.sh                 tier-1 tests + smoke-size microbench
#   FAST=1 ./scripts/check.sh          skip the slow end-to-end trainer tests
#   DYNAMICS_SMOKE=1 ./scripts/check.sh
#                                      dynamics-only smoke: one short
#                                      --scenario churn experiment through
#                                      the scenario engine (the CI
#                                      dynamics job), skipping the full
#                                      pytest + microbench gate
#   OBS_SMOKE=1 ./scripts/check.sh     flight-recorder smoke: a small
#                                      telemetry-on experiment through
#                                      python -m repro.obs.report, with
#                                      the JSONL event log validated
#                                      against the schema and the
#                                      Perfetto trace written (the CI
#                                      obs job uploads both as
#                                      artifacts; OBS_EVENTS/OBS_TRACE
#                                      override the output paths)
#   CHAOS_SMOKE=1 ./scripts/check.sh   chaos-transport smoke: the fault
#                                      x intensity degradation smoke
#                                      grid (drop/chaos x none/alie x
#                                      mean/wfagg) through
#                                      benchmarks.chaos_matrix, with
#                                      the degradation-curve JSON
#                                      written for the CI chaos-smoke
#                                      job to upload (CHAOS_JSON
#                                      overrides the output path)
#   LINT_SPMD=1 ./scripts/check.sh     SPMD communication-contract gate:
#                                      lint the three sharded entries on
#                                      8 virtual CPU devices (the CI
#                                      lint-spmd job; LINT_JSON=<path>
#                                      writes the report it uploads),
#                                      then run the 8-device parity +
#                                      fire checks, skipping the full
#                                      pytest + microbench gate
#
# The microbench invocation exercises the Pallas kernel paths (fused
# robust_stats incl. the batched, +prev and schedule-swap variants) at a
# smoke size so the bench path itself cannot rot silently.  Smoke rows
# are NOT appended to the committed benchmarks/BENCH_agg.json baseline —
# real trajectory entries come from `python -m benchmarks.run`.  Set
# BENCH_JSON=<path> to append this run's rows somewhere (CI appends to
# its workspace copy of BENCH_agg.json so the uploaded artifact carries
# the run's own numbers, not just the committed baseline).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${DYNAMICS_SMOKE:-0}" == "1" ]]; then
  python examples/dfl_paper_experiment.py --scenario churn --rounds 3 \
    --model mlp --aggregator wfagg --attack ipm_100
  echo "check.sh: dynamics smoke OK"
  exit 0
fi

if [[ "${OBS_SMOKE:-0}" == "1" ]]; then
  OBS_EVENTS="${OBS_EVENTS:-obs_events.jsonl}"
  OBS_TRACE="${OBS_TRACE:-obs_trace.json}"
  python -m repro.obs.report --nodes 10 --degree 4 --rounds 4 --n-test 64 \
    --out-events "$OBS_EVENTS" --out-trace "$OBS_TRACE"
  # re-read the files the run wrote: the JSONL must round-trip through
  # the schema validator and the trace must be well-formed trace_event
  # JSON (what ui.perfetto.dev parses)
  python - "$OBS_EVENTS" "$OBS_TRACE" <<'PY'
import json, sys
from repro.obs import recorder
events = recorder.read_events(sys.argv[1])
recorder.validate_events(events, strict=True)
trace = json.load(open(sys.argv[2]))
assert isinstance(trace.get("traceEvents"), list) and trace["traceEvents"], \
    "empty traceEvents"
for ev in trace["traceEvents"]:
    assert ev["ph"] in ("X", "C", "M") and "pid" in ev, ev
print(f"obs smoke: {len(events)} events, "
      f"{len(trace['traceEvents'])} trace events — schema OK")
PY
  echo "check.sh: obs smoke OK"
  exit 0
fi

if [[ "${CHAOS_SMOKE:-0}" == "1" ]]; then
  CHAOS_JSON="${CHAOS_JSON:-chaos_matrix.json}"
  python -m benchmarks.chaos_matrix --smoke --out "$CHAOS_JSON"
  # the chaos lint entry: fault-injected dynamic scan must still be one
  # launch with no in-scan host transfer (the stacked-ring delivery
  # trick's whole point)
  python -m repro.analysis --entry chaos_scan
  echo "check.sh: chaos smoke OK"
  exit 0
fi

if [[ "${LINT_SPMD:-0}" == "1" ]]; then
  # the device-count flag must be in the environment BEFORE jax imports
  export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8"
  python -m repro.analysis \
    --entry sharded_one_launch_round \
    --entry sharded_dynamic_scan \
    --entry sharded_stacked_mode_b \
    ${LINT_JSON:+--json "$LINT_JSON"}
  for mode in round scan stacked engine gather_fire; do
    python tests/_spmd_parity_main.py "$mode"
  done
  echo "check.sh: spmd lint OK"
  exit 0
fi

if [[ "${FAST:-0}" == "1" ]]; then
  python -m pytest -x -q -m "not slow"
else
  python -m pytest -x -q
fi

python benchmarks/agg_microbench.py --kernels --sizes 8x4096 \
  --bench-json "${BENCH_JSON:-}"

# memory_passes() for the shipped configs must not exceed the traffic
# table documented in src/repro/kernels/README.md (single-launch = ~1).
python scripts/passes_gate.py

# Computation linter: one static-analysis pass over the jaxprs, optimized
# HLO and Pallas block specs of every registered entry point (rule
# catalog in docs/STATIC_ANALYSIS.md).  The self-test doctors a fixture
# per rule so a rule that stops firing fails here, then the real lint
# must come back clean.  LINT=0 skips both (kernel-only iterations);
# LINT_JSON=<path> writes the machine-readable report (CI uploads it).
if [[ "${LINT:-1}" == "1" ]]; then
  python -m repro.analysis --self-test
  python -m repro.analysis ${LINT_JSON:+--json "$LINT_JSON"}
fi

# Robustness-matrix regression gate: re-runs the committed gate subgrid
# (benchmarks/BENCH_robustness.json) and fails when any attack x
# scenario x aggregator cell degrades beyond tolerance.  The comparator
# self-test is instant; the grid re-run takes a few minutes — skip it
# with ROBUSTNESS_GATE=0 (e.g. for kernel-only iterations).
python scripts/robustness_gate.py --self-test
if [[ "${ROBUSTNESS_GATE:-1}" == "1" ]]; then
  python scripts/robustness_gate.py
fi

echo "check.sh: OK"
