#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke: run before merging.
#
#   ./scripts/check.sh          tier-1 tests + smoke-size microbench
#   FAST=1 ./scripts/check.sh   skip the slow end-to-end trainer tests
#
# The microbench invocation exercises the Pallas kernel paths (fused
# robust_stats incl. the batched and +prev variants) at a smoke size so
# the bench path itself cannot rot silently.  Smoke rows are NOT
# appended to the committed benchmarks/BENCH_agg.json baseline — real
# trajectory entries come from `python -m benchmarks.run`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${FAST:-0}" == "1" ]]; then
  python -m pytest -x -q -m "not slow"
else
  python -m pytest -x -q
fi

python benchmarks/agg_microbench.py --kernels --sizes 8x4096
echo "check.sh: OK"
