"""Memory-passes regression gate.

``core.wfagg.memory_passes`` is the executable form of the traffic table
in src/repro/kernels/README.md; this gate pins the shipped configs to
the documented ceilings so a refactor cannot silently regress the
candidate-pass count (e.g. the single-launch round falling back to two
launches, or the indexed path regrowing a separate Gram pass).

Run via ``scripts/check.sh`` (and as its own CI step):

    PYTHONPATH=src python scripts/passes_gate.py
"""
from repro.core.wfagg import WFAggConfig, alt_wfagg_config, memory_passes

# (description, cfg, memory_passes kwargs, documented ceiling)
CHECKS = [
    ("single-launch indexed gossip round (the default)",
     WFAggConfig(), dict(include_gather=True, indexed=True), 1),
    ("single-launch indexed Alt-WFAgg (Gram folded into the stats phase)",
     alt_wfagg_config(), dict(include_gather=True, indexed=True), 1),
    ("two-launch indexed fallback",
     WFAggConfig(backend="fused_two_launch"),
     dict(include_gather=True, indexed=True), 2),
    ("fused single-node aggregation (stats + combine)",
     WFAggConfig(), {}, 2),
    ("fused single-node Alt-WFAgg (one extra Gram pass)",
     alt_wfagg_config(), {}, 3),
    ("fused gathered gossip round (gather + stats + combine)",
     WFAggConfig(), dict(include_gather=True), 3),
]


def main() -> None:
    failed = []
    for desc, cfg, kwargs, ceiling in CHECKS:
        got = memory_passes(cfg, **kwargs)
        status = "ok" if got <= ceiling else "REGRESSION"
        print(f"  {desc}: {got} (ceiling {ceiling}) {status}")
        if got > ceiling:
            failed.append(desc)
    if failed:
        raise SystemExit(
            f"memory_passes regression vs the documented table: {failed}")
    print("passes_gate: OK")


if __name__ == "__main__":
    main()
