"""Memory-passes regression gate — a shim over ``repro.analysis``.

``core.wfagg.memory_passes`` is the executable form of the traffic table
in src/repro/kernels/README.md.  The table itself now lives on the lint
entry points (``repro.analysis.entry_points``) as ``passes`` rows, and
the check is the registered ``memory-passes`` rule — this script just
collects every row from the registry and runs that one rule, keeping
the historical CLI (printed table + non-zero exit on regression) for
``scripts/check.sh`` and the standalone CI step:

    PYTHONPATH=src python scripts/passes_gate.py

The full linter (``python -m repro.analysis``) runs the same rule per
entry alongside the compiled-artifact rules.
"""
from repro.analysis import RULES_BY_ID
from repro.analysis.entry_points import entry_points


def main() -> None:
    rule = RULES_BY_ID["memory-passes"]
    findings = []
    for ep in entry_points().values():
        if ep.passes:
            # artifacts unused by this config-layer rule: nothing is built
            findings.extend(rule.run(None, ep))
    failed = []
    for f in findings:
        d = f.detail
        status = "ok" if f.severity == "info" else "REGRESSION"
        print(f"  [{f.entry}] {d['desc']}: {d['got']} "
              f"(ceiling {d['ceiling']}) {status}")
        if f.severity == "error":
            failed.append(d["desc"])
    if not findings:
        raise SystemExit("passes_gate: no entry registers a passes row")
    if failed:
        raise SystemExit(
            f"memory_passes regression vs the documented table: {failed}")
    print("passes_gate: OK")


if __name__ == "__main__":
    main()
