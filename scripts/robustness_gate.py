"""Robustness-matrix regression gate.

``benchmarks/BENCH_robustness.json`` pins the attack x scenario x
aggregator gate subgrid (``benchmarks.robustness_matrix.GATE_GRID``).
This gate re-runs EXACTLY that grid (the meta block of the committed
JSON carries every knob) and fails when any cell's final benign
accuracy or consistency R^2 degrades beyond tolerance — plus two
structural claims of the adaptive-adversary evaluation that must hold
on the FRESH numbers, not just relative to the baseline:

  * each adaptive attack (``core.attacks.ADAPTIVE_ATTACKS``) still
    measurably degrades at least one baseline aggregator on some
    scenario (if it stops biting, the attack regressed — the grid would
    silently measure nothing), and
  * WFAgg stays within tolerance of its own attack-free cell on the
    static scenario under EVERY attack in the grid (the robustness
    claim itself).

When the baseline carries a ``"chaos"`` block (the fault-injection
subgrid of ``benchmarks.chaos_matrix.CHAOS_GATE``), the gate also
re-runs it and enforces the graceful-degradation claims of
docs/FAULTS.md on the fresh numbers: WFAgg under a 0.3 drop rate and
under a 0.3 corrupt rate (no attack) stays within ``CHAOS_WFAGG_TOL``
of its fault-free anchor, while plain mean loses at least
``CHAOS_MEAN_DEGRADE_MIN`` under the same transport — both sides, so a
fault injection that silently stops biting fails the gate just like a
defense that collapses.

Run via ``scripts/check.sh`` (and as its own CI step):

    PYTHONPATH=src python scripts/robustness_gate.py
    PYTHONPATH=src python scripts/robustness_gate.py --self-test

``--self-test`` proves the comparator can fail: it replays the
committed baseline as the "fresh" run but swaps the ``ipm_100`` WFAgg
cell for the ``ipm_100`` mean cell (mean collapses under IPM; WFAgg
must not) and asserts the gate rejects it.  No experiments run.

Regenerate the baseline after an intentional change:

    PYTHONPATH=src python -m benchmarks.robustness_matrix --gate-grid \
        --out benchmarks/BENCH_robustness.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir)
# `python scripts/robustness_gate.py` puts scripts/ on sys.path, not the
# repo root that holds the benchmarks package, nor src/ that holds repro
sys.path.insert(0, _REPO_ROOT)
sys.path.insert(1, os.path.join(_REPO_ROOT, "src"))

from repro.core.attacks import ADAPTIVE_ATTACKS

BASELINE = os.path.join(_REPO_ROOT, "benchmarks", "BENCH_robustness.json")

# Per-cell regression tolerances vs the committed baseline.  The grid is
# seeded and single-threaded deterministic in practice, but compiler
# updates and accelerator nondeterminism wiggle low-round accuracies by
# a few points — the tolerances absorb that while still catching a
# collapsed cell (attack regressions move accuracy by 10-20+ points,
# see the baseline's mean-under-IPM cells).
TOL_ACC = 0.06
TOL_R2 = 0.15
# An adaptive attack "measurably degrades" a baseline aggregator when it
# costs at least this much final accuracy vs that aggregator's
# attack-free cell on the same scenario.
DEGRADE_MIN = 0.08
# WFAgg's static-scenario accuracy under every attack must stay within
# this of its own attack-free static cell.
WFAGG_STATIC_TOL = 0.06
# Chaos-transport graceful-degradation claims (the "chaos" block of the
# baseline, docs/FAULTS.md).  WFAgg under a 0.3 drop rate / 0.3 corrupt
# rate with no attack must stay within this of its own fault-free
# anchor...
CHAOS_WFAGG_TOL = 0.06
# ...while plain mean must measurably degrade under the same transport:
# at least this much final accuracy lost vs ITS fault-free anchor
# (measured on the committed grid: drop costs mean ~0.12, corrupt ~0.46
# — the threshold sits well under both, and far above seed wiggle).
CHAOS_MEAN_DEGRADE_MIN = 0.08
# The fault kinds the structural chaos claims quantify over.
CHAOS_CLAIM_FAULTS = ("drop", "corrupt")

_BASELINE_AGGS = ("mean", "median", "trimmed_mean", "krum", "multi_krum",
                  "clustering")


def _key(attack, scenario, aggregator):
    return f"{attack}|{scenario}|{aggregator}"


def compare(baseline: dict, fresh_cells: dict) -> list:
    """All gate failures (empty = green) of ``fresh_cells`` against the
    committed ``baseline`` dict."""
    meta = baseline["meta"]
    failures = []
    for key, base in baseline["cells"].items():
        cell = fresh_cells.get(key)
        if cell is None:
            failures.append(f"missing cell {key}")
            continue
        if cell["final_acc"] < base["final_acc"] - TOL_ACC:
            failures.append(
                f"{key}: final_acc {cell['final_acc']:.4f} < baseline "
                f"{base['final_acc']:.4f} - {TOL_ACC}")
        if cell["final_r2"] < base["final_r2"] - TOL_R2:
            failures.append(
                f"{key}: final_r2 {cell['final_r2']:.4f} < baseline "
                f"{base['final_r2']:.4f} - {TOL_R2}")

    # structural claim 1: every adaptive attack in the grid still bites
    # some baseline aggregator somewhere
    for attack in meta["attacks"]:
        if attack not in ADAPTIVE_ATTACKS:
            continue
        bites = []
        for scenario in meta["scenarios"]:
            for agg in meta["aggregators"]:
                if agg not in _BASELINE_AGGS:
                    continue
                clean = fresh_cells.get(_key("none", scenario, agg))
                hit = fresh_cells.get(_key(attack, scenario, agg))
                if clean and hit and (
                        hit["final_acc"]
                        < clean["final_acc"] - DEGRADE_MIN):
                    bites.append((scenario, agg))
        if not bites:
            failures.append(
                f"adaptive attack {attack!r} no longer degrades any "
                f"baseline aggregator by > {DEGRADE_MIN} — the attack "
                "(or the grid) regressed to a no-op")

    # structural claim 2: WFAgg holds on the static scenario under every
    # attack in the grid
    if "wfagg" in meta["aggregators"] and "static" in meta["scenarios"]:
        clean = fresh_cells[_key("none", "static", "wfagg")]
        for attack in meta["attacks"]:
            cell = fresh_cells[_key(attack, "static", "wfagg")]
            if cell["final_acc"] < clean["final_acc"] - WFAGG_STATIC_TOL:
                failures.append(
                    f"wfagg static under {attack!r}: final_acc "
                    f"{cell['final_acc']:.4f} more than {WFAGG_STATIC_TOL} "
                    f"below its attack-free {clean['final_acc']:.4f} — the "
                    "robustness claim broke")
    return failures


def compare_chaos(baseline_chaos: dict, fresh_cells: dict) -> list:
    """Gate failures of the chaos (fault-injection) subgrid: per-cell
    regression vs the committed ``"chaos"`` block, plus the structural
    graceful-degradation claims on the FRESH numbers."""
    from benchmarks.chaos_matrix import base_key, cell_key

    meta = baseline_chaos["meta"]
    failures = []
    for key, base in baseline_chaos["cells"].items():
        cell = fresh_cells.get(key)
        if cell is None:
            failures.append(f"missing chaos cell {key}")
            continue
        if cell["final_acc"] < base["final_acc"] - TOL_ACC:
            failures.append(
                f"chaos {key}: final_acc {cell['final_acc']:.4f} < baseline "
                f"{base['final_acc']:.4f} - {TOL_ACC}")
        if cell["final_r2"] < base["final_r2"] - TOL_R2:
            failures.append(
                f"chaos {key}: final_r2 {cell['final_r2']:.4f} < baseline "
                f"{base['final_r2']:.4f} - {TOL_R2}")

    # structural claim: under each claimed fault kind at 0.3 intensity
    # with no attack, wfagg holds its fault-free anchor while mean
    # measurably degrades from its own — graceful degradation is a
    # RELATIVE property, so both sides are enforced on fresh numbers
    intensity = max(float(i) for i in meta["intensities"])
    for fault in CHAOS_CLAIM_FAULTS:
        if fault not in meta["faults"]:
            continue
        wf_clean = fresh_cells.get(base_key("none", "wfagg"))
        wf_hit = fresh_cells.get(cell_key(fault, intensity, "none", "wfagg"))
        if wf_clean and wf_hit and (
                wf_hit["final_acc"]
                < wf_clean["final_acc"] - CHAOS_WFAGG_TOL):
            failures.append(
                f"wfagg under {fault}@{intensity:g} (no attack): final_acc "
                f"{wf_hit['final_acc']:.4f} more than {CHAOS_WFAGG_TOL} "
                f"below its fault-free {wf_clean['final_acc']:.4f} — the "
                "graceful-degradation claim broke")
        mn_clean = fresh_cells.get(base_key("none", "mean"))
        mn_hit = fresh_cells.get(cell_key(fault, intensity, "none", "mean"))
        if mn_clean and mn_hit and (
                mn_hit["final_acc"]
                > mn_clean["final_acc"] - CHAOS_MEAN_DEGRADE_MIN):
            failures.append(
                f"mean under {fault}@{intensity:g} (no attack): final_acc "
                f"{mn_hit['final_acc']:.4f} within {CHAOS_MEAN_DEGRADE_MIN} "
                f"of its fault-free {mn_clean['final_acc']:.4f} — the fault "
                "injection stopped biting the unprotected baseline (the "
                "claim would measure nothing)")
    return failures


def self_test(baseline: dict) -> None:
    """Prove the comparator fails when mean is substituted for WFAgg
    under ipm_100 (mean collapses under IPM; the doctored 'fresh' run
    must be rejected on both the per-cell and the structural check)."""
    doctored = dict(baseline["cells"])
    swapped = 0
    for scenario in baseline["meta"]["scenarios"]:
        src = _key("ipm_100", scenario, "mean")
        dst = _key("ipm_100", scenario, "wfagg")
        if src in doctored and dst in doctored:
            doctored[dst] = doctored[src]
            swapped += 1
    if not swapped:
        raise SystemExit("self-test could not doctor the baseline: no "
                         "ipm_100 mean/wfagg cell pair in the grid")
    failures = compare(baseline, doctored)
    if not failures:
        raise SystemExit(
            "self-test FAILED: the gate accepted mean's ipm_100 cells "
            "passed off as wfagg — the comparator cannot detect a "
            "robustness regression")
    print(f"self-test: doctored run rejected with {len(failures)} "
          "failure(s), e.g.:")
    print(f"  {failures[0]}")
    # the clean baseline must pass against itself, or the gate is noise
    residual = compare(baseline, baseline["cells"])
    if residual:
        raise SystemExit("self-test FAILED: the committed baseline does "
                         f"not pass against itself: {residual}")
    print("self-test: baseline passes against itself")

    chaos = baseline.get("chaos")
    if chaos:
        from benchmarks.chaos_matrix import base_key, cell_key
        # doctor the chaos block both ways: pretend wfagg collapsed under
        # drops (swap in mean's dropped cell) AND pretend mean stopped
        # degrading (swap in its own fault-free anchor) — the comparator
        # must reject each side of the graceful-degradation claim
        intensity = max(float(i) for i in chaos["meta"]["intensities"])
        doctored = dict(chaos["cells"])
        doctored[cell_key("drop", intensity, "none", "wfagg")] = \
            doctored[cell_key("drop", intensity, "none", "mean")]
        doctored[cell_key("drop", intensity, "none", "mean")] = \
            doctored[base_key("none", "mean")]
        chaos_failures = compare_chaos(chaos, doctored)
        if len(chaos_failures) < 2:
            raise SystemExit(
                "self-test FAILED: the chaos comparator accepted a wfagg "
                "collapse and/or a no-op fault injection: "
                f"{chaos_failures}")
        print(f"self-test: doctored chaos block rejected with "
              f"{len(chaos_failures)} failure(s), e.g.:")
        print(f"  {chaos_failures[0]}")
        residual = compare_chaos(chaos, chaos["cells"])
        if residual:
            raise SystemExit("self-test FAILED: the committed chaos block "
                             f"does not pass against itself: {residual}")
        print("self-test: chaos block passes against itself")
    print("robustness_gate self-test: OK")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--self-test", action="store_true",
                    help="verify the comparator rejects a doctored run "
                         "(no experiments)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)

    if args.self_test:
        self_test(baseline)
        return

    from benchmarks.robustness_matrix import run_matrix
    meta = dict(baseline["meta"])
    meta.pop("wall_s", None)
    fresh = run_matrix(meta.pop("attacks"), meta.pop("scenarios"),
                       meta.pop("aggregators"), **meta)
    failures = compare(baseline, fresh["cells"])
    if "chaos" in baseline:
        from benchmarks.chaos_matrix import run_matrix as run_chaos_matrix
        cmeta = dict(baseline["chaos"]["meta"])
        cmeta.pop("wall_s", None)
        fresh_chaos = run_chaos_matrix(
            cmeta.pop("faults"), cmeta.pop("intensities"),
            cmeta.pop("attacks"), cmeta.pop("aggregators"), **cmeta)
        failures += compare_chaos(baseline["chaos"], fresh_chaos["cells"])
    if failures:
        for fail in failures:
            print(f"  REGRESSION {fail}")
        raise SystemExit(
            f"robustness_gate: {len(failures)} regression(s) vs "
            f"{os.path.relpath(args.baseline)}")
    n_cells = len(baseline["cells"]) + len(
        baseline.get("chaos", {}).get("cells", ()))
    print(f"robustness_gate: OK ({n_cells} cells within tolerance, "
          f"structural claims hold)")


if __name__ == "__main__":
    main()
