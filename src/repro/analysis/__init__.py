"""Computation linter: one static-analysis pass over jaxprs, optimized
HLO, and Pallas block specs.

    PYTHONPATH=src python -m repro.analysis            # lint every entry
    PYTHONPATH=src python -m repro.analysis --self-test
    PYTHONPATH=src python -m repro.analysis --configs  # vmem headroom sweep

Rule catalog, severities, suppression syntax and entry-point
registration: docs/STATIC_ANALYSIS.md.
"""
from repro.analysis.artifacts import (
    Artifacts,
    BlockInfo,
    PallasCallInfo,
    collect_pallas_calls,
    count_pallas_calls,
    walk_eqns,
)
from repro.analysis.rules import (
    RULES,
    RULES_BY_ID,
    EntryPoint,
    Finding,
    Rule,
    gate_failures,
    parse_suppressions,
    run_rules,
    scan_gather_model_dim,
    scan_host_transfers_in_while,
    scan_nkd_buffers,
)
from repro.analysis.vmem import config_vmem_report, round_kernel_residency

__all__ = [
    "Artifacts", "BlockInfo", "PallasCallInfo", "collect_pallas_calls",
    "count_pallas_calls", "walk_eqns",
    "RULES", "RULES_BY_ID", "EntryPoint", "Finding", "Rule",
    "gate_failures", "parse_suppressions", "run_rules",
    "scan_gather_model_dim", "scan_host_transfers_in_while",
    "scan_nkd_buffers",
    "config_vmem_report", "round_kernel_residency",
]
