"""``python -m repro.analysis`` — lint the registered entry points.

Exit codes (stable, for CI that gates on the JSON artifact):

  0   no unsuppressed error-severity finding (warnings/info never fail)
  1   gate failure: at least one unsuppressed error finding
  2   usage error (argparse: unknown entry, bad --suppress spec, ...)

``--json`` writes the machine-readable report; its top-level
``schema_version`` bumps whenever the report layout changes shape
(consumers should pin on it instead of sniffing keys).  Sharded entries
whose ``min_devices`` exceeds the visible device count are recorded as
``{"skipped": ...}`` rather than silently dropped — a lint run on a
1-device box still shows WHICH gates did not run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict

# bump when the JSON report layout changes shape
SCHEMA_VERSION = 2


def _lint_entry(entry, suppressions, with_cost: bool) -> Dict[str, Any]:
    from repro.analysis.artifacts import Artifacts
    from repro.analysis.rules import run_rules
    from repro.launch import hlo_analysis as ha

    fn, args = entry.build()
    artifacts = Artifacts(fn, args)
    findings = run_rules(artifacts, entry, suppressions)
    rec: Dict[str, Any] = {
        "description": entry.description,
        "expected_launches": entry.expected_launches,
        "nkd": list(entry.nkd),
        "suppress": sorted(entry.suppress),
        "findings": [f.to_json() for f in findings],
        "pallas": [{
            "kernel": p.name, "grid": list(p.grid),
            "block_bytes": p.block_bytes, "scratch_bytes": p.scratch_bytes,
            "vmem_bytes": p.vmem_bytes(),
        } for p in artifacts.pallas_calls],
    }
    if entry.contract is not None:
        rec["contract"] = entry.contract.to_dict()
    if with_cost:
        # the absorbed launch/hlo_analysis signals: roofline terms,
        # top-traffic instructions, trip counts, dead computations —
        # sharded entries price collectives at their contract's axis size
        n_dev = entry.contract.axis_size if entry.contract else 1
        cost = ha.analyze(artifacts.hlo, n_devices=n_dev)
        rec["cost"] = {
            "flops": cost.flops, "bytes": cost.bytes,
            "wire_bytes": cost.wire_bytes, "n_while": cost.n_while,
            "unknown_trip_whiles": cost.unknown_trip_whiles,
            "trip_counts": cost.trip_counts,
            "top_bytes": [[b, s] for b, s in (cost.top_bytes or [])[:5]],
            "top_wire": [[w, s] for w, s in (cost.top_wire or [])[:5]],
            "dead_computations": cost.dead_computations or [],
            "num_partitions": cost.num_partitions,
            "collectives": [r.to_dict() for r in (cost.collectives or [])],
        }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="rule-based static analysis over the repo's compiled "
                    "artifacts (docs/STATIC_ANALYSIS.md)")
    ap.add_argument("--entry", action="append", default=None,
                    help="lint only this entry (repeatable; default all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered entries and rules, then exit")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--suppress", action="append", default=[],
                    metavar="RULE[@ENTRY]",
                    help="suppress a rule everywhere or for one entry "
                         "(repeatable)")
    ap.add_argument("--vmem-ceiling", type=int, default=None,
                    help="override the per-grid-step VMEM ceiling in bytes "
                         "(default 16 MiB)")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the hlo_analysis roofline block in the report")
    ap.add_argument("--configs", action="store_true",
                    help="also sweep the configs/ model-shape registry and "
                         "record per-config vmem-budget headroom")
    ap.add_argument("--self-test", action="store_true",
                    help="run the doctored-fixture self-tests (every rule "
                         "must fire) and exit")
    args = ap.parse_args(argv)

    if args.self_test:
        from repro.analysis.selftest import main as selftest_main
        selftest_main()
        return 0

    from repro.analysis.entry_points import entry_points
    from repro.analysis.rules import RULES, parse_suppressions

    entries = entry_points()
    if args.list:
        print("entries:")
        for name, e in entries.items():
            print(f"  {name}: {e.description}")
        print("rules:")
        for r in RULES:
            print(f"  {r.id} [{r.severity}, {r.layer}]: {r.description}")
        return 0

    if args.entry:
        unknown = [n for n in args.entry if n not in entries]
        if unknown:
            ap.error(f"unknown entries {unknown}; known: {sorted(entries)}")
        entries = {n: entries[n] for n in args.entry}
    try:
        suppressions = parse_suppressions(args.suppress)
    except ValueError as e:
        ap.error(str(e))

    import jax

    n_devices = len(jax.devices())
    report: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "meta": {
            "backend": jax.default_backend(),
            "n_devices": n_devices,
            "rules": [{"id": r.id, "severity": r.severity, "layer": r.layer}
                      for r in RULES],
            "suppress": list(args.suppress),
        },
        "entries": {},
    }
    all_findings = []
    for name, entry in entries.items():
        if entry.min_devices > n_devices:
            msg = (f"needs {entry.min_devices} devices, {n_devices} visible "
                   "— run under XLA_FLAGS=--xla_force_host_platform_"
                   f"device_count={entry.min_devices} (scripts/check.sh "
                   "LINT_SPMD=1)")
            print(f"skipping {name}: {msg}", flush=True)
            report["entries"][name] = {
                "description": entry.description, "skipped": msg}
            continue
        if args.vmem_ceiling is not None:
            entry = dataclasses.replace(entry, vmem_ceiling=args.vmem_ceiling)
        print(f"linting {name} ...", flush=True)
        rec = _lint_entry(entry, suppressions, with_cost=not args.no_cost)
        report["entries"][name] = rec
        for f in rec["findings"]:
            all_findings.append(f)
            tag = " (suppressed)" if f["suppressed"] else ""
            if f["severity"] != "info" or f["suppressed"]:
                print(f"  {f['severity'].upper()} {f['rule']}{tag}: "
                      f"{f['message']}")
            else:
                print(f"  info {f['rule']}: {f['message']}")

    if args.configs:
        from repro.analysis.vmem import DEFAULT_VMEM_CEILING, config_vmem_report
        ceiling = args.vmem_ceiling or DEFAULT_VMEM_CEILING
        print("sweeping configs/ registry (vmem-budget headroom) ...",
              flush=True)
        report["configs"] = config_vmem_report(ceiling=ceiling)
        for rec in report["configs"]:
            status = "ok" if rec["ok"] else "OVER BUDGET"
            print(f"  {rec['arch']}: d={rec['d']:,} grid={rec['grid']} "
                  f"vmem={rec['vmem_bytes'] / 2**20:.2f} MiB headroom="
                  f"{100 * rec['headroom_frac']:.0f}% {status}")
        if any(not rec["ok"] for rec in report["configs"]):
            all_findings.append({
                "rule": "vmem-budget", "severity": "error",
                "entry": "configs", "suppressed": False,
                "message": "a registry config exceeds the VMEM ceiling",
                "detail": {}})

    failures = [f for f in all_findings
                if f["severity"] == "error" and not f["suppressed"]]
    report["summary"] = {
        "n_findings": len(all_findings),
        "n_errors": len(failures),
        "n_suppressed": sum(1 for f in all_findings if f["suppressed"]),
        "ok": not failures,
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report -> {args.json}")
    if failures:
        print(f"repro.analysis: {len(failures)} unsuppressed error(s)")
        return 1
    print(f"repro.analysis: OK ({len(report['entries'])} entries, "
          f"{len(all_findings)} findings, "
          f"{report['summary']['n_suppressed']} suppressed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
