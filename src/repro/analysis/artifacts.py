"""Compiled-artifact extraction for the computation linter.

One entry point, three inspection layers:

  * **jaxpr** — ``jax.make_jaxpr`` on the jitted callable; rules walk the
    closed jaxpr recursively (through scan/cond/pjit/pallas sub-jaxprs)
    to count launches and catch dtype downcasts before XLA touches them;
  * **HLO** — the optimized module text from ``.lower().compile()``;
    rules grep structure (buffer shapes, gathers, host transfers) and
    feed ``launch.hlo_analysis`` for trip-count-aware cost signals;
  * **Pallas** — grid / BlockSpec / scratch metadata pulled out of every
    ``pallas_call`` equation's ``GridMapping``, so the VMEM-budget rule
    prices each grid step without re-deriving the launch geometry.

Artifacts are built lazily and cached: a rule that only needs the jaxpr
never pays for a compile.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from jax.core import ClosedJaxpr, Jaxpr


def iter_subjaxprs(jaxpr: Jaxpr) -> Iterator[Tuple[Any, Jaxpr]]:
    """Yield ``(eqn, sub_jaxpr)`` for every sub-jaxpr reachable from
    ``jaxpr``'s equations (scan bodies, cond branches, pjit calls,
    pallas kernel bodies, custom-vjp residuals, ...)."""
    def unwrap(val):
        if isinstance(val, ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, Jaxpr):
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from unwrap(v)

    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            for sub in unwrap(val):
                yield eqn, sub


def walk_eqns(jaxpr: Jaxpr) -> Iterator[Any]:
    """Every equation in ``jaxpr`` and all its sub-jaxprs, depth-first."""
    for eqn in jaxpr.eqns:
        yield eqn
    for _, sub in iter_subjaxprs(jaxpr):
        yield from walk_eqns(sub)


def count_pallas_calls(jaxpr: Jaxpr) -> int:
    """Recursively count ``pallas_call`` eqns through all sub-jaxprs.

    This is the launch counter the one-launch round test pins to 1 (and
    the two-launch fallback to 2) — hoisted here from
    ``tests/test_one_launch.py`` so every entry point shares it."""
    return sum(1 for e in walk_eqns(jaxpr) if e.primitive.name == "pallas_call")


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """One operand's BlockSpec as seen by the compiled launch."""
    origin: str                      # "refs[i]" / "outputs[i]" from pallas
    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]
    dtype: str
    itemsize: int
    index_map_jaxpr: Any             # ClosedJaxpr (grid idx [+ smem refs]) -> block idx

    @property
    def block_bytes(self) -> int:
        return math.prod(self.block_shape) * self.itemsize


@dataclasses.dataclass(frozen=True)
class PallasCallInfo:
    """Grid / BlockSpec / scratch metadata of one ``pallas_call`` eqn."""
    name: str
    grid: Tuple[int, ...]
    blocks: Tuple[BlockInfo, ...]    # inputs then outputs, pallas order
    n_inputs: int
    n_outputs: int
    n_scalar_prefetch: int
    scratch_shapes: Tuple[Tuple[Tuple[int, ...], str, int], ...]  # (shape, dtype, itemsize)

    @property
    def scratch_bytes(self) -> int:
        return sum(math.prod(s) * iz for s, _, iz in self.scratch_shapes)

    @property
    def block_bytes(self) -> int:
        return sum(b.block_bytes for b in self.blocks)

    def vmem_bytes(self, double_buffer: bool = True) -> int:
        """Modelled per-grid-step VMEM residency: every in/out block is
        double-buffered by the pipeline (fetch next while computing
        current), scratch is single-resident."""
        mult = 2 if double_buffer else 1
        return mult * self.block_bytes + self.scratch_bytes


def _block_dims(block_shape) -> Tuple[int, ...]:
    # squeezed dims may appear as None / pallas Mapped sentinels
    return tuple(int(d) if isinstance(d, (int, np.integer)) else 1
                 for d in block_shape)


def collect_pallas_calls(jaxpr: Jaxpr) -> List[PallasCallInfo]:
    """Extract :class:`PallasCallInfo` for every pallas_call equation."""
    infos: List[PallasCallInfo] = []
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        name = getattr(eqn.params.get("name_and_src_info"), "name", "") or \
            "pallas_call"
        blocks = []
        for bm in gm.block_mappings:
            sds = bm.array_shape_dtype
            dt = np.dtype(sds.dtype)
            blocks.append(BlockInfo(
                origin=str(getattr(bm, "origin", "")),
                block_shape=_block_dims(bm.block_shape),
                array_shape=tuple(int(d) for d in sds.shape),
                dtype=dt.name,
                itemsize=dt.itemsize,
                index_map_jaxpr=bm.index_map_jaxpr,
            ))
        # scratch avals are the tail invars of the kernel jaxpr
        scratch = []
        n_scratch = int(getattr(gm, "num_scratch_operands", 0))
        if n_scratch:
            inner = eqn.params["jaxpr"]
            for var in inner.invars[-n_scratch:]:
                aval = getattr(var.aval, "inner_aval", var.aval)
                dt = np.dtype(aval.dtype)
                scratch.append((tuple(int(d) for d in aval.shape),
                                dt.name, dt.itemsize))
        infos.append(PallasCallInfo(
            name=name,
            grid=tuple(int(g) for g in gm.grid),
            blocks=tuple(blocks),
            n_inputs=int(gm.num_inputs),
            n_outputs=int(gm.num_outputs),
            n_scalar_prefetch=int(getattr(gm, "num_index_operands", 0)),
            scratch_shapes=tuple(scratch),
        ))
    return infos


class Artifacts:
    """Lazily-built (jaxpr, HLO, Pallas metadata) bundle for one entry
    point.  ``fn`` is the (jitted) callable, ``args`` its example
    arguments (real arrays or ShapeDtypeStructs)."""

    def __init__(self, fn: Callable, args: Sequence[Any],
                 hlo: Optional[str] = None,
                 jaxpr: Optional[ClosedJaxpr] = None):
        self.fn = fn
        self.args = tuple(args)
        self._hlo = hlo
        self._jaxpr = jaxpr
        self._pallas: Optional[List[PallasCallInfo]] = None

    @property
    def jaxpr(self) -> ClosedJaxpr:
        if self._jaxpr is None:
            import jax
            self._jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self._jaxpr

    @property
    def hlo(self) -> str:
        if self._hlo is None:
            self._hlo = self.fn.lower(*self.args).compile().as_text()
        return self._hlo

    @property
    def pallas_calls(self) -> List[PallasCallInfo]:
        if self._pallas is None:
            self._pallas = collect_pallas_calls(self.jaxpr.jaxpr)
        return self._pallas

    @classmethod
    def from_hlo(cls, hlo: str) -> "Artifacts":
        """HLO-only artifacts (doctored fixtures, pre-dumped modules).
        jaxpr-layer rules see an empty program."""
        import jax
        art = cls(fn=None, args=(), hlo=hlo)
        art._jaxpr = jax.make_jaxpr(lambda: 0)()
        return art
