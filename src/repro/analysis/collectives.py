"""Communication contracts for sharded entry points.

A :class:`CommContract` declares what an entry point is ALLOWED to put on
the wire when its model dimension is sharded over the mesh: which
collective kinds may appear in the optimized HLO, how large any single
payload may be, and how many per-device wire bytes the whole module may
move once trip-count multipliers are applied.  The SPMD rule family in
``rules.py`` checks the compiled module against the contract using the
per-collective records of :mod:`repro.launch.hlo_analysis`.

The WFAgg round contract is the repo's bandwidth story in one object:
under a D-sharded mesh the ONLY cross-shard traffic is the psum of the
O(N·K) filter-statistic partials (the coordinate-additive ``RobustStats``
fields — see distributed/spmd.py), so every ceiling here is an O(N·K)
quantity with headroom, independent of d.  A full-d all-gather — what
GSPMD silently inserts when a sharded array meets a replicated consumer —
busts the per-collective ceiling by ~2 orders of magnitude and is the
exact failure mode these contracts exist to catch.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.launch import hlo_analysis as ha

# RobustStats psum payload: 6 (N, K) accumulators (dist2, dotmed, norm2,
# prev_dist2, prev_dot, prev_norm2) + the (N,) mednorm2 row, f32
_STATS_FIELDS = 6


@dataclasses.dataclass(frozen=True)
class CommContract:
    """What may cross shards, and at what size.

    axis_size             devices the model dimension shards over (the
                          module must compile with this num_partitions)
    allowed_kinds         collective opcodes the contract permits
    max_collective_bytes  ceiling on any single collective's payload
    wire_budget_bytes     ceiling on per-device wire bytes for the whole
                          module, trip-count multipliers applied
    """

    axis_size: int
    allowed_kinds: Tuple[str, ...]
    max_collective_bytes: int
    wire_budget_bytes: float
    description: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)


def wfagg_round_contract(n: int, k: int, n_shards: int, rounds: int = 1,
                         need_gram: bool = False,
                         headroom: float = 4.0) -> CommContract:
    """Contract for ``rounds`` sharded WFAgg gossip rounds over N nodes
    of degree K: all-reduce only, each payload O(N·K) (O(N·K²) with the
    Alt-WFAgg Gram riding along), total wire = rounds x the psum of the
    statistic partials.  ``headroom`` absorbs float/layout slack and how
    XLA splits or fuses the per-field psums — NOT a full-d gather, which
    overshoots these ceilings ~100x at MLP size."""
    per_collective = 4 * n * (k * k if need_gram else k)
    per_round = 4 * (_STATS_FIELDS * n * k + n
                     + (n * k * k if need_gram else 0))
    ring = 2 * (n_shards - 1) / max(1, n_shards)   # all-reduce ring factor
    return CommContract(
        axis_size=n_shards,
        allowed_kinds=("all-reduce",),
        max_collective_bytes=int(headroom * per_collective),
        wire_budget_bytes=headroom * rounds * ring * per_round,
        description=(f"{rounds} sharded WFAgg round(s): all-reduce-only, "
                     f"O(N*K) statistic psums across {n_shards} shards"),
    )


def stacked_allreduce_contract(k: int, n_shards: int,
                               headroom: float = 4.0) -> CommContract:
    """Contract for mode-B ``robust_allreduce_stacked`` under the mesh:
    the pure-jnp reference stats reduce each leaf shard locally and meet
    in (K,)/(K,K)/scalar all-reduces — one node's view (n=1), Gram-sized
    ceiling for the pairwise statistics."""
    c = wfagg_round_contract(n=1, k=k, n_shards=n_shards, rounds=1,
                             need_gram=True, headroom=headroom)
    return dataclasses.replace(
        c, description=(f"mode-B stacked allreduce: O(K^2) statistic "
                        f"psums across {n_shards} shards"))


def contract_cost(artifacts, axis_size: int) -> ha.HloCost:
    """hlo_analysis over the entry's HLO at the contract's device count,
    memoized on the Artifacts instance (several rules share it)."""
    cached = getattr(artifacts, "_contract_cost", None)
    if cached is None or cached[0] != axis_size:
        cached = (axis_size, ha.analyze(artifacts.hlo, n_devices=axis_size))
        artifacts._contract_cost = cached
    return cached[1]
