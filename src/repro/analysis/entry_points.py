"""The registered lint targets.

Every computation the repo ships — the fused one-launch round, the
two-launch fallback, the valid-aware reference oracle, the
dynamic-scenario scan, stacked ``robust_allreduce`` mode-B — is
registered here as an :class:`~repro.analysis.rules.EntryPoint` and gets
the FULL rule gate on every ``python -m repro.analysis`` run.  A new
subsystem (shard_map multi-pod round, compressed gossip) inherits the
gate by adding one entry: a ``build()`` returning its jitted callable
plus example args, the pinned launch count, and its (N, K, d) triple.

The builders use the same small shapes as the tier-1 tests (N=10 ring,
K=4 churn slates, the MLP model) so a lint run costs seconds, not the
paper experiment.  ``memory_passes`` table rows (the absorbed
``scripts/passes_gate.py``) are distributed over the entries each row
describes; ``scripts/passes_gate.py`` re-collects them all.
"""
from __future__ import annotations

import functools
from typing import Dict

from repro.analysis import collectives
from repro.analysis.rules import EntryPoint

# the MLP classifier the lint entries train: fc1 (784 x 64 + 64) +
# fc2 (64 x 10 + 10) raveled
MLP_D = 784 * 64 + 64 + 64 * 10 + 10

_N, _DEGREE, _ROUNDS = 10, 4, 3


def _ring_fixture():
    from repro.core.topology import make_topology
    from repro.data.synthetic import SyntheticImages
    from repro.dfl import dynamics as dyn

    topo = make_topology(n_nodes=_N, degree=_DEGREE, n_malicious=2,
                         kind="ring", seed=0)
    data = SyntheticImages()
    sched = dyn.churn_schedule(topo, _ROUNDS, seed=1)
    return topo, data, sched


def _build_dynamic_round(aggregator: str, backend: str):
    """(fn, args) for one jitted dynamic round under ``backend``."""
    import jax.numpy as jnp

    from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

    topo, data, sched = _ring_fixture()
    cfg = DFLConfig(aggregator=aggregator, attack="ipm_100", model="mlp",
                    wfagg_backend=backend)
    fn = build_round_fn(cfg, topo, data, dynamic=True)
    state = init_dfl_state(cfg, topo, degree=sched.width)
    args = (state, jnp.asarray(sched.neighbor_idx[0]),
            jnp.asarray(sched.valid[0]), jnp.asarray(sched.malicious[0]))
    return fn, args


def _build_reference_round():
    """The static round on the ring topology, reference (gathering)
    backend — the parity oracle, linted with its two gather rules
    suppressed (materializing the gossip tensor is its job)."""
    from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

    topo, data, _ = _ring_fixture()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp",
                    wfagg_backend="reference")
    fn = build_round_fn(cfg, topo, data)
    return fn, (init_dfl_state(cfg, topo),)


def _build_dynamic_scan(telemetry: bool = False):
    """The whole-schedule scan ``run_dynamic_experiment`` jits — built by
    the engine's own ``build_dynamic_scan_fn``, so the linted program IS
    the experiment driver's.  With ``telemetry`` it is the flight-
    recorder variant: the scan additionally emits the packed per-round
    verdict bitmask + per-node summaries (``repro.obs``) as pure traced
    outputs — same launch count, and the no-host-transfer-in-scan rule
    must hold over it just like the silent scan."""
    from repro.dfl.engine import DFLConfig, build_dynamic_scan_fn

    topo, data, sched = _ring_fixture()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    state, run, sched_arrays = build_dynamic_scan_fn(cfg, topo, data, sched,
                                                     n_test=64,
                                                     telemetry=telemetry)
    return run, (state,) + tuple(sched_arrays)


def _build_chaos_scan():
    """The fault-injected whole-schedule scan (chaos transport): drop +
    stale + duplicate + corrupt + crash-restart schedules riding as five
    extra scan stacks, the stale-delivery ring and corrupt bank folded
    into ONE stacked 2-D model matrix per round (``repro.dfl.faults``).
    Acceptance gate for docs/FAULTS.md: launch count identical to the
    clean scan (still the single fused round launch), and no host
    transfer enters the scan — the fault path must cost zero extra
    kernel launches and zero recompiles."""
    from repro.dfl import faults as flt
    from repro.dfl.engine import DFLConfig, build_dynamic_scan_fn

    topo, data, sched = _ring_fixture()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    fs = flt.make_fault_schedule("chaos", sched, 0.4, seed=2)
    carry0, run, arrays = build_dynamic_scan_fn(
        cfg, topo, data, sched, n_test=64, telemetry=True, faults=fs)
    return run, (carry0,) + tuple(arrays)


_STACKED_K, _STACKED_D = 6, 24 * 6 + 80

# sharded entries: shard count and the zero-padded model dim (padding d
# to a shard multiple is exact — see kernels.common.pad_d)
_SHARDS = 8
MLP_D_PAD = MLP_D + (-MLP_D) % _SHARDS


def _build_sharded_round():
    """One sharded gossip round: the two-launch decomposition per shard
    (local stats, O(N*K) psum, replicated scoring, local combine), the
    (N, d) state pinned P(None, 'model') at the jit boundary."""
    from repro.core.wfagg import WFAggConfig
    from repro.distributed import spmd

    cfg = WFAggConfig(backend="fused_two_launch", f=1, window=3, transient=1)
    mesh = spmd.aggregation_mesh(_SHARDS)
    return spmd.sharded_round_jit(cfg, mesh, n=_N, k=_DEGREE, d=MLP_D_PAD)


def _build_sharded_scan():
    """The whole dynamic schedule inside ONE shard_map region: lax.scan
    carries the (N, d/S) model shard, so the model matrix never crosses
    the shard_map boundary between rounds."""
    from repro.core.wfagg import WFAggConfig
    from repro.distributed import spmd

    cfg = WFAggConfig(backend="fused_two_launch", f=1, window=3, transient=1)
    mesh = spmd.aggregation_mesh(_SHARDS)
    return spmd.sharded_scan_jit(cfg, mesh, n=_N, k=_DEGREE, d=MLP_D_PAD,
                                 rounds=_ROUNDS)


def _build_sharded_stacked():
    """Mode-B stacked allreduce under the (1, 8) mesh via the pure-jnp
    reference stats (GSPMD-partitionable — no Pallas custom-call for the
    partitioner to replicate): leaves shard their trailing dim over
    'model', statistics meet in O(K)/O(K^2) all-reduces."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import wfagg as wf
    from repro.distributed import spmd
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = _STACKED_K
    g = {"w": jnp.zeros((K, 24, _SHARDS), jnp.float32),
         "b": jnp.zeros((K, 80), jnp.float32)}
    cfg = RobustAggConfig(
        method="wfagg", layout="stacked", backend="reference",
        wfagg=wf.WFAggConfig(f=1, transient=1, window=2))
    state = init_tree_agg_state(cfg, K, jax.tree.map(lambda x: x[0], g))
    mesh = spmd.aggregation_mesh(_SHARDS)
    shardings = {"w": NamedSharding(mesh, P(None, None, "model")),
                 "b": NamedSharding(mesh, P(None, "model"))}
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, P(*s.spec[1:])),
                          shardings)
    st_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                         state)._replace(prev=shardings)
    fn = jax.jit(lambda grads, st: robust_allreduce_stacked(grads, cfg, st),
                 in_shardings=(shardings, st_sh),
                 out_shardings=(out_sh, st_sh, None))
    return fn, (g, state)


def _build_stacked_mode_b():
    import jax
    import jax.numpy as jnp

    from repro.core import wfagg as wf
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = _STACKED_K
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 24, 6)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (K, 80))}
    g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
    cfg = RobustAggConfig(
        method="wfagg", layout="stacked", backend="fused",
        wfagg=wf.WFAggConfig(f=1, transient=1, window=2))
    state = init_tree_agg_state(cfg, K, jax.tree.map(lambda x: x[0], g))
    fn = jax.jit(lambda grads, st: robust_allreduce_stacked(grads, cfg, st))
    return fn, (g, state)


def _compile_once_probe() -> int:
    """Drive 5 churn rounds through 5 DIFFERENT graphs and report the
    trace-cache size — the compile-once claim on live executables (this
    is the one runtime-layer rule: it executes, the rest only trace)."""
    import jax.numpy as jnp

    from repro.dfl import dynamics as dyn
    from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

    topo, data, _ = _ring_fixture()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    sched = dyn.churn_schedule(topo, 5, seed=7, p_leave=0.4)
    fn = build_round_fn(cfg, topo, data, dynamic=True)
    state = init_dfl_state(cfg, topo, degree=sched.width)
    for r in range(sched.rounds):
        state = fn(state, jnp.asarray(sched.neighbor_idx[r]),
                   jnp.asarray(sched.valid[r]),
                   jnp.asarray(sched.malicious[r]))
    return fn._cache_size()


@functools.lru_cache(maxsize=1)
def entry_points() -> Dict[str, EntryPoint]:
    """Name -> EntryPoint, in lint order."""
    from repro.core.wfagg import WFAggConfig, alt_wfagg_config

    _, _, sched = _ring_fixture()
    K = int(sched.width)
    nkd = (_N, K, MLP_D)

    entries = [
        EntryPoint(
            name="one_launch_round",
            description="fused single-launch dynamic WFAgg round "
                        "(backend='fused', the default)",
            build=lambda: _build_dynamic_round("wfagg", "fused"),
            expected_launches=1, nkd=nkd,
            compile_once=_compile_once_probe,
            passes=(("single-launch indexed gossip round (the default)",
                     WFAggConfig(),
                     dict(include_gather=True, indexed=True), 1),),
        ),
        EntryPoint(
            name="one_launch_round_alt",
            description="fused single-launch Alt-WFAgg round (in-kernel "
                        "Gram + Multi-Krum/Clustering)",
            build=lambda: _build_dynamic_round("alt_wfagg", "fused"),
            expected_launches=1, nkd=nkd,
            passes=(("single-launch indexed Alt-WFAgg (Gram folded into "
                     "the stats phase)", alt_wfagg_config(),
                     dict(include_gather=True, indexed=True), 1),),
        ),
        EntryPoint(
            name="two_launch_round",
            description="two-launch indexed fallback "
                        "(backend='fused_two_launch', parity path)",
            build=lambda: _build_dynamic_round("wfagg", "fused_two_launch"),
            expected_launches=2, nkd=nkd,
            passes=(("two-launch indexed fallback",
                     WFAggConfig(backend="fused_two_launch"),
                     dict(include_gather=True, indexed=True), 2),),
        ),
        EntryPoint(
            name="reference_round",
            description="valid-aware pure-jnp reference oracle "
                        "(backend='reference'; gather rules suppressed — "
                        "materializing the gossip tensor is its job)",
            build=_build_reference_round,
            expected_launches=0, nkd=nkd,
            suppress=frozenset({"no-nkd-buffer", "gather-free-model-dim"}),
            passes=(("fused gathered gossip round (gather + stats + "
                     "combine)", WFAggConfig(),
                     dict(include_gather=True), 3),),
        ),
        EntryPoint(
            name="dynamic_scan",
            description="whole-schedule lax.scan (run_dynamic_experiment's "
                        "one jit: rounds + in-scan evaluation)",
            build=_build_dynamic_scan,
            expected_launches=1, nkd=nkd,
        ),
        EntryPoint(
            name="dynamic_scan_telemetry",
            description="the same whole-schedule scan with the flight "
                        "recorder's decision plane on (telemetry=True): "
                        "packed verdict bitmasks as pure traced scan "
                        "outputs — launch count unchanged, no host "
                        "transfer enters the scan (docs/OBSERVABILITY.md)",
            build=lambda: _build_dynamic_scan(telemetry=True),
            expected_launches=1, nkd=nkd,
        ),
        EntryPoint(
            name="chaos_scan",
            description="the fault-injected whole-schedule scan: drop/"
                        "stale/duplicate/corrupt/crash fault stacks + the "
                        "stale-delivery ring as scan carry, telemetry on "
                        "— one compile, launch count unchanged vs the "
                        "clean scan, no in-scan host transfer "
                        "(docs/FAULTS.md)",
            build=_build_chaos_scan,
            expected_launches=1, nkd=nkd,
        ),
        EntryPoint(
            name="stacked_mode_b",
            description="stacked robust_allreduce mode-B (N=1 identity-"
                        "slate instance of the round kernel)",
            build=_build_stacked_mode_b,
            expected_launches=1, nkd=(1, _STACKED_K, _STACKED_D),
            passes=(("fused single-node aggregation (stats + combine)",
                     WFAggConfig(), {}, 2),
                    ("fused single-node Alt-WFAgg (one extra Gram pass)",
                     alt_wfagg_config(), {}, 3)),
        ),
        EntryPoint(
            name="sharded_one_launch_round",
            description="D-sharded gossip round under shard_map over the "
                        "(1, 8) mesh: per-shard stats launch + O(N*K) "
                        "psum + shard-local combine launch "
                        "(distributed/spmd.py; needs 8 devices)",
            build=_build_sharded_round,
            expected_launches=2, nkd=(_N, _DEGREE, MLP_D_PAD),
            contract=collectives.wfagg_round_contract(
                n=_N, k=_DEGREE, n_shards=_SHARDS, rounds=1),
            min_devices=_SHARDS,
            passes=(("sharded round = two-launch shape per shard",
                     WFAggConfig(backend="fused_two_launch"),
                     dict(include_gather=True, indexed=True), 2),),
        ),
        EntryPoint(
            name="sharded_dynamic_scan",
            description="whole dynamic schedule scanned INSIDE the "
                        "shard_map region — the (N, d/S) shard is the "
                        "scan carry, with temporal slot-history "
                        "realignment per round (needs 8 devices)",
            build=_build_sharded_scan,
            expected_launches=2, nkd=(_N, _DEGREE, MLP_D_PAD),
            contract=collectives.wfagg_round_contract(
                n=_N, k=_DEGREE, n_shards=_SHARDS, rounds=_ROUNDS),
            min_devices=_SHARDS,
        ),
        EntryPoint(
            name="sharded_stacked_mode_b",
            description="mode-B stacked allreduce jitted over the (1, 8) "
                        "mesh via the pure-jnp reference stats (GSPMD-"
                        "partitionable; statistics meet in O(K^2) "
                        "all-reduces; needs 8 devices)",
            build=_build_sharded_stacked,
            expected_launches=0, nkd=(1, _STACKED_K, 24 * _SHARDS + 80),
            contract=collectives.stacked_allreduce_contract(
                k=_STACKED_K, n_shards=_SHARDS),
            min_devices=_SHARDS,
        ),
    ]
    return {e.name: e for e in entries}
