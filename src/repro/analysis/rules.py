"""Rule framework + the core structural rules.

Every rule is a small dataclass: an id, a severity, the artifact layer
it inspects (``jaxpr`` / ``hlo`` / ``pallas`` / ``runtime`` / ``config``)
and a check function returning :class:`Finding`\\ s.  Rules encode the
repo's compiled-computation claims — gather-free gossip, no (N, K, d)
materialization, ~1 candidate pass per round, compile-once dynamic
schedules, f32 trust arithmetic, bounded VMEM — as machine-checked
properties instead of ad-hoc HLO greps copy-pasted across test files.

Suppression: an entry point declares ``suppress={rule_id, ...}`` for
properties it intentionally violates (the reference oracle materializes
the gather — that is its job), and the CLI accepts extra
``--suppress rule-id[@entry]`` pins.  Suppressed findings are still
reported (``suppressed: true`` in the JSON) but never fail the gate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.artifacts import Artifacts, count_pallas_calls, walk_eqns

SEVERITIES = ("error", "warning", "info")

# dtypes the f32-trust-invariant refuses for trust/temporal arithmetic
_SUB_F32 = ("bfloat16", "float16", "float8_e4m3fn", "float8_e5m2",
            "float8_e4m3b11fnuz", "float8_e4m3fnuz", "float8_e5m2fnuz")

# HLO custom-call targets that move data to the host (Python callbacks)
_HOST_CALLBACK_TARGETS = ("xla_python_cpu_callback", "xla_ffi_python_cpu_callback",
                          "xla_python_gpu_callback", "tpu_py_callback")
_HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "recv",
                      "send-done", "recv-done")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str
    entry: str
    message: str
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)
    suppressed: bool = False

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """One static-analysis rule.

    ``check(artifacts, entry)`` returns the findings; ``entry`` is the
    registered :class:`EntryPoint` (rules read its pinned expectations —
    launch count, (N, K, d) triple, VMEM ceiling)."""
    id: str
    severity: str
    layer: str          # jaxpr | hlo | pallas | runtime | config
    description: str
    check: Callable[[Artifacts, "EntryPoint"], List[Finding]]

    def run(self, artifacts: Artifacts, entry: "EntryPoint") -> List[Finding]:
        return self.check(artifacts, entry)


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """A registered lint target.

    ``build()`` returns ``(fn, args)`` — the jitted callable plus example
    arguments.  New subsystems (shard_map rounds, compressed gossip)
    inherit the full gate by registering an entry here; see
    docs/STATIC_ANALYSIS.md for the two-line recipe."""
    name: str
    description: str
    build: Callable[[], Tuple[Callable, Tuple]]
    expected_launches: int
    nkd: Tuple[int, int, int]            # (N, K, d) of the gossip round
    suppress: frozenset = frozenset()
    vmem_ceiling: int = 16 * 1024 * 1024             # ~16 MB/core VMEM
    compile_once: Optional[Callable[[], int]] = None  # -> trace-cache size
    # memory_passes pins: rows of (desc, WFAggConfig, kwargs, ceiling) —
    # the absorbed scripts/passes_gate.py table, distributed over the
    # entries each row describes
    passes: Tuple[Tuple[str, Any, Dict[str, Any], int], ...] = ()
    # sharded entries: the declared communication contract (collectives.
    # CommContract) the spmd-* rule family enforces, and the device count
    # build() needs — the CLI records a skip instead of building when
    # fewer devices are visible (virtual CPU devices count)
    contract: Optional[Any] = None
    min_devices: int = 1


# ---------------------------------------------------------------------------
# HLO text helpers (the shared forms of the old per-test greps)
# ---------------------------------------------------------------------------

def scan_nkd_buffers(hlo: str, n: int, k: int, min_d: int = 0,
                     dtype: str = "f32") -> List[int]:
    """All ``d`` for which a ``dtype[n, k, d]`` buffer (d > min_d)
    appears anywhere in the HLO module — while bodies included, since the
    module text prints every computation.  ``min_d=0`` is the strict
    form; the one-launch round passes ``min_d=16*k`` so the legitimate
    O(K²) Alt-WFAgg Gram ((N, K, K)) is not mistaken for a gossip
    tensor."""
    pat = re.compile(rf"{re.escape(dtype)}\[{n},{k},(\d+)\]")
    return sorted({int(m) for m in pat.findall(hlo) if int(m) > min_d})


def scan_gather_model_dim(hlo: str, min_d: int) -> List[str]:
    """Lines where a ``gather``/``scatter`` instruction touches a
    model-dim-sized operand (any output dimension >= ``min_d``).  Small
    gathers (minibatch indexing, neighbor-table lookups) pass; a K-fold
    gossip gather of d-sized rows does not."""
    hits = []
    shape_re = re.compile(r"[a-z][a-z0-9]*\[([0-9,]*)\]")
    for line in hlo.splitlines():
        if not re.search(r"\b(gather|scatter)\(", line):
            continue
        dims = []
        for tok in shape_re.findall(line):
            dims += [int(x) for x in tok.split(",") if x.strip()]
        if dims and max(dims) >= min_d:
            hits.append(line.strip()[:160])
    return hits


def _hlo_call_graph(hlo: str):
    """(computations, entry, edges, while_bodies) from the module text —
    a thin re-use of launch.hlo_analysis's splitter."""
    from repro.launch import hlo_analysis as ha
    comps, entry = ha._split_computations(hlo)
    edges: Dict[str, List[str]] = {c: [] for c in comps}
    while_roots: List[str] = []
    for cname, lines in comps.items():
        for line in lines:
            for m in ha._BODY_RE.finditer(line):
                edges[cname].append(m.group(1))
                while_roots.append(m.group(1))
            for m in ha._COND_RE.finditer(line):
                edges[cname].append(m.group(1))
            for m in ha._CALLS_RE.finditer(line):
                edges[cname].append(m.group(1))
            for m in ha._TO_APPLY_RE.finditer(line):
                edges[cname].append(m.group(1))
            for m in ha._CALLED_COMPS_RE.finditer(line):
                edges[cname] += [b.strip().lstrip("%")
                                 for b in m.group(1).split(",") if b.strip()]
            for m in ha._TRUE_FALSE_RE.finditer(line):
                edges[cname].append(m.group(1))
            m = ha._BRANCHES_RE.search(line)
            if m:
                edges[cname] += [b.strip().lstrip("%")
                                 for b in m.group(1).split(",") if b.strip()]
    return comps, entry, edges, while_roots


def scan_host_transfers_in_while(hlo: str) -> List[Tuple[str, str]]:
    """(computation, line) pairs for host transfers — infeed/outfeed/
    send/recv or Python-callback custom-calls — inside any computation
    reachable from a ``while`` body."""
    comps, _, edges, while_roots = _hlo_call_graph(hlo)
    reachable: set = set()
    stack = list(while_roots)
    while stack:
        c = stack.pop()
        if c in reachable:
            continue
        reachable.add(c)
        stack += edges.get(c, [])
    hits = []
    op_re = re.compile(r"=\s*\(?[\w\[\],{}<> ]*?\)?\s*(" +
                       "|".join(_HOST_TRANSFER_OPS) + r")\(")
    for cname in reachable:
        for line in comps.get(cname, []):
            if op_re.search(line):
                hits.append((cname, line.strip()[:160]))
            elif "custom-call" in line and any(
                    t in line for t in _HOST_CALLBACK_TARGETS):
                hits.append((cname, line.strip()[:160]))
    return hits


# ---------------------------------------------------------------------------
# rule checks
# ---------------------------------------------------------------------------

def _check_nkd(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    n, k, _ = entry.nkd
    hits = scan_nkd_buffers(artifacts.hlo, n, k, min_d=16 * k)
    return [Finding(
        "no-nkd-buffer", "error", entry.name,
        f"(N={n}, K={k}, d)-shaped f32 buffer(s) materialized: d={hits} — "
        "the K-fold gossip tensor must never exist in HBM",
        {"d_values": hits})] if hits else []


def _check_gather(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    _, k, d = entry.nkd
    min_d = max(16 * k + 1, d // 2)
    hits = scan_gather_model_dim(artifacts.hlo, min_d)
    return [Finding(
        "gather-free-model-dim", "error", entry.name,
        f"{len(hits)} gather/scatter op(s) touch a model-dim-sized "
        f"(>= {min_d}) operand — the indexed path must DMA neighbor "
        "blocks, never gather them",
        {"lines": hits[:8]})] if hits else []


def _check_launch_count(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    got = count_pallas_calls(artifacts.jaxpr.jaxpr)
    if got == entry.expected_launches:
        return []
    return [Finding(
        "launch-count", "error", entry.name,
        f"{got} pallas_call eqn(s) traced, pinned {entry.expected_launches} "
        "— a launch regression (single-launch falling back to two) or an "
        "unregistered new kernel",
        {"got": got, "expected": entry.expected_launches})]


def _check_f32_trust(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    """Temporal metrics and trust scores are O(K)-sized; model payloads
    are d-sized.  Any f32 -> sub-f32 convert of a NON-model-dim buffer is
    a trust-arithmetic downcast (d-sized downcasts are the province of a
    future compressed-gossip wire format and stay legal)."""
    _, _, d = entry.nkd
    findings = []
    for eqn in walk_eqns(artifacts.jaxpr.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new = np.dtype(eqn.params.get("new_dtype"))
        if new.name not in _SUB_F32:
            continue
        src = eqn.invars[0].aval
        if np.dtype(src.dtype) != np.dtype(np.float32):
            continue
        size = int(np.prod(src.shape)) if src.shape else 1
        if size >= max(d // 2, 1):
            continue                      # model-dim payload: allowed
        findings.append(Finding(
            "f32-trust-invariant", "error", entry.name,
            f"f32 -> {new.name} downcast of a trust/temporal-sized buffer "
            f"{tuple(src.shape)} — filter statistics must stay f32",
            {"shape": list(src.shape), "dtype": new.name}))
    return findings


def _check_host_transfer(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    hits = scan_host_transfers_in_while(artifacts.hlo)
    return [Finding(
        "no-host-transfer-in-scan", "error", entry.name,
        f"{len(hits)} device->host transfer(s)/callback(s) inside a while "
        "body — the round scan must stay on-device",
        {"hits": [f"{c}: {l}" for c, l in hits[:8]]})] if hits else []


def _eval_index_map(ij, coords, smem_shapes) -> Optional[Tuple[int, ...]]:
    """Evaluate a BlockSpec index-map jaxpr at integer grid ``coords``.
    SMEM scalar-prefetch refs are fed zero tables (block index 0 is
    always in range), so pure-grid arithmetic — the pinning expressions
    like ``i * p`` — is what gets validated."""
    import jax
    args = [np.int32(c) for c in coords]
    args += [np.zeros(s, np.int32) for s in smem_shapes]
    try:
        out = jax.core.eval_jaxpr(ij.jaxpr, ij.consts, *args)
    except Exception:
        return None
    return tuple(int(o) for o in out)


def _check_vmem(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    findings = []
    for info in artifacts.pallas_calls:
        vmem = info.vmem_bytes()
        detail = {
            "kernel": info.name, "grid": list(info.grid),
            "block_bytes": info.block_bytes,
            "scratch_bytes": info.scratch_bytes,
            "vmem_bytes": vmem, "ceiling": entry.vmem_ceiling,
        }
        if vmem > entry.vmem_ceiling:
            findings.append(Finding(
                "vmem-budget", "error", entry.name,
                f"kernel {info.name!r}: modelled per-grid-step VMEM "
                f"residency {vmem / 2**20:.1f} MiB exceeds the "
                f"{entry.vmem_ceiling / 2**20:.0f} MiB ceiling "
                "(2x double-buffered blocks + scratch)", detail))
        # divisibility: a block dim that does not divide its (padded)
        # array dim silently reads ragged tails
        for b in info.blocks:
            bs, ash = b.block_shape, b.array_shape
            if len(bs) != len(ash):
                continue
            ragged = [(x, y) for x, y in zip(ash, bs) if y and x % y != 0]
            if ragged:
                findings.append(Finding(
                    "vmem-budget", "error", entry.name,
                    f"kernel {info.name!r} operand {b.origin}: block shape "
                    f"{bs} does not divide array shape {ash} — the ops "
                    "wrappers must pad D to the block size",
                    {"kernel": info.name, "origin": b.origin,
                     "block_shape": list(bs), "array_shape": list(ash)}))
        # pinned-index-map validation: every evaluated block index must
        # stay inside the array across the whole grid (catches a broken
        # pin like `i + p` walking the output out of range in phase 1)
        smem_shapes = []  # scalar-prefetch aval shapes, from any block's map
        for b in info.blocks:
            extra = len(b.index_map_jaxpr.in_avals) - len(info.grid)
            if extra > 0:
                smem_shapes = [tuple(a.shape)
                               for a in b.index_map_jaxpr.in_avals[-extra:]]
                break
        coords_list = _grid_sample(info.grid)
        for b in info.blocks:
            if len(b.block_shape) != len(b.array_shape):
                continue
            nblocks = [max(1, -(-x // y)) if y else 1
                       for x, y in zip(b.array_shape, b.block_shape)]
            for coords in coords_list:
                idx = _eval_index_map(b.index_map_jaxpr, coords, smem_shapes)
                if idx is None or len(idx) != len(nblocks):
                    continue
                if any(i < 0 or i >= nb for i, nb in zip(idx, nblocks)):
                    findings.append(Finding(
                        "vmem-budget", "error", entry.name,
                        f"kernel {info.name!r} operand {b.origin}: index map "
                        f"returns block {idx} at grid {coords} but the array "
                        f"only has {nblocks} blocks",
                        {"kernel": info.name, "origin": b.origin,
                         "grid_coords": list(coords), "block_idx": list(idx)}))
                    break
        findings.append(Finding(
            "vmem-budget", "info", entry.name,
            f"kernel {info.name!r}: {vmem / 2**20:.2f} MiB/step of "
            f"{entry.vmem_ceiling / 2**20:.0f} MiB "
            f"({100.0 * vmem / entry.vmem_ceiling:.0f}%)", detail))
    return findings


def _grid_sample(grid: Tuple[int, ...], cap: int = 512) -> List[Tuple[int, ...]]:
    """All grid points when small, otherwise the corners of each axis
    plus a deterministic stride sample."""
    total = int(np.prod(grid)) if grid else 0
    if total == 0:
        return []
    if total <= cap:
        pts = np.indices(grid).reshape(len(grid), -1).T
        return [tuple(int(x) for x in p) for p in pts]
    # corner sample: first/last block of every axis, others at 0 and max
    axes = [(0, g - 1) if g > 1 else (0,) for g in grid]
    import itertools
    return [tuple(p) for p in itertools.product(*axes)][:cap]


def _check_compile_once(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.compile_once is None:
        return []
    size = int(entry.compile_once())
    if size == 1:
        return []
    return [Finding(
        "compile-once", "error", entry.name,
        f"trace cache holds {size} executables after a round-varying "
        "schedule — the dynamic round retraced per graph",
        {"cache_size": size})]


def _check_memory_passes(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if not entry.passes:
        return []
    from repro.core.wfagg import memory_passes
    findings = []
    for desc, cfg, kwargs, ceiling in entry.passes:
        got = memory_passes(cfg, **kwargs)
        if got <= ceiling:
            findings.append(Finding(
                "memory-passes", "info", entry.name,
                f"{desc}: memory_passes = {got} (ceiling {ceiling})",
                {"desc": desc, "got": got, "ceiling": ceiling}))
        else:
            findings.append(Finding(
                "memory-passes", "error", entry.name,
                f"{desc}: memory_passes regressed to {got} (documented "
                f"ceiling {ceiling})",
                {"desc": desc, "got": got, "ceiling": ceiling}))
    return findings


def _check_unknown_trip(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    from repro.launch import hlo_analysis as ha
    cost = ha.analyze(artifacts.hlo, n_devices=1)
    findings = []
    if cost.unknown_trip_whiles:
        findings.append(Finding(
            "unknown-trip-count", "warning", entry.name,
            f"{cost.unknown_trip_whiles} while loop(s) without "
            "known_trip_count — the roofline model multiplies their "
            "bodies by 1, under-reporting cost",
            {"unknown_trip_whiles": cost.unknown_trip_whiles,
             "trip_counts": cost.trip_counts[:16]}))
    return findings


def _check_dead_computation(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    from repro.launch import hlo_analysis as ha
    cost = ha.analyze(artifacts.hlo, n_devices=1)
    dead = getattr(cost, "dead_computations", []) or []
    if not dead:
        return []
    return [Finding(
        "dead-computation", "info", entry.name,
        f"{len(dead)} computation(s) unreachable from the entry — dead "
        "code the compiler kept (or a call-graph edge the analyzer "
        "missed)", {"computations": dead[:16]})]


# ---------------------------------------------------------------------------
# SPMD communication-contract rules (entries with entry.contract set)
# ---------------------------------------------------------------------------
#
# These read the per-collective records hlo_analysis parses out of the
# sharded optimized HLO (kind, payload bytes, replica groups, trip-count
# multiplier) and hold them against the entry's declared CommContract:
# under a D-sharded mesh the only cross-shard traffic the WFAgg round
# may emit is the O(N*K) statistic psum — never a model-dim gather.

def _contract_records(artifacts: Artifacts, entry: EntryPoint):
    from repro.analysis.collectives import contract_cost
    cost = contract_cost(artifacts, entry.contract.axis_size)
    return cost, (cost.collectives or [])


def _check_spmd_contract(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.contract is None:
        return []
    ct = entry.contract
    _, colls = _contract_records(artifacts, entry)
    findings = []
    for r in colls:
        if r.kind not in ct.allowed_kinds:
            findings.append(Finding(
                "spmd-collective-contract", "error", entry.name,
                f"{r.kind} {r.name!r} ({r.out_bytes} B) — contract allows "
                f"only {ct.allowed_kinds}: GSPMD inserted cross-shard "
                "traffic the sharded round never declared",
                {"collective": r.to_dict(), "allowed": list(ct.allowed_kinds)}))
        elif r.out_bytes > ct.max_collective_bytes:
            findings.append(Finding(
                "spmd-collective-contract", "error", entry.name,
                f"{r.kind} {r.name!r} moves {r.out_bytes} B, over the "
                f"{ct.max_collective_bytes} B per-collective ceiling — the "
                "trust-weight reduction is O(N*K); anything bigger is "
                "model-dim payload on the wire",
                {"collective": r.to_dict(),
                 "ceiling": ct.max_collective_bytes}))
    return findings


def _check_spmd_allgather(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.contract is None:
        return []
    ct = entry.contract
    _, _, d = entry.nkd
    # half of one model row's SHARD: generous against O(N*K) psums, far
    # below any d-sized buffer a boundary all-gather would rebuild
    min_b = 4 * max(d // max(1, ct.axis_size), 1) // 2
    findings = []
    for r in _contract_records(artifacts, entry)[1]:
        if r.kind in ("all-gather", "all-to-all") and r.out_bytes >= min_b:
            findings.append(Finding(
                "spmd-model-dim-allgather", "error", entry.name,
                f"{r.kind} {r.name!r} rebuilds {r.out_bytes} B of model-dim "
                f"payload (>= {min_b} B) — a sharded array met a replicated "
                "consumer and GSPMD un-sharded it; keep (N, d) buffers "
                "P(None, 'model') end to end",
                {"collective": r.to_dict(), "min_bytes": min_b}))
    return findings


def _check_spmd_replica_groups(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.contract is None:
        return []
    ct = entry.contract
    cost, colls = _contract_records(artifacts, entry)
    findings = []
    if cost.num_partitions != ct.axis_size:
        findings.append(Finding(
            "spmd-replica-groups", "error", entry.name,
            f"module compiled with num_partitions={cost.num_partitions}, "
            f"contract declares a {ct.axis_size}-shard mesh — the entry "
            "is not actually sharding d",
            {"num_partitions": cost.num_partitions,
             "axis_size": ct.axis_size}))
    for r in colls:
        if r.group_size <= 1:
            findings.append(Finding(
                "spmd-replica-groups", "error", entry.name,
                f"{r.kind} {r.name!r} has singleton replica groups — a "
                "dead collective (reduces nothing, still synchronizes)",
                {"collective": r.to_dict()}))
            continue
        if r.covers_mesh(ct.axis_size) is False:
            findings.append(Finding(
                "spmd-replica-groups", "error", entry.name,
                f"{r.kind} {r.name!r} replica groups cover only "
                f"{sorted(r.participants())} of the {ct.axis_size}-device "
                "mesh — shards outside the group keep PARTIAL statistics "
                "and the filters diverge per shard",
                {"collective": r.to_dict()}))
    return findings


def _check_spmd_wire_budget(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.contract is None:
        return []
    ct = entry.contract
    _, colls = _contract_records(artifacts, entry)
    total = sum(r.mult * r.wire_bytes for r in colls)
    by_kind: Dict[str, float] = {}
    for r in colls:
        by_kind[r.kind] = by_kind.get(r.kind, 0.0) + r.mult * r.wire_bytes
    detail = {"wire_bytes": total, "budget": ct.wire_budget_bytes,
              "by_kind": by_kind, "n_collectives": len(colls)}
    if total > ct.wire_budget_bytes:
        return [Finding(
            "spmd-wire-budget", "error", entry.name,
            f"trip-count-aware per-device wire {total:.4g} B exceeds the "
            f"contract budget {ct.wire_budget_bytes:.4g} B — a collective "
            "multiplied into a loop body, or payloads grew past O(N*K)",
            detail)]
    return [Finding(
        "spmd-wire-budget", "info", entry.name,
        f"per-device wire {total:.4g} B of {ct.wire_budget_bytes:.4g} B "
        f"budget ({100.0 * total / max(ct.wire_budget_bytes, 1e-9):.0f}%)",
        detail)]


def _check_spmd_nkd(artifacts: Artifacts, entry: EntryPoint) -> List[Finding]:
    if entry.contract is None:
        return []
    n, k, d = entry.nkd
    d_shard = max(1, d // max(1, entry.contract.axis_size))
    min_d = max(16 * k, d_shard // 4)
    hits = scan_nkd_buffers(artifacts.hlo, n, k, min_d=min_d)
    return [Finding(
        "spmd-sharded-nkd-buffer", "error", entry.name,
        f"per-shard (N={n}, K={k}, d/S)-sized f32 buffer(s): d={hits} — "
        "the gossip tensor re-materialized inside the shard (the indexed "
        "kernels must DMA neighbor shards, never stack them)",
        {"d_values": hits, "min_d": min_d})] if hits else []


RULES: Tuple[Rule, ...] = (
    Rule("no-nkd-buffer", "error", "hlo",
         "No (N, K, d)-shaped f32 intermediate anywhere in the module, "
         "while bodies included (d > 16K excludes the O(K^2) Gram).",
         _check_nkd),
    Rule("gather-free-model-dim", "error", "hlo",
         "No gather/scatter touches a model-dim-sized operand.",
         _check_gather),
    Rule("launch-count", "error", "jaxpr",
         "pallas_call count through scan/cond/pjit matches the pin.",
         _check_launch_count),
    Rule("f32-trust-invariant", "error", "jaxpr",
         "Trust/temporal statistics are never downcast below f32.",
         _check_f32_trust),
    Rule("no-host-transfer-in-scan", "error", "hlo",
         "No device->host transfer or callback inside a while body.",
         _check_host_transfer),
    Rule("vmem-budget", "error", "pallas",
         "Per-grid-step VMEM residency (2x blocks + scratch) under the "
         "ceiling; block shapes divide arrays; index maps stay in range.",
         _check_vmem),
    Rule("compile-once", "error", "runtime",
         "Trace cache stays at 1 across a round-varying schedule.",
         _check_compile_once),
    Rule("memory-passes", "error", "config",
         "memory_passes() stays within the documented traffic table "
         "(the absorbed scripts/passes_gate.py).", _check_memory_passes),
    Rule("unknown-trip-count", "warning", "hlo",
         "While loops carry known_trip_count (roofline accuracy).",
         _check_unknown_trip),
    Rule("dead-computation", "info", "hlo",
         "Every computation is reachable from the entry.",
         _check_dead_computation),
    Rule("spmd-collective-contract", "error", "hlo",
         "Sharded entries emit only the contract's collective kinds, each "
         "payload under the O(N*K) per-collective ceiling.",
         _check_spmd_contract),
    Rule("spmd-model-dim-allgather", "error", "hlo",
         "No all-gather/all-to-all rebuilds model-dim payload across "
         "shards (the GSPMD boundary-un-sharding failure mode).",
         _check_spmd_allgather),
    Rule("spmd-replica-groups", "error", "hlo",
         "Collectives cover the declared mesh: no singleton groups, no "
         "partial-mesh reductions, num_partitions matches the contract.",
         _check_spmd_replica_groups),
    Rule("spmd-wire-budget", "error", "hlo",
         "Trip-count-aware per-device collective wire bytes stay within "
         "the contract budget.", _check_spmd_wire_budget),
    Rule("spmd-sharded-nkd-buffer", "error", "hlo",
         "No per-shard (N, K, d/S) gossip tensor materializes inside the "
         "sharded module.", _check_spmd_nkd),
)

RULES_BY_ID: Dict[str, Rule] = {r.id: r for r in RULES}


def parse_suppressions(specs: Sequence[str]) -> Dict[str, Optional[set]]:
    """``rule-id`` (everywhere) or ``rule-id@entry`` -> {rule: entries}
    where entries None means all."""
    out: Dict[str, Optional[set]] = {}
    for spec in specs:
        rule, _, ent = spec.partition("@")
        if rule not in RULES_BY_ID:
            raise ValueError(f"unknown rule {rule!r} in suppression {spec!r}; "
                             f"known: {sorted(RULES_BY_ID)}")
        if not ent:
            out[rule] = None
        elif out.get(rule, set()) is not None:
            out.setdefault(rule, set())
            out[rule].add(ent)
    return out


def run_rules(artifacts: Artifacts, entry: EntryPoint,
              suppressions: Optional[Dict[str, Optional[set]]] = None,
              rules: Sequence[Rule] = RULES) -> List[Finding]:
    """Run every rule on one entry point, applying entry-level and
    caller-level suppressions (suppressed findings are kept, flagged)."""
    suppressions = suppressions or {}
    findings: List[Finding] = []
    for rule in rules:
        sup_entries = suppressions.get(rule.id, "unset")
        globally = sup_entries is None
        for_entry = (isinstance(sup_entries, set) and entry.name in sup_entries)
        suppressed = (rule.id in entry.suppress) or globally or for_entry
        if suppressed and rule.layer in ("runtime",):
            continue      # don't pay to run a suppressed runtime probe
        for f in rule.run(artifacts, entry):
            findings.append(dataclasses.replace(f, suppressed=suppressed)
                            if suppressed else f)
    return findings


def gate_failures(findings: Sequence[Finding]) -> List[Finding]:
    """The findings that fail the gate: unsuppressed errors."""
    return [f for f in findings if f.severity == "error" and not f.suppressed]
