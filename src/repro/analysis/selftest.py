"""Linter self-tests: every rule must FIRE on a doctored fixture.

The ``robustness_gate.py --self-test`` idiom applied to the linter
itself: each rule gets a small fixture with the defect planted — a
materialized (N, K, d) buffer, a bf16 trust downcast, an extra
pallas_call, an oversized / ragged / mis-pinned block, a callback inside
a scan, a data-dependent while — and the self-test asserts the rule
produces an error (or warning) on it AND stays quiet on a clean twin.
A linter whose rules cannot fail is noise; this is the proof they can.

    PYTHONPATH=src python -m repro.analysis --self-test
"""
from __future__ import annotations

from typing import List

from repro.analysis.artifacts import Artifacts
from repro.analysis.rules import (
    EntryPoint,
    Finding,
    RULES_BY_ID,
    gate_failures,
    run_rules,
)


def _entry(name: str, **kw) -> EntryPoint:
    d = dict(name=name, description="self-test fixture",
             build=lambda: (None, ()), expected_launches=0, nkd=(4, 3, 256))
    d.update(kw)
    return EntryPoint(**d)


def _findings(rule_id: str, fn, args, entry: EntryPoint) -> List[Finding]:
    return RULES_BY_ID[rule_id].run(Artifacts(fn, args), entry)


def _fired(rule_id: str, findings: List[Finding], severity: str = "error",
           why: str = "") -> None:
    hits = [f for f in findings if f.rule == rule_id and f.severity == severity]
    if not hits:
        raise SystemExit(
            f"self-test FAILED: rule {rule_id!r} did not fire on its "
            f"doctored fixture ({why}); findings: {findings}")
    print(f"  {rule_id}: fires ({hits[0].message.splitlines()[0][:72]}...)")


def _quiet(rule_id: str, findings: List[Finding], why: str = "") -> None:
    bad = [f for f in findings
           if f.rule == rule_id and f.severity in ("error", "warning")]
    if bad:
        raise SystemExit(
            f"self-test FAILED: rule {rule_id!r} false-positives on a "
            f"clean fixture ({why}): {bad}")


def _jnp():
    import jax
    import jax.numpy as jnp
    return jax, jnp


def test_no_nkd_buffer() -> None:
    jax, jnp = _jnp()
    ep = _entry("nkd")
    # doctored: m[idx] materializes the (4, 3, 256) gossip tensor
    dirty = jax.jit(lambda m, i: m[i].sum(1))
    args = (jnp.ones((6, 256)), jnp.zeros((4, 3), jnp.int32))
    _fired("no-nkd-buffer", _findings("no-nkd-buffer", dirty, args, ep),
           why="planted f32[4,3,256] buffer")
    # clean twin: same math via one-hot matmul, no 3-D buffer
    clean = jax.jit(lambda m, i: jnp.einsum(
        "nkm,md->nd", jax.nn.one_hot(i, m.shape[0], dtype=m.dtype), m))
    _quiet("no-nkd-buffer", _findings("no-nkd-buffer", clean, args, ep),
           why="gather-free twin")
    # the 16K exclusion: an (N, K, K) Gram-sized buffer must NOT trip it
    gram = jax.jit(lambda m, i: m[i][..., :3] @ jnp.swapaxes(m[i][..., :3], -1, -2))
    _quiet("no-nkd-buffer", _findings("no-nkd-buffer", gram,
                                      (jnp.ones((6, 3)), args[1]), ep),
           why="(N, K, K) Gram exclusion")


def test_gather_free_model_dim() -> None:
    jax, jnp = _jnp()
    ep = _entry("gather")
    dirty = jax.jit(lambda m, i: m[i].sum(1))
    args = (jnp.ones((6, 256)), jnp.zeros((4, 3), jnp.int32))
    _fired("gather-free-model-dim",
           _findings("gather-free-model-dim", dirty, args, ep),
           why="gather of d=256 rows")
    # clean twin: a SMALL gather (minibatch indexing) stays legal
    small = jax.jit(lambda m, i: m[i].sum(1))
    sargs = (jnp.ones((6, 8)), jnp.zeros((4, 3), jnp.int32))
    _quiet("gather-free-model-dim",
           _findings("gather-free-model-dim", small, sargs, ep),
           why="small-dim gather exclusion")


def test_launch_count() -> None:
    jax, jnp = _jnp()
    import jax.experimental.pallas as pl

    def launch(x):
        return pl.pallas_call(
            lambda xr, orf: orf.__setitem__(..., xr[...] + 1.0),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    args = (jnp.ones((8, 128)),)
    ep = _entry("launch", expected_launches=1)
    # doctored: a second launch hiding under a scan body
    def two(x):
        y = launch(x)
        z, _ = jax.lax.scan(lambda c, _: (launch(c), None), y, None, length=2)
        return z
    _fired("launch-count",
           _findings("launch-count", jax.jit(two), args, ep),
           why="extra pallas_call under a scan")
    _quiet("launch-count",
           _findings("launch-count", jax.jit(launch), args, ep),
           why="exactly-one launch")


def test_f32_trust_invariant() -> None:
    jax, jnp = _jnp()
    ep = _entry("f32")
    # doctored: (4, 3) trust-sized f32 stat downcast to bf16
    dirty = jax.jit(lambda s: s.astype(jnp.bfloat16).astype(jnp.float32) + 1)
    _fired("f32-trust-invariant",
           _findings("f32-trust-invariant", dirty,
                     (jnp.ones((4, 3), jnp.float32),), ep),
           why="planted bf16 downcast of a (4, 3) statistic")
    # clean twins: f64->f32 is fine; a d-sized payload downcast is the
    # (future) compressed-gossip wire format, not a trust downcast
    wide = jax.jit(lambda s: s.astype(jnp.float32))
    _quiet("f32-trust-invariant",
           _findings("f32-trust-invariant", wide,
                     (jnp.ones((4, 3), jnp.float32),), ep),
           why="no sub-f32 cast")
    payload = jax.jit(lambda s: s.astype(jnp.bfloat16))
    _quiet("f32-trust-invariant",
           _findings("f32-trust-invariant", payload,
                     (jnp.ones((4, 256), jnp.float32),), ep),
           why="model-dim payload exclusion")


def test_no_host_transfer_in_scan() -> None:
    jax, jnp = _jnp()
    ep = _entry("host")

    def dirty(x):
        def body(c, _):
            jax.debug.print("c={c}", c=c.sum())   # host callback in-scan
            return c * 1.01, None
        return jax.lax.scan(body, x, None, length=4)[0]

    args = (jnp.ones((8,)),)
    _fired("no-host-transfer-in-scan",
           _findings("no-host-transfer-in-scan", jax.jit(dirty), args, ep),
           why="debug callback inside the scan while body")

    def clean(x):
        return jax.lax.scan(lambda c, _: (c * 1.01, None), x, None,
                            length=4)[0]
    _quiet("no-host-transfer-in-scan",
           _findings("no-host-transfer-in-scan", jax.jit(clean), args, ep),
           why="pure scan")


def test_vmem_budget() -> None:
    jax, jnp = _jnp()
    import jax.experimental.pallas as pl

    def kernel(xr, orf):
        orf[...] = xr[...] * 2.0

    # doctored 1: block bigger than a tiny ceiling
    def big(x):
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            grid=(2,),
            in_specs=[pl.BlockSpec((64, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((64, 128), lambda i: (i, 0)),
            interpret=True)(x)
    args = (jnp.ones((128, 128)),)
    ep_small = _entry("vmem", vmem_ceiling=1024)
    _fired("vmem-budget", _findings("vmem-budget", jax.jit(big), args,
                                    ep_small),
           why="oversized block vs 1 KiB ceiling")

    # doctored 2: ragged block (64 does not divide 100)
    def ragged(x):
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            grid=(2,),
            in_specs=[pl.BlockSpec((64, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((64, 128), lambda i: (i, 0)),
            interpret=True)(x)
    rargs = (jnp.ones((100, 128)),)
    _fired("vmem-budget", _findings("vmem-budget", jax.jit(ragged), rargs,
                                    _entry("vmem-ragged")),
           why="block shape does not divide array shape")

    # doctored 3: mis-pinned index map walks out of range (i+1, not i)
    def mispinned(x):
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            grid=(2,),
            in_specs=[pl.BlockSpec((64, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((64, 128), lambda i: (i + 1, 0)),
            interpret=True)(x)
    _fired("vmem-budget", _findings("vmem-budget", jax.jit(mispinned), args,
                                    _entry("vmem-pin")),
           why="index map out of range at the last grid step")

    # clean twin under the default ceiling
    fs = _findings("vmem-budget", jax.jit(big), args, _entry("vmem-ok"))
    _quiet("vmem-budget", fs, why="64 KiB blocks under a 16 MiB ceiling")
    if not any(f.severity == "info" for f in fs):
        raise SystemExit("self-test FAILED: vmem-budget emitted no "
                         "residency info record on the clean fixture")


def test_compile_once() -> None:
    jax, jnp = _jnp()
    art = Artifacts(jax.jit(lambda x: x), (jnp.ones((2,)),))
    _fired("compile-once",
           RULES_BY_ID["compile-once"].run(
               art, _entry("retrace", compile_once=lambda: 3)),
           why="probe reporting a 3-entry trace cache")
    _quiet("compile-once",
           RULES_BY_ID["compile-once"].run(
               art, _entry("once", compile_once=lambda: 1)),
           why="cache size 1")


def test_memory_passes() -> None:
    jax, jnp = _jnp()
    from repro.core.wfagg import WFAggConfig
    art = Artifacts(jax.jit(lambda x: x), (jnp.ones((2,)),))
    # doctored: ceiling 0 — the real accounting (>= 1 pass) must trip it
    _fired("memory-passes",
           RULES_BY_ID["memory-passes"].run(
               art, _entry("passes", passes=(
                   ("doctored zero-pass ceiling", WFAggConfig(),
                    dict(include_gather=True, indexed=True), 0),))),
           why="documented-table regression")
    _quiet("memory-passes",
           RULES_BY_ID["memory-passes"].run(
               art, _entry("passes-ok", passes=(
                   ("single-launch pin", WFAggConfig(),
                    dict(include_gather=True, indexed=True), 1),))),
           why="table row within ceiling")


def test_unknown_trip_count() -> None:
    jax, jnp = _jnp()
    ep = _entry("trip")

    def dirty(x):
        return jax.lax.while_loop(lambda c: c[0] < c[1],
                                  lambda c: (c[0] + 1.0, c[1]),
                                  (x, 10.0))[0]
    _fired("unknown-trip-count",
           _findings("unknown-trip-count", jax.jit(dirty),
                     (jnp.float32(0),), ep),
           severity="warning", why="data-dependent while loop")

    def clean(x):
        return jax.lax.scan(lambda c, _: (c * 1.01, None), x, None,
                            length=4)[0]
    _quiet("unknown-trip-count",
           _findings("unknown-trip-count", jax.jit(clean),
                     (jnp.ones((8,)),), ep),
           why="scan carries known_trip_count")


def test_dead_computation() -> None:
    # handcrafted module: %orphan is referenced by nothing
    hlo = """\
HloModule doctored_dead

%orphan (p.1: f32[4]) -> f32[4] {
  %p.1 = f32[4] parameter(0)
  ROOT %neg = f32[4] negate(f32[4] %p.1)
}

ENTRY %main (p.0: f32[4]) -> f32[4] {
  %p.0 = f32[4] parameter(0)
  ROOT %out = f32[4] add(f32[4] %p.0, f32[4] %p.0)
}
"""
    ep = _entry("dead")
    _fired("dead-computation",
           RULES_BY_ID["dead-computation"].run(Artifacts.from_hlo(hlo), ep),
           severity="info", why="orphan computation in a doctored module")


# ---------------------------------------------------------------------------
# SPMD communication-contract fixtures: handcrafted sharded modules (the
# jaxpr layer is empty via Artifacts.from_hlo, which is all these hlo-
# layer rules need — they run on a 1-device box; the REAL sharded
# artifacts are linted in tests/test_spmd_analysis.py on 8 virtual
# devices)
# ---------------------------------------------------------------------------

_SPMD_NKD = (10, 4, 50896)          # the sharded entries' padded triple


def _spmd_contract(rounds: int = 1):
    from repro.analysis.collectives import wfagg_round_contract
    return wfagg_round_contract(n=10, k=4, n_shards=8, rounds=rounds)


def _spmd_entry(name: str, **kw):
    return _entry(name, nkd=_SPMD_NKD, contract=_spmd_contract(), **kw)


_SPMD_SUM = """\
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(f32[] %a, f32[] %b)
}
"""

# the contract-conforming twin: ONE O(N*K) psum over the full mesh
_SPMD_CLEAN_HLO = f"""\
HloModule doctored_spmd_clean, num_partitions=8

{_SPMD_SUM}
ENTRY %main (p.0: f32[10,4]) -> f32[10,4] {{
  %p.0 = f32[10,4] parameter(0)
  ROOT %ar = f32[10,4] all-reduce(f32[10,4] %p.0), channel_id=1, replica_groups={{{{0,1,2,3,4,5,6,7}}}}, use_global_device_ids=true, to_apply=%sum
}}
"""


def _spmd_art(body: str, header: str = "num_partitions=8") -> Artifacts:
    return Artifacts.from_hlo(
        f"HloModule doctored_spmd, {header}\n\n{_SPMD_SUM}\n{body}")


def test_spmd_collective_contract() -> None:
    ep = _spmd_entry("spmd-contract")
    # doctored 1: a replicated candidate matrix forces GSPMD to insert
    # the full-d all-gather — a kind the contract never allows
    dirty_kind = _spmd_art("""\
ENTRY %main (p.0: f32[10,6362]) -> f32[10,50896] {
  %p.0 = f32[10,6362] parameter(0)
  ROOT %ag = f32[10,50896] all-gather(f32[10,6362] %p.0), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}, use_global_device_ids=true
}
""")
    _fired("spmd-collective-contract",
           RULES_BY_ID["spmd-collective-contract"].run(dirty_kind, ep),
           why="all-gather where the contract allows all-reduce only")
    # doctored 2: an allowed kind but a model-dim-sized payload
    dirty_size = _spmd_art("""\
ENTRY %main (p.0: f32[10,4,128]) -> f32[10,4,128] {
  %p.0 = f32[10,4,128] parameter(0)
  ROOT %ar = f32[10,4,128] all-reduce(f32[10,4,128] %p.0), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, use_global_device_ids=true, to_apply=%sum
}
""")
    _fired("spmd-collective-contract",
           RULES_BY_ID["spmd-collective-contract"].run(dirty_size, ep),
           why="all-reduce payload over the O(N*K) ceiling")
    _quiet("spmd-collective-contract",
           RULES_BY_ID["spmd-collective-contract"].run(
               Artifacts.from_hlo(_SPMD_CLEAN_HLO), ep),
           why="one O(N*K) psum over the full mesh")


def test_spmd_model_dim_allgather() -> None:
    ep = _spmd_entry("spmd-allgather")
    dirty = _spmd_art("""\
ENTRY %main (p.0: f32[10,6362]) -> f32[10,50896] {
  %p.0 = f32[10,6362] parameter(0)
  ROOT %ag = f32[10,50896] all-gather(f32[10,6362] %p.0), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}, use_global_device_ids=true
}
""")
    _fired("spmd-model-dim-allgather",
           RULES_BY_ID["spmd-model-dim-allgather"].run(dirty, ep),
           why="boundary all-gather rebuilding the full-d matrix")
    _quiet("spmd-model-dim-allgather",
           RULES_BY_ID["spmd-model-dim-allgather"].run(
               Artifacts.from_hlo(_SPMD_CLEAN_HLO), ep),
           why="psum-only module")


def test_spmd_replica_groups() -> None:
    ep = _spmd_entry("spmd-groups")
    # doctored 1: singleton groups — a dead collective
    singleton = _spmd_art("""\
ENTRY %main (p.0: f32[10,4]) -> f32[10,4] {
  %p.0 = f32[10,4] parameter(0)
  ROOT %ar = f32[10,4] all-reduce(f32[10,4] %p.0), channel_id=1, replica_groups={{0},{1},{2},{3},{4},{5},{6},{7}}, use_global_device_ids=true, to_apply=%sum
}
""")
    _fired("spmd-replica-groups",
           RULES_BY_ID["spmd-replica-groups"].run(singleton, ep),
           why="singleton replica groups")
    # doctored 2: half-mesh groups — the other shards keep partial stats
    partial = _spmd_art("""\
ENTRY %main (p.0: f32[10,4]) -> f32[10,4] {
  %p.0 = f32[10,4] parameter(0)
  ROOT %ar = f32[10,4] all-reduce(f32[10,4] %p.0), channel_id=1, replica_groups={{0,1,2,3}}, use_global_device_ids=true, to_apply=%sum
}
""")
    _fired("spmd-replica-groups",
           RULES_BY_ID["spmd-replica-groups"].run(partial, ep),
           why="replica groups cover half the mesh")
    # doctored 3: module not actually partitioned
    unsharded = _spmd_art("""\
ENTRY %main (p.0: f32[10,4]) -> f32[10,4] {
  %p.0 = f32[10,4] parameter(0)
  ROOT %neg = f32[10,4] negate(f32[10,4] %p.0)
}
""", header="num_partitions=1")
    _fired("spmd-replica-groups",
           RULES_BY_ID["spmd-replica-groups"].run(unsharded, ep),
           why="num_partitions=1 against an 8-shard contract")
    _quiet("spmd-replica-groups",
           RULES_BY_ID["spmd-replica-groups"].run(
               Artifacts.from_hlo(_SPMD_CLEAN_HLO), ep),
           why="full-mesh groups")


def test_spmd_wire_budget() -> None:
    ep = _spmd_entry("spmd-wire")
    # doctored: the contract prices ONE round, but the psum sits in a
    # while body the compiler multiplies 1000x
    dirty = _spmd_art("""\
%cond (c.1: (s32[], f32[10,4])) -> pred[] {
  %c.1 = (s32[], f32[10,4]) parameter(0)
  %i.1 = s32[] get-tuple-element((s32[], f32[10,4]) %c.1), index=0
  %lim = s32[] constant(1000)
  ROOT %lt = pred[] compare(s32[] %i.1, s32[] %lim), direction=LT
}

%body (c.0: (s32[], f32[10,4])) -> (s32[], f32[10,4]) {
  %c.0 = (s32[], f32[10,4]) parameter(0)
  %i.0 = s32[] get-tuple-element((s32[], f32[10,4]) %c.0), index=0
  %x.0 = f32[10,4] get-tuple-element((s32[], f32[10,4]) %c.0), index=1
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i.0, s32[] %one)
  %ar = f32[10,4] all-reduce(f32[10,4] %x.0), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, use_global_device_ids=true, to_apply=%sum
  ROOT %t = (s32[], f32[10,4]) tuple(s32[] %ip, f32[10,4] %ar)
}

ENTRY %main (p.0: f32[10,4]) -> (s32[], f32[10,4]) {
  %p.0 = f32[10,4] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[10,4]) tuple(s32[] %zero, f32[10,4] %p.0)
  ROOT %w = (s32[], f32[10,4]) while((s32[], f32[10,4]) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"1000"}}
}
""")
    _fired("spmd-wire-budget",
           RULES_BY_ID["spmd-wire-budget"].run(dirty, ep),
           why="psum multiplied 1000x into a loop body")
    clean_fs = RULES_BY_ID["spmd-wire-budget"].run(
        Artifacts.from_hlo(_SPMD_CLEAN_HLO), ep)
    _quiet("spmd-wire-budget", clean_fs, why="one psum within budget")
    if not any(f.severity == "info" for f in clean_fs):
        raise SystemExit("self-test FAILED: spmd-wire-budget emitted no "
                         "utilization info record on the clean fixture")


def test_spmd_sharded_nkd_buffer() -> None:
    ep = _spmd_entry("spmd-nkd")
    # doctored: the per-shard (N, K, d/S) gossip tensor re-materialized
    dirty = _spmd_art("""\
ENTRY %main (p.0: f32[10,4]) -> f32[10,4,6362] {
  %p.0 = f32[10,4] parameter(0)
  ROOT %big = f32[10,4,6362] broadcast(f32[10,4] %p.0), dimensions={0,1}
}
""")
    _fired("spmd-sharded-nkd-buffer",
           RULES_BY_ID["spmd-sharded-nkd-buffer"].run(dirty, ep),
           why="per-shard (10, 4, 6362) gossip tensor")
    # the threshold scales with d/S: a (N, K, K)-sized Gram stays legal
    gram = _spmd_art("""\
ENTRY %main (p.0: f32[10,4]) -> f32[10,4,4] {
  %p.0 = f32[10,4] parameter(0)
  ROOT %g = f32[10,4,4] broadcast(f32[10,4] %p.0), dimensions={0,1}
}
""")
    _quiet("spmd-sharded-nkd-buffer",
           RULES_BY_ID["spmd-sharded-nkd-buffer"].run(gram, ep),
           why="O(K^2) Gram exclusion")
    _quiet("spmd-sharded-nkd-buffer",
           RULES_BY_ID["spmd-sharded-nkd-buffer"].run(
               Artifacts.from_hlo(_SPMD_CLEAN_HLO), ep),
           why="no 3-D buffer at all")


def test_suppression_mechanism() -> None:
    import jax
    import jax.numpy as jnp
    fn = jax.jit(lambda m, i: m[i].sum(1))
    args = (jnp.ones((6, 256)), jnp.zeros((4, 3), jnp.int32))
    ep = _entry("sup", suppress=frozenset({"no-nkd-buffer",
                                           "gather-free-model-dim"}))
    fs = run_rules(Artifacts(fn, args), ep)
    sup = [f for f in fs if f.suppressed]
    if not sup:
        raise SystemExit("self-test FAILED: entry-level suppression "
                         "produced no suppressed findings")
    if gate_failures(fs):
        raise SystemExit("self-test FAILED: suppressed findings still "
                         f"fail the gate: {gate_failures(fs)}")
    print(f"  suppression: {len(sup)} finding(s) kept but gated out")


def main() -> None:
    tests = [
        test_no_nkd_buffer, test_gather_free_model_dim, test_launch_count,
        test_f32_trust_invariant, test_no_host_transfer_in_scan,
        test_vmem_budget, test_compile_once, test_memory_passes,
        test_unknown_trip_count, test_dead_computation,
        test_spmd_collective_contract, test_spmd_model_dim_allgather,
        test_spmd_replica_groups, test_spmd_wire_budget,
        test_spmd_sharded_nkd_buffer,
        test_suppression_mechanism,
    ]
    print("repro.analysis self-test: every rule must fire on its doctored "
          "fixture")
    for t in tests:
        t()
    print("repro.analysis self-test: OK")


if __name__ == "__main__":
    main()
