"""Per-config VMEM-budget headroom over the model-shape registry.

The round kernel blocks the model dimension, so its per-grid-step VMEM
residency is set by (K, block_d), NOT by d — that independence is
exactly the scaling claim (LeNet to yi-6b through one kernel), and this
report makes it checkable instead of folklore: for every registered
architecture, trace ``wfagg_round_indexed`` abstractly at the compiled-
TPU block policy (1024 lanes) and price the launch with the same
:class:`~repro.analysis.artifacts.PallasCallInfo` model the vmem-budget
rule uses.  Tracing uses ShapeDtypeStructs only — a 480B-parameter
config costs the same milliseconds as LeNet.

``launch/dryrun.py`` embeds one of these records per dry-run artifact;
``python -m repro.analysis --configs`` emits the whole sweep.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

# compiled-TPU policy: 1024-lane D tiles, ~16 MiB/core VMEM
TPU_BLOCK_D = 1024
DEFAULT_VMEM_CEILING = 16 * 1024 * 1024


def round_kernel_residency(d: int, n: int = 10, k: int = 8,
                           block_d: int = TPU_BLOCK_D,
                           temporal: bool = True) -> Dict[str, Any]:
    """Trace the one-launch round kernel at ``(n, k, d)`` and return its
    grid + modelled per-grid-step VMEM bytes (no arrays allocated)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.artifacts import collect_pallas_calls
    from repro.core import wfagg as wf
    from repro.kernels.robust_stats.ops import wfagg_round_indexed

    cfg = wf.WFAggConfig(f=1)
    f32 = jnp.float32
    local = jax.ShapeDtypeStruct((n, d), f32)
    idx = jax.ShapeDtypeStruct((n, k), jnp.int32)
    valid = jax.ShapeDtypeStruct((n, k), jnp.bool_)
    prev = jax.ShapeDtypeStruct((n, d), f32) if temporal else None
    tbands = jax.ShapeDtypeStruct((n, 4, k), f32) if temporal else None

    def fn(m, i, v, *rest):
        p, tb = rest if temporal else (None, None)
        return wfagg_round_indexed(m, m, i, v, cfg, prev=p, tbands=tb,
                                   block_d=block_d, interpret=False)

    args = (local, idx, valid) + ((prev, tbands) if temporal else ())
    jaxpr = jax.make_jaxpr(fn)(*args)
    calls = collect_pallas_calls(jaxpr.jaxpr)
    if not calls:
        raise RuntimeError("round op traced to zero pallas_call eqns")
    info = calls[0]
    return {
        "kernel": info.name,
        "grid": list(info.grid),
        "block_d": block_d,
        "block_bytes": info.block_bytes,
        "scratch_bytes": info.scratch_bytes,
        "vmem_bytes": info.vmem_bytes(),
    }


def config_vmem_report(arch: Optional[str] = None, n: int = 10, k: int = 8,
                       ceiling: int = DEFAULT_VMEM_CEILING) -> List[Dict[str, Any]]:
    """vmem-budget headroom records for ``arch`` (or every registered
    architecture, LeNet to yi-6b, when None)."""
    from repro.configs.registry import ALL_ARCHS, get_config

    names = [arch] if arch else sorted(ALL_ARCHS)
    records = []
    for name in names:
        cfg = get_config(name)
        d = int(cfg.param_count())
        res = round_kernel_residency(d, n=n, k=k)
        vmem = res["vmem_bytes"]
        records.append({
            "arch": name,
            "d": d,
            **res,
            "ceiling": ceiling,
            "headroom_bytes": ceiling - vmem,
            "headroom_frac": round(1.0 - vmem / ceiling, 4),
            "ok": vmem <= ceiling,
        })
    return records
