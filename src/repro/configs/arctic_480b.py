"""arctic-480b [moe]: Snowflake Arctic base — dense-MoE hybrid.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, 128 experts top-2
routed MoE in parallel with a dense residual FFN on every layer.
Memory plan: bf16 params + Adafactor (factored second moment) — Adam
moments for 470B params do not fit a 16 GB/chip single pod.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    pad_heads_to=64,   # 56 !% 16-way TP: activation-layout padding (layers.attention_fwd)
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    first_dense_layers=0,
    moe_dense_residual=True,
    dense_residual_ff=4864,
    capacity_factor=1.25,
    param_dtype="bfloat16",
    optimizer="adafactor",
)
