"""Architecture + run configuration schema.

One frozen dataclass describes every assigned architecture family:
dense / MoE / MLA / SSM (Mamba-1/2) / hybrid / encoder-decoder / VLM /
audio.  Configs are hashable so they can be jit static arguments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity ------------------------------------------------------------
    name: str = "arch"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm | audio | cnn
    source: str = ""               # citation (paper / model card)

    # trunk ---------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention -----------------------------------------------------------
    qkv_bias: bool = False         # qwen1.5 style
    rope_theta: float = 10000.0
    pad_heads_to: int = 0          # pad the activation head axis to this
                                   # multiple-of-TP count (sharding layout
                                   # only — padded heads are zeros, dropped
                                   # before the output projection)
    sliding_window: Optional[int] = None   # ring-buffer KV window (long-context decode variant)

    # MLA (deepseek-v2) -----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    dense_residual_ff: int = 0            # width of that dense residual FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_dense_layers: int = 1           # leading layers use dense FFN (deepseek/moonlight style)

    # SSM (mamba) -----------------------------------------------------------
    ssm_state: int = 0
    ssm_variant: str = ""                 # mamba1 | mamba2
    d_inner: int = 0                      # default 2*d_model
    ssm_conv: int = 4
    ssm_head_dim: int = 64                # mamba2 head size
    dt_rank: int = 0                      # mamba1 dt projection rank (default d_model/16)

    # hybrid (zamba2): shared attention block every k scanned layers --------
    shared_attn_every: int = 0

    # encoder-decoder (seamless) ---------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # modality frontend stubs -------------------------------------------------
    modality: str = "text"                # text | vision | audio
    n_modal_tokens: int = 0               # precomputed patch/frame embeddings prepended

    # numerics / execution -----------------------------------------------------
    dtype: str = "bfloat16"               # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    loss_chunk: int = 0                   # chunked cross-entropy (0 = off)
    optimizer: str = "adamw"              # sgd | adamw | adafactor

    # derived ----------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner_(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank else max(1, self.d_model // 16)

    @property
    def n_ssm_heads(self) -> int:
        return max(1, self.d_inner_ // self.ssm_head_dim)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic long decode: native for ssm/hybrid, via sliding
        window for attention archs."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory checks)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, Hkv = self.head_dim_, self.n_heads, self.n_kv_heads
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        def attn_params() -> int:
            if self.use_mla:
                r = self.kv_lora_rank
                return (
                    d * H * hd                 # q
                    + d * r + d * self.qk_rope_dim   # kv down + rope key
                    + r * H * hd * 2           # k/v up
                    + H * hd * d               # out
                )
            return d * H * hd + 2 * d * Hkv * hd + H * hd * d + (
                (H * hd + 2 * Hkv * hd) if self.qkv_bias else 0
            )
        def dense_ffn(width: int) -> int:
            return 3 * d * width
        def moe_ffn() -> int:
            total = self.n_experts * 3 * d * ff + d * self.n_experts  # experts + router
            total += self.n_shared_experts * 3 * d * ff
            if self.moe_dense_residual:
                total += dense_ffn(self.dense_residual_ff or ff)
            return total
        def mamba_params() -> int:
            di, n = self.d_inner_, self.ssm_state
            if self.ssm_variant == "mamba2":
                Hm = self.n_ssm_heads
                return d * 2 * di + di * self.ssm_conv + di * d + Hm + Hm + (
                    di * 2 * n + di  # B,C proj + dt proj (head-wise)
                )
            dtr = self.dt_rank_
            return (
                d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * n) + dtr * di
                + di * n + di + di * d
            )
        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += mamba_params()
            total += self.n_layers * per_layer
        elif self.family == "hybrid":
            total += self.n_layers * (mamba_params() + 2 * d)
            # shared attention block (params shared across invocations)
            total += 2 * d * d + attn_params() + dense_ffn(ff) + 4 * d
        else:
            layers = self.n_layers + (self.n_enc_layers if self.is_encoder_decoder else 0)
            moe_layers = 0
            if self.n_experts:
                moe_layers = max(0, self.n_layers - self.first_dense_layers)
            dense_layers = layers - moe_layers
            total += layers * (attn_params() + 2 * d)
            if self.is_encoder_decoder:
                total += self.n_layers * attn_params()  # cross attention
            total += moe_layers * moe_ffn() + dense_layers * dense_ffn(ff)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        moe_layers = max(0, self.n_layers - self.first_dense_layers)
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 * d * ff
        return int(self.param_count() - inactive)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — runnable in seconds on one CPU."""
        d = min(self.d_model, 256)
        H = min(self.n_heads, 4)
        kwargs = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=H,
            n_kv_heads=min(self.n_kv_heads, H),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=d // H,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.use_mla else 0,
            qk_rope_dim=min(self.qk_rope_dim, 16) if self.use_mla else 64,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            dense_residual_ff=min(self.dense_residual_ff, 256) if self.dense_residual_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            d_inner=2 * d if self.family in ("ssm", "hybrid") else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.family in ("ssm", "hybrid") else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_enc_layers=2 if self.is_encoder_decoder else 0,
            n_modal_tokens=min(self.n_modal_tokens, 16) if self.n_modal_tokens else 0,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            loss_chunk=0,
            optimizer="sgd",
        )
        return dataclasses.replace(self, **kwargs)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"
