"""deepseek-v2-lite-16b [moe]: MLA attention + fine-grained MoE.

27L d_model=2048 16H kv_lora_rank=512 d_ff=1408 vocab=102400,
64 routed experts top-6 + 2 shared experts, first layer dense.
Decode caches only (c_kv, k_rope) — the MLA compression — and runs the
absorbed attention form.  [arXiv:2405.04434]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    loss_chunk=512,
    optimizer="adamw",
)
