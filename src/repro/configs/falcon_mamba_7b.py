"""falcon-mamba-7b [ssm]: pure Mamba-1, attention-free.

64L d_model=4096 d_inner=8192 ssm_state=16 vocab=65024.  Decode is an
O(1) recurrent-state update, so long_500k runs natively.
[arXiv:2410.05355]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_variant="mamba1",
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    optimizer="adamw",
)
