"""The paper's own experiment config: LeNet-5-style CNN on (synthetic)
MNIST, 20-node 8-regular DFL, 2 Byzantine nodes (Section V-A)."""
import dataclasses

from repro.configs.base import ArchConfig


CONFIG = ArchConfig(
    name="lenet-mnist",
    family="cnn",
    source="paper Section V-A (LeCun et al. 1998 LeNet-5)",
    n_layers=7,
    d_model=84,
    n_heads=1,
    n_kv_heads=1,
    d_ff=120,
    vocab_size=10,       # 10 classes
    dtype="float32",
    param_dtype="float32",
    remat=False,
    optimizer="sgd",
)


@dataclasses.dataclass(frozen=True)
class PaperDFLConfig:
    """Section V-A validation scenario."""

    n_nodes: int = 20
    degree: int = 8
    n_malicious: int = 2
    rounds: int = 10
    local_epochs: int = 1
    lr: float = 0.01
    momentum: float = 0.9
    batch_size: int = 64
    # aggregation hyper-parameters
    f: int = 2
    trim_beta: float = 0.1
    multi_krum_m_frac: float = 0.25
    tau1: float = 0.4
    tau2: float = 0.4
    tau3: float = 0.2
    alpha: float = 0.8
    window: int = 3
    transient: int = 3


PAPER_DFL = PaperDFLConfig()
