"""llava-next-34b [vlm]: large decoder LM consuming ViT patch embeddings.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower
is STUBBED per the task rules: input_specs() provides precomputed patch
embeddings (B, 576, 1024) — one anyres base tile — which the learned
two-layer projector maps into the LM embedding space.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    pad_heads_to=64,   # 56 !% 16-way TP: activation-layout padding (layers.attention_fwd)
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    modality="vision",
    n_modal_tokens=576,
    optimizer="adafactor",
)
