"""moonshot-v1-16b-a3b [dense/MoE]: Moonlight-16B-A3B.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=163840,
MoE 64 experts top-6, DeepSeek-V3-style trunk: 2 shared experts, first
layer dense.  [hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    capacity_factor=1.25,
    loss_chunk=512,
    optimizer="adamw",
)
