"""Architecture registry: --arch <id> -> ArchConfig."""
from __future__ import annotations

from repro.configs import (
    arctic_480b,
    deepseek_v2_lite_16b,
    falcon_mamba_7b,
    lenet_mnist,
    llava_next_34b,
    moonshot_v1_16b_a3b,
    qwen1_5_0_5b,
    seamless_m4t_medium,
    stablelm_3b,
    yi_6b,
    zamba2_1_2b,
)
from repro.configs.base import ArchConfig

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        moonshot_v1_16b_a3b,
        stablelm_3b,
        zamba2_1_2b,
        arctic_480b,
        deepseek_v2_lite_16b,
        yi_6b,
        seamless_m4t_medium,
        falcon_mamba_7b,
        qwen1_5_0_5b,
        llava_next_34b,
    )
}

PAPER_ARCH = lenet_mnist.CONFIG
ALL_ARCHS = dict(ARCHS, **{PAPER_ARCH.name: PAPER_ARCH})


def get_config(name: str) -> ArchConfig:
    try:
        return ALL_ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ALL_ARCHS)}") from None


def assigned_archs() -> list[str]:
    """The ten architectures assigned from the public pool (dry-run set)."""
    return list(ARCHS)
