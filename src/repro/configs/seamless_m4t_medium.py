"""seamless-m4t-medium [audio]: encoder-decoder transformer backbone.

12 encoder + 12 decoder layers, d_model=1024 16H d_ff=4096 vocab=256206.
The mel-spectrogram + conv frontend is STUBBED per the task rules:
input_specs() provides precomputed frame embeddings (B, S_enc, d_model).
long_500k is SKIPPED for this arch (enc-dec target side; see DESIGN.md).
[arXiv:2308.11596]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=12,
    n_enc_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    modality="audio",
    loss_chunk=256,
    optimizer="adamw",
)
