"""stablelm-3b [dense]: StableLM family (LayerNorm trunk, full MHA).

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    optimizer="adamw",
)
