"""yi-6b [dense]: llama-architecture GQA decoder.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  [arXiv:2403.04652]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    source="arXiv:2403.04652",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    optimizer="adamw",
)
