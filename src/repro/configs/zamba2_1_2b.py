"""zamba2-1.2b [hybrid]: Mamba-2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
The shared transformer block (concat(h, h0) input, params shared across
invocations) fires every 2 scanned Mamba-2 layers (19 invocations).
[arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_variant="mamba2",
    ssm_state=64,
    d_inner=4096,
    ssm_head_dim=64,
    shared_attn_every=2,
    optimizer="adamw",
)
