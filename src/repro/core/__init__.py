"""Core library: the paper's contribution (WFAgg) + SOTA baselines."""
from repro.core.aggregators import (
    AGGREGATORS,
    DYN_AGGREGATORS,
    clustering_agg,
    clustering_select,
    coordinate_median,
    krum_agg,
    krum_scores,
    masked_mean,
    masked_median,
    mean_agg,
    median_agg,
    multi_krum_agg,
    pairwise_sq_dists,
    smallest_k_mask,
    trimmed_mean_agg,
)
from repro.core.attacks import (
    ADAPTIVE_ATTACKS,
    ATTACK_NAMES,
    AttackConfig,
    DefenseView,
    alie_attack,
    apply_matrix_attack,
    apply_model_attack,
    band_rider_attack,
    flip_labels,
    ipm_attack,
    min_max_attack,
    noise_attack,
    sign_flip_attack,
)
from repro.core.metrics import consensus_distance, cross_entropy, micro_accuracy, r_squared
from repro.core.topology import (
    Topology,
    make_topology,
    padded_neighbor_table,
    paper_topology,
)
# NOTE: the bare `wfagg` function is intentionally NOT re-exported here --
# it would shadow the `repro.core.wfagg` submodule attribute.  Use
# `from repro.core.wfagg import wfagg` directly.
from repro.core.wfagg import (
    TemporalState,
    WFAggConfig,
    alt_wfagg_config,
    init_temporal_state,
    wfagg_c_agg,
    wfagg_c_select,
    wfagg_d_agg,
    wfagg_d_select,
    wfagg_e,
    wfagg_e_agg,
    wfagg_scores,
    wfagg_t_select,
)
