"""Centralized Byzantine-robust aggregation baselines (paper SsII-B).

Every rule takes a candidate matrix ``updates: (K, d)`` (the K received
models/updates, flattened) and returns ``(aggregated (d,), mask (K,) bool)``
where ``mask`` marks the candidates that participated in the aggregate.
All functions are jit/vmap-safe (static K) so they can run per-DFL-node
under ``vmap`` and inside compiled multi-pod training steps.

Implemented rules and their provenance:
  mean          FedAvg simplification [McMahan et al. 2016]
  median        coordinate-wise median [Yin et al. 2018]
  trimmed_mean  coordinate-wise beta-trimmed mean [Yin et al. 2018]
  krum          Krum [Blanchard et al. 2017]
  multi_krum    Multi-Krum [Blanchard et al. 2017]
  clustering    2-way agglomerative clustering, average linkage, cosine
                distance; aggregate the larger cluster [Sattler et al. 2020]
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def smallest_k_mask(scores: Array, k: int) -> Array:
    """Boolean mask (K,) selecting the k smallest scores (ties broken by index)."""
    K = scores.shape[0]
    k = max(0, min(int(k), K))
    if k == 0:
        return jnp.zeros((K,), dtype=bool)
    # top_k of negated scores; build mask by scattering.
    _, idx = jax.lax.top_k(-scores, k)
    return jnp.zeros((K,), dtype=bool).at[idx].set(True)


def smallest_k_mask_dyn(scores: Array, k: Array) -> Array:
    """``smallest_k_mask`` with a TRACED keep count ``k`` (clamped to
    [0, K]).  Same tie-breaking (by index, via stable argsort) so the
    masks agree bit-for-bit with the static variant when k is concrete —
    the irregular-topology path uses per-node valid-degree-dependent
    counts that cannot be Python ints."""
    K = scores.shape[0]
    order = jnp.argsort(scores)
    rank = jnp.zeros((K,), jnp.int32).at[order].set(jnp.arange(K, dtype=jnp.int32))
    return rank < jnp.clip(k, 0, K)


def masked_mean(updates: Array, mask: Array) -> Array:
    w = mask.astype(updates.dtype)
    denom = jnp.maximum(w.sum(), 1.0)
    return (w[:, None] * updates).sum(axis=0) / denom


def coordinate_median(updates: Array) -> Array:
    """Coordinate-wise median over axis 0; mean of the two middles if K even."""
    return jnp.median(updates, axis=0)


def pairwise_sq_dists(updates: Array) -> Array:
    """(K, K) squared Euclidean distance matrix via the Gram expansion."""
    sq = jnp.sum(updates * updates, axis=-1)
    gram = updates @ updates.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def cosine_distance_matrix(updates: Array) -> Array:
    norms = jnp.linalg.norm(updates, axis=-1, keepdims=True)
    unit = updates / jnp.maximum(norms, _EPS)
    return 1.0 - unit @ unit.T


# ---------------------------------------------------------------------------
# aggregation rules
# ---------------------------------------------------------------------------

def mean_agg(updates: Array) -> Tuple[Array, Array]:
    K = updates.shape[0]
    return jnp.mean(updates, axis=0), jnp.ones((K,), dtype=bool)


def median_agg(updates: Array) -> Tuple[Array, Array]:
    K = updates.shape[0]
    return coordinate_median(updates), jnp.ones((K,), dtype=bool)


def trimmed_mean_agg(updates: Array, beta: float = 0.1) -> Tuple[Array, Array]:
    """Remove the smallest/largest floor(beta*K) values per coordinate."""
    K = updates.shape[0]
    t = int(beta * K)
    srt = jnp.sort(updates, axis=0)
    if t > 0:
        srt = srt[t : K - t]
    return jnp.mean(srt, axis=0), jnp.ones((K,), dtype=bool)


def krum_scores_from_sq_dists(d2: Array, f: int) -> Array:
    """Krum scores from a precomputed (K, K) squared-distance matrix.

    Shared by the jnp path, the Gram-statistics path in
    ``distributed.robust_allreduce`` and the fused Pallas backend in
    ``core.wfagg`` (which all obtain d2 differently but score identically).
    """
    K = d2.shape[0]
    d2 = d2 + jnp.diag(jnp.full((K,), jnp.inf, dtype=d2.dtype))
    n_closest = max(1, K - int(f) - 2)
    neg_small, _ = jax.lax.top_k(-d2, n_closest)  # per row
    return -neg_small.sum(axis=-1)


def krum_scores_from_sq_dists_dyn(d2: Array, f: int, n_valid: Array) -> Array:
    """Krum scores over a (K, K) squared-distance matrix whose invalid
    rows/columns carry +inf, scoring each candidate by its
    ``max(1, n_valid - f - 2)`` closest VALID peers (``n_valid`` traced).
    Invalid candidates score +inf.  Matches ``krum_scores_from_sq_dists``
    when every candidate is valid."""
    K = d2.shape[0]
    d2 = d2 + jnp.diag(jnp.full((K,), jnp.inf, dtype=d2.dtype))
    srt = jnp.sort(d2, axis=1)
    n_closest = jnp.maximum(n_valid - int(f) - 2, 1)
    take = jnp.arange(K)[None, :] < n_closest
    return jnp.sum(jnp.where(take, srt, 0.0), axis=1)


def krum_scores(updates: Array, f: int) -> Array:
    """Krum score per candidate: sum of sq-dists to its K-f-2 closest peers."""
    return krum_scores_from_sq_dists(pairwise_sq_dists(updates), f)


def krum_agg(updates: Array, f: int = 2) -> Tuple[Array, Array]:
    scores = krum_scores(updates, f)
    best = jnp.argmin(scores)
    mask = jnp.zeros((updates.shape[0],), dtype=bool).at[best].set(True)
    return updates[best], mask


def multi_krum_agg(updates: Array, f: int = 2, m: int | None = None) -> Tuple[Array, Array]:
    K = updates.shape[0]
    if m is None:
        m = max(1, K // 4)  # paper: m = K/4
    scores = krum_scores(updates, f)
    mask = smallest_k_mask(scores, m)
    return masked_mean(updates, mask), mask


def clustering_select_from_dist(D0: Array) -> Array:
    """Agglomerative 2-way clustering (average linkage) on a precomputed
    (K, K) distance matrix; returns the boolean mask of the LARGER
    cluster.  Uses the Lance-Williams recurrence so the merge loop is
    jit-compatible with static candidate count K.  Shared by the jnp
    path, the Gram-statistics path and the fused Pallas backend.

    The all-valid special case of ``clustering_select_from_dist_dyn``
    (bit-identical: every merge gate is open and the final mask is not
    valid-restricted), so the subtle recurrence lives in ONE place.
    """
    return clustering_select_from_dist_dyn(
        D0, jnp.ones((D0.shape[0],), dtype=bool))


def clustering_select_from_dist_dyn(D0: Array, valid: Array) -> Array:
    """``clustering_select_from_dist`` restricted to the valid candidates
    of a padded (irregular-degree) slate: invalid slots start inactive
    with size 0 and +inf distances, and only ``n_valid - 2`` merges are
    applied (later scan steps are gated no-ops), so the recurrence runs
    exactly on the valid submatrix.  Bit-identical to the static variant
    when every candidate is valid."""
    K = D0.shape[0]
    valid = valid.astype(bool)
    if K <= 2:
        return valid
    eye = jnp.eye(K, dtype=bool)
    vpair = valid[:, None] & valid[None, :]
    D0 = jnp.where(vpair, D0, jnp.inf)
    n_merge = valid.sum() - 2

    def merge_step(carry, s):
        D, active, sizes, assign = carry
        gate = s < n_merge
        pair_ok = active[:, None] & active[None, :] & ~eye
        Dm = jnp.where(pair_ok, D, jnp.inf)
        flat = jnp.argmin(Dm)
        i0, j0 = flat // K, flat % K
        i = jnp.minimum(i0, j0)
        j = jnp.maximum(i0, j0)
        ni, nj = sizes[i], sizes[j]
        newrow = (ni * D[i] + nj * D[j]) / jnp.maximum(ni + nj, 1.0)
        nD = D.at[i, :].set(newrow).at[:, i].set(newrow)
        nactive = active.at[j].set(False)
        nsizes = sizes.at[i].set(ni + nj).at[j].set(0.0)
        nassign = jnp.where(assign == j, i, assign)
        carry = (jnp.where(gate, nD, D), jnp.where(gate, nactive, active),
                 jnp.where(gate, nsizes, sizes), jnp.where(gate, nassign, assign))
        return carry, None

    init = (D0, valid, valid.astype(D0.dtype), jnp.arange(K))
    (_, _, sizes, assign), _ = jax.lax.scan(
        merge_step, init, jnp.arange(K - 2))
    big = jnp.argmax(sizes)
    # <= 2 valid candidates: nothing to cluster, accept them all (the
    # static variant's K <= 2 early-out)
    return jnp.where(n_merge + 2 <= 2, valid, (assign == big) & valid)


def clustering_select(updates: Array) -> Array:
    """2-way agglomerative clustering of the candidates (cosine distance)."""
    return clustering_select_from_dist(cosine_distance_matrix(updates))


def clustering_agg(updates: Array) -> Tuple[Array, Array]:
    mask = clustering_select(updates)
    return masked_mean(updates, mask), mask


# ---------------------------------------------------------------------------
# valid-mask-aware (dynamic) variants
# ---------------------------------------------------------------------------
#
# Padded gossip slates carry invalid slots (irregular degrees, dynamic
# topologies), so every baseline also exists in a ``*_dyn`` form taking a
# traced ``valid: (K,) bool`` mask: invalid candidates never influence
# the aggregate and never appear in the participation mask.  With
# ``valid`` all-True each reduces to its static counterpart.  These are
# what lets the DFL engine route mean/median/krum/… through the same
# compile-once dynamic-topology scan as WFAgg.

def masked_median(updates: Array, valid: Array) -> Array:
    """Coordinate-wise median of the VALID rows with a traced mask: the
    invalid rows sort to +inf and the two middle elements of the valid
    prefix are read at traced positions.  Matches ``coordinate_median``
    when every row is valid."""
    K = updates.shape[0]
    valid = valid.astype(bool)
    srt = jnp.sort(jnp.where(valid[:, None], updates, jnp.inf), axis=0)
    v = valid.sum()
    lo = jnp.clip((v - 1) // 2, 0, K - 1)
    hi = jnp.clip(v // 2, 0, K - 1)
    med = 0.5 * (srt[lo] + srt[hi])
    return jnp.where(v > 0, med, jnp.zeros_like(med))


def median_agg_dyn(updates: Array, valid: Array) -> Tuple[Array, Array]:
    return masked_median(updates, valid), valid.astype(bool)


def trimmed_mean_agg_dyn(updates: Array, valid: Array,
                         beta: float = 0.1) -> Tuple[Array, Array]:
    """beta-trimmed mean over the valid rows: per coordinate, drop the
    floor(beta * n_valid) smallest and largest VALID values (a traced
    rank window over the +inf-padded sort), mean the rest."""
    K = updates.shape[0]
    valid = valid.astype(bool)
    v = valid.sum()
    t = (beta * v.astype(jnp.float32)).astype(jnp.int32)
    srt = jnp.sort(jnp.where(valid[:, None], updates, jnp.inf), axis=0)
    ranks = jnp.arange(K)[:, None]
    keep = (ranks >= t) & (ranks < v - t)
    denom = jnp.maximum((v - 2 * t).astype(updates.dtype), 1.0)
    out = jnp.sum(jnp.where(keep, srt, 0.0), axis=0) / denom
    return jnp.where(v > 0, out, jnp.zeros_like(out)), valid


def _masked_sq_dists(updates: Array, valid: Array) -> Array:
    vpair = valid[:, None] & valid[None, :]
    return jnp.where(vpair, pairwise_sq_dists(updates), jnp.inf)


def krum_agg_dyn(updates: Array, valid: Array, f: int = 2) -> Tuple[Array, Array]:
    valid = valid.astype(bool)
    scores = krum_scores_from_sq_dists_dyn(
        _masked_sq_dists(updates, valid), f, valid.sum())
    scores = jnp.where(valid, scores, jnp.inf)
    best = jnp.argmin(scores)
    mask = jnp.zeros((updates.shape[0],), dtype=bool).at[best].set(True) & valid
    return masked_mean(updates, mask), mask


def multi_krum_agg_dyn(updates: Array, valid: Array, f: int = 2,
                       m: int | None = None) -> Tuple[Array, Array]:
    """Multi-Krum with a traced valid count: keep min(m, n_valid) best
    (paper default m = K/4 becomes n_valid/4)."""
    K = updates.shape[0]
    valid = valid.astype(bool)
    v = valid.sum()
    scores = jnp.where(
        valid,
        krum_scores_from_sq_dists_dyn(_masked_sq_dists(updates, valid), f, v),
        jnp.inf)
    keep = (jnp.maximum(v // 4, 1) if m is None
            else jnp.minimum(jnp.asarray(m, jnp.int32), v))
    mask = smallest_k_mask_dyn(scores, keep) & valid
    return masked_mean(updates, mask), mask


def clustering_agg_dyn(updates: Array, valid: Array) -> Tuple[Array, Array]:
    valid = valid.astype(bool)
    D = jnp.where(valid[:, None] & valid[None, :],
                  cosine_distance_matrix(updates), jnp.inf)
    mask = clustering_select_from_dist_dyn(D, valid)
    return masked_mean(updates, mask), mask


def mean_agg_dyn(updates: Array, valid: Array) -> Tuple[Array, Array]:
    valid = valid.astype(bool)
    return masked_mean(updates, valid), valid


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# Valid-mask-aware registry: same kwargs convention as AGGREGATORS plus a
# leading traced ``valid`` mask.
DYN_AGGREGATORS = {
    "mean": lambda u, v, **kw: mean_agg_dyn(u, v),
    "median": lambda u, v, **kw: median_agg_dyn(u, v),
    "trimmed_mean": lambda u, v, **kw: trimmed_mean_agg_dyn(u, v, beta=kw.get("beta", 0.1)),
    "krum": lambda u, v, **kw: krum_agg_dyn(u, v, f=kw.get("f", 2)),
    "multi_krum": lambda u, v, **kw: multi_krum_agg_dyn(u, v, f=kw.get("f", 2), m=kw.get("m")),
    "clustering": lambda u, v, **kw: clustering_agg_dyn(u, v),
}

AGGREGATORS = {
    "mean": lambda u, **kw: mean_agg(u),
    "median": lambda u, **kw: median_agg(u),
    "trimmed_mean": lambda u, **kw: trimmed_mean_agg(u, beta=kw.get("beta", 0.1)),
    "krum": lambda u, **kw: krum_agg(u, f=kw.get("f", 2)),
    "multi_krum": lambda u, **kw: multi_krum_agg(u, f=kw.get("f", 2), m=kw.get("m")),
    "clustering": lambda u, **kw: clustering_agg(u),
}
