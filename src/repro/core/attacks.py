"""Model-poisoning and data-poisoning attacks (paper Section V-B).

Model-poisoning attacks transform the flat update vector(s) a Byzantine
node sends.  ALIE and IPM are omniscient attacks: they are computed from
the benign cohort's updates (standard threat model in the literature).
Label-Flipping is a data poisoning attack and is applied to the batch
labels inside the training step instead.

All functions are jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    name: str = "none"
    noise_mu: float = 0.1       # Noise attack mean (paper: 0.1)
    noise_sigma: float = 0.1    # Noise attack std  (paper: 0.1)
    alie_zmax: float = 0.5      # ALIE z_max (paper: 0.5)
    ipm_eps: float = 0.5        # IPM epsilon (paper evaluates 0.5 and 100)


def noise_attack(update: Array, key: Array, mu: float = 0.1, sigma: float = 0.1) -> Array:
    """theta <- theta + N(mu, sigma^2 I)."""
    return update + mu + sigma * jax.random.normal(key, update.shape, update.dtype)


def sign_flip_attack(update: Array) -> Array:
    """theta <- -theta."""
    return -update


def flip_labels(labels: Array, num_classes: int) -> Array:
    """Label-Flipping data poisoning: l -> C-1-l."""
    return (num_classes - 1) - labels


def alie_attack(benign: Array, zmax: float = 0.5) -> Array:
    """A-Little-Is-Enough: mu_j - z_max * sigma_j per coordinate.

    ``benign``: (K_b, d) stack of benign updates the attacker can observe.
    """
    mu = jnp.mean(benign, axis=0)
    sd = jnp.std(benign, axis=0)
    return mu - zmax * sd


def ipm_attack(benign: Array, eps: float = 0.5) -> Array:
    """Inner-Product Manipulation: -(eps / (N-M)) * sum_benign = -eps * mean."""
    return -eps * jnp.mean(benign, axis=0)


def apply_model_attack(
    name: str,
    update: Array,
    benign: Array,
    key: Array,
    cfg: Optional[AttackConfig] = None,
) -> Array:
    """Dispatch a model-poisoning attack on a single flat update.

    ``benign`` is the (K_b, d) stack of benign updates (for omniscient
    attacks).  ``name`` in {none, noise, sign_flip, label_flip, alie,
    ipm_0.5, ipm_100}.  label_flip is a no-op here (handled in the data
    pipeline) so that the engine can treat all attacks uniformly.
    """
    cfg = cfg or AttackConfig(name=name)
    if name in ("none", "label_flip"):
        return update
    if name == "noise":
        return noise_attack(update, key, cfg.noise_mu, cfg.noise_sigma)
    if name == "sign_flip":
        return sign_flip_attack(update)
    if name == "alie":
        return alie_attack(benign, cfg.alie_zmax)
    if name == "ipm_0.5":
        return ipm_attack(benign, 0.5)
    if name == "ipm_100":
        return ipm_attack(benign, 100.0)
    if name == "ipm":
        return ipm_attack(benign, cfg.ipm_eps)
    raise ValueError(f"unknown attack {name!r}")


def _ipm_eps(name: str, cfg: AttackConfig) -> float:
    if name == "ipm_0.5":
        return 0.5
    if name == "ipm_100":
        return 100.0
    return cfg.ipm_eps


def apply_matrix_attack(
    name: str,
    models: Array,             # (K, ...) candidate stack (leading K axis)
    malicious: Array,          # (K,) bool
    key: Array,
    cfg: Optional[AttackConfig] = None,
) -> Array:
    """Replace the malicious rows of a stacked candidate array.

    The single jit-safe implementation of the vectorized model-poisoning
    math: benign-cohort statistics come from masked sums (``malicious``
    may be traced, so no boolean indexing), and only Byzantine rows are
    replaced.  Both the mode-A engine (flat (N, d) model matrix) and the
    mode-B stacked layout (per-leaf (K, *shape)) route through here —
    previously each carried its own copy of this math.
    """
    cfg = cfg or AttackConfig(name=name)
    if name in ("none", "label_flip"):
        return models
    K = models.shape[0]
    mal = malicious.reshape((K,) + (1,) * (models.ndim - 1))
    if name == "noise":
        attacked = noise_attack(models, key, cfg.noise_mu, cfg.noise_sigma)
        return jnp.where(mal, attacked.astype(models.dtype), models)
    if name == "sign_flip":
        return jnp.where(mal, -models, models)
    benign_w = (~malicious).reshape(mal.shape).astype(jnp.float32)
    n_benign = jnp.maximum(K - malicious.sum(), 1).astype(jnp.float32)
    mf = models.astype(jnp.float32)
    mu = jnp.sum(mf * benign_w, axis=0, keepdims=True) / n_benign
    if name.startswith("ipm"):
        attacked = -_ipm_eps(name, cfg) * mu
    elif name == "alie":
        var = jnp.sum(benign_w * (mf - mu) ** 2, axis=0, keepdims=True) / n_benign
        attacked = mu - cfg.alie_zmax * jnp.sqrt(var)
    else:
        raise ValueError(f"unknown attack {name!r}")
    return jnp.where(mal, jnp.broadcast_to(attacked, mf.shape).astype(models.dtype),
                     models)


ATTACK_NAMES = ("none", "noise", "sign_flip", "label_flip", "ipm_0.5", "ipm_100", "alie")
