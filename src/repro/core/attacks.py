"""Model-poisoning and data-poisoning attacks (paper Section V-B) plus
defense-aware adaptive adversaries (DART, arXiv 2407.08652).

Three adversary classes, by what the attacker can observe:

  oblivious   noise / sign_flip / label_flip — no knowledge of anyone.
  omniscient  alie / ipm — computed from the benign cohort's updates
              (the standard strong threat model of the literature).
  adaptive    band_rider / min_max — additionally observe the DEFENSE:
              a ``DefenseView`` carries the per-victim WFAgg-T EWMA
              acceptance bands (``core.trust.temporal_bands``), the
              previous-round model matrix the temporal metrics are
              measured against, and the gossip neighbor table.  The
              attacks solve for the largest deviation that the filters
              still accept — the adversary the paper never evaluates.

Model-poisoning attacks transform the flat update vector(s) a Byzantine
node sends; Label-Flipping is a data-poisoning attack applied to the
batch labels inside the training step instead.

All functions are jit-safe: benign-cohort statistics are masked sums
(``malicious`` may be traced), and every adaptive construction is closed
form — no host round-trips, so the attacks run inside the engine's
single-compile dynamic scan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    name: str = "none"
    noise_mu: float = 0.1       # Noise attack mean (paper: 0.1)
    noise_sigma: float = 0.1    # Noise attack std  (paper: 0.1)
    alie_zmax: float = 0.5      # ALIE z_max (paper: 0.5)
    ipm_eps: float = 0.5        # IPM epsilon (paper evaluates 0.5 and 100)
    # Adaptive-attack safety margin: band_rider targets deviations this
    # relative fraction INSIDE the acceptance interval (never exactly on
    # the edge, where fp rounding could tip the filter), min_max scales
    # its deviation to (1 - margin) of the feasible radius.
    adaptive_margin: float = 0.05


class DefenseView(NamedTuple):
    """What an adaptive adversary sees of WFAgg's filter state.

    The engine assembles this right before the attack step of a round —
    every field is either a traced array of the current jitted round or
    ``None`` (statically) when the corresponding defense state does not
    exist, so threading the view through ``lax.scan`` costs nothing.

      neighbor_idx  (N, K) gossip table — who receives whose model
      valid         (N, K) real-edge mask of padded slates (None = all)
      prev          previous-round sent models, aligned with the
                    candidate axis of the attacked matrix ((N, d) in the
                    mode-A engine; the per-leaf prev in mode-B) — the
                    reference point of the WFAgg-T metrics
      tbands        (N, 4*K) flat per-victim WFAgg-T acceptance bands
                    ``[lo_d | hi_d | lo_c | hi_c]`` exactly as
                    ``core.trust.temporal_bands`` precomputes them for
                    the kernel (None: no temporal defense is active)
      f             the defense's assumed Byzantine count (filter keep
                    counts derive from it)
    """

    neighbor_idx: Optional[Array] = None
    valid: Optional[Array] = None
    prev: Optional[Array] = None
    tbands: Optional[Array] = None
    f: int = 2


def noise_attack(update: Array, key: Array, mu: float = 0.1, sigma: float = 0.1) -> Array:
    """theta <- theta + N(mu, sigma^2 I)."""
    return update + mu + sigma * jax.random.normal(key, update.shape, update.dtype)


def sign_flip_attack(update: Array) -> Array:
    """theta <- -theta."""
    return -update


def flip_labels(labels: Array, num_classes: int) -> Array:
    """Label-Flipping data poisoning: l -> C-1-l."""
    return (num_classes - 1) - labels


def alie_attack(benign: Array, zmax: float = 0.5) -> Array:
    """A-Little-Is-Enough: mu_j - z_max * sigma_j per coordinate.

    ``benign``: (K_b, d) stack of benign updates the attacker can observe.
    """
    mu = jnp.mean(benign, axis=0)
    sd = jnp.std(benign, axis=0)
    return mu - zmax * sd


def ipm_attack(benign: Array, eps: float = 0.5) -> Array:
    """Inner-Product Manipulation: -(eps / (N-M)) * sum_benign = -eps * mean."""
    return -eps * jnp.mean(benign, axis=0)


# ---------------------------------------------------------------------------
# adaptive (defense-aware) attacks
# ---------------------------------------------------------------------------

def _masked_moments(mf: Array, benign_w: Array) -> Tuple[Array, Array, Array]:
    """(mu, sd, n_benign) of the benign rows of a flat (K, P) stack."""
    n_benign = jnp.maximum(benign_w.sum(), 1.0)
    mu = jnp.sum(mf * benign_w[:, None], axis=0) / n_benign
    var = jnp.sum(benign_w[:, None] * (mf - mu[None, :]) ** 2, axis=0) / n_benign
    return mu, jnp.sqrt(jnp.maximum(var, 0.0)), n_benign


def _masked_coordinate_median(mf: Array, benign: Array) -> Array:
    """Coordinate-wise median of the benign rows (traced mask, no boolean
    indexing): invalid rows sort to +inf and the two middle elements are
    read at the dynamic positions of the benign count."""
    K = mf.shape[0]
    big = jnp.where(benign[:, None], mf, jnp.inf)
    srt = jnp.sort(big, axis=0)
    v = benign.sum()
    lo = jnp.clip((v - 1) // 2, 0, K - 1)
    hi = jnp.clip(v // 2, 0, K - 1)
    med = 0.5 * (srt[lo] + srt[hi])
    return jnp.where(v > 0, med, jnp.zeros_like(med))


def _sender_band_limits(view: DefenseView, malicious: Array, K: int):
    """Fold the per-(victim, slot) WFAgg-T bands into per-SENDER limits.

    A Byzantine node sends ONE model to every neighbor, so to stay inside
    every benign victim's band it must satisfy the tightest of them:
    scatter-min the upper edges / scatter-max the lower edges over all
    valid edges whose receiver is benign.  Returns four (K,) vectors
    ``(lo_d, hi_d, lo_c, hi_c)``; senders with no constrained edge come
    back ``(-inf, +inf)`` (unconstrained), senders facing an INACTIVE
    band (transient rounds encode ``(+inf, -inf)``) come back infeasible
    — the attack falls back to mimicry for those.
    """
    idx = view.neighbor_idx
    N, Knb = idx.shape
    valid = (jnp.ones((N, Knb), bool) if view.valid is None
             else view.valid.astype(bool))
    tb = view.tbands.reshape(N, 4, Knb)
    # only benign receivers constrain the attacker (fooling a fellow
    # attacker buys nothing)
    em = valid & (~malicious)[:, None]
    flat_idx = idx.reshape(-1)

    def scatter_min(vals):
        v = jnp.where(em, vals, jnp.inf).reshape(-1)
        return jnp.full((K,), jnp.inf, vals.dtype).at[flat_idx].min(v)

    def scatter_max(vals):
        v = jnp.where(em, vals, -jnp.inf).reshape(-1)
        return jnp.full((K,), -jnp.inf, vals.dtype).at[flat_idx].max(v)

    lo_d = scatter_max(tb[:, 0])
    hi_d = scatter_min(tb[:, 1])
    lo_c = scatter_max(tb[:, 2])
    hi_c = scatter_min(tb[:, 3])
    return lo_d, hi_d, lo_c, hi_c


def band_rider_attack(
    models: Array,             # (K, P) flat candidate stack
    malicious: Array,          # (K,) bool
    view: Optional[DefenseView],
    cfg: AttackConfig,
) -> Array:
    """Temporal mimicry: the largest deviation strictly inside the
    WFAgg-T acceptance bands of every benign victim.

    WFAgg-T admits a candidate iff its round-over-round squared distance
    ``s_t = ||c - prev||^2`` and cosine distance ``b_t = 1 - cos(c, prev)``
    both land inside the victim's EWMA bands.  The attacker solves the
    inverse problem in closed form: pick targets ``s*``/``b*`` at
    ``(1 - margin)`` of the tightest band (folded over its victims via
    ``_sender_band_limits``) and construct, in the 2-D plane spanned by
    its own previous model ``p`` and a harmful direction, the exact
    vector realizing both —

        c = a p_hat + a tan(theta) q_hat,   cos(theta) = 1 - b*,
        a = (|p| + sqrt(|p|^2 - (1+tan^2)(|p|^2 - s*))) / (1 + tan^2)

    (the + root maximizes magnitude; the geometric cap
    ``b* <= 1 - sqrt(1 - s*/|p|^2)`` keeps the discriminant >= 0).  The
    tangential direction ``q_hat`` is the attacker's drift-escape
    direction ``p - mu_benign`` orthogonalized against ``p``, so
    successive rides compound away from the cohort.  Where bands are
    inactive/infeasible (transient rounds, zero prev, no temporal
    defense in the view) the attack degrades to ALIE-style mimicry —
    the strongest non-adaptive small-perturbation attack.
    """
    mf = models.astype(jnp.float32)
    K = mf.shape[0]
    benign_w = (~malicious).astype(jnp.float32)
    mu, sd, _ = _masked_moments(mf, benign_w)
    fallback = jnp.broadcast_to(mu - cfg.alie_zmax * sd, mf.shape)
    if (view is None or view.prev is None or view.tbands is None
            or view.neighbor_idx is None):
        return fallback

    m = cfg.adaptive_margin
    lo_d, hi_d, lo_c, hi_c = _sender_band_limits(view, malicious, K)
    p = view.prev.reshape(K, -1).astype(jnp.float32)
    P2 = jnp.sum(p * p, axis=-1)
    Pn = jnp.sqrt(P2)
    feasible = (jnp.isfinite(hi_d) & jnp.isfinite(hi_c)
                & (hi_d > 0.0) & (lo_d <= hi_d) & (Pn > 1e-6))

    # distance target: (1 - margin) of the way up the band
    lo_s = jnp.maximum(lo_d, 0.0)
    s_tgt = lo_s + (1.0 - m) * jnp.maximum(hi_d - lo_s, 0.0)
    # cosine target: as much angle as the band AND the geometry allow
    ratio = jnp.clip(s_tgt / jnp.maximum(P2, _EPS), 0.0, 1.0)
    b_geom = 1.0 - jnp.sqrt(jnp.maximum(1.0 - ratio, 0.0))
    lo_b = jnp.clip(lo_c, 0.0, 0.999)
    hi_b = jnp.clip(jnp.minimum(hi_c, b_geom), 0.0, 0.999)
    b_tgt = jnp.clip(lo_b + (1.0 - m) * (hi_b - lo_b), 0.0, 0.999)

    cos_t = 1.0 - b_tgt
    tan2 = jnp.maximum(1.0 / jnp.maximum(cos_t * cos_t, _EPS) - 1.0, 0.0)
    disc = jnp.maximum(P2 - (1.0 + tan2) * (P2 - s_tgt), 0.0)
    a = (Pn + jnp.sqrt(disc)) / (1.0 + tan2)

    phat = p / jnp.maximum(Pn, _EPS)[:, None]
    h = p - mu[None, :]                       # drift-escape direction
    q = h - jnp.sum(h * phat, -1, keepdims=True) * phat
    qn = jnp.linalg.norm(q, axis=-1, keepdims=True)
    # degenerate h || p: any orthogonal direction serves — derive one
    # deterministically from a rolled copy of p
    e = jnp.roll(phat, 1, axis=-1)
    q2 = e - jnp.sum(e * phat, -1, keepdims=True) * phat
    q2n = jnp.linalg.norm(q2, axis=-1, keepdims=True)
    qhat = jnp.where(qn > 1e-6, q / jnp.maximum(qn, _EPS),
                     jnp.where(q2n > 1e-6, q2 / jnp.maximum(q2n, _EPS),
                               jnp.zeros_like(q)))

    ride = a[:, None] * phat + (a * jnp.sqrt(tan2))[:, None] * qhat
    return jnp.where(feasible[:, None], ride, fallback)


def min_max_attack(
    models: Array,             # (K, P) flat candidate stack
    malicious: Array,          # (K,) bool
    cfg: AttackConfig,
) -> Array:
    """Min-max deviation (Shejwalkar & Houmansadr 2021, adapted to the
    WFAgg filter radii): ``c = mu + gamma * u`` with the largest gamma
    keeping the attacker inside BOTH distance-filter acceptance regions —

      * ``||c - x_b|| <= max pairwise benign distance`` for every benign
        ``x_b`` (the classic min-max constraint, which keeps Krum/
        Multi-Krum scores in the benign range), and
      * ``||c - med|| <= max benign distance to the coordinate median``
        (WFAgg-D's radius around the median model),

    each a quadratic in gamma with a closed-form positive root; gamma is
    the masked min over benign nodes of both caps, scaled by
    ``1 - margin``.  The deviation direction is the negative benign
    coordinate deviation ``-sd/||sd||`` (the unit-vector variant of the
    paper's attack — colinear-with-mu directions cannot move the cosine
    filter, and sd-directed deviations maximize per-coordinate harm).
    """
    mf = models.astype(jnp.float32)
    benign = ~malicious
    benign_w = benign.astype(jnp.float32)
    mu, sd, _ = _masked_moments(mf, benign_w)

    sdn = jnp.linalg.norm(sd)
    mun = jnp.linalg.norm(mu)
    u = jnp.where(sdn > 1e-6, -sd / jnp.maximum(sdn, _EPS),
                  -mu / jnp.maximum(mun, _EPS))

    # max pairwise benign squared distance via the Gram expansion
    sq = jnp.sum(mf * mf, axis=-1)
    gram = mf @ mf.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    bpair = benign[:, None] & benign[None, :]
    dmax2 = jnp.max(jnp.where(bpair, d2, -jnp.inf))

    # cap 1: ||mu + g u - x_b||^2 <= dmax^2 for every benign b
    delta = mu[None, :] - mf                  # (K, P)
    A = delta @ u                             # (K,)
    n2 = jnp.sum(delta * delta, axis=-1)
    g_pair = -A + jnp.sqrt(jnp.maximum(A * A + dmax2 - n2, 0.0))
    g_pair = jnp.min(jnp.where(benign, g_pair, jnp.inf))

    # cap 2: ||mu + g u - med||^2 <= max_b ||x_b - med||^2 (WFAgg-D radius)
    med = _masked_coordinate_median(mf, benign)
    rmed2 = jnp.max(jnp.where(
        benign, jnp.sum((mf - med[None, :]) ** 2, axis=-1), -jnp.inf))
    dm = mu - med
    Am = jnp.dot(dm, u)
    g_med = -Am + jnp.sqrt(jnp.maximum(Am * Am + rmed2 - jnp.sum(dm * dm), 0.0))

    gamma = (1.0 - cfg.adaptive_margin) * jnp.maximum(
        jnp.minimum(g_pair, g_med), 0.0)
    ok = jnp.isfinite(gamma) & (benign_w.sum() >= 2)
    c = mu + jnp.where(ok, gamma, 0.0) * u
    return jnp.broadcast_to(c, mf.shape)


def apply_model_attack(
    name: str,
    update: Array,
    benign: Array,
    key: Array,
    cfg: Optional[AttackConfig] = None,
) -> Array:
    """Dispatch a model-poisoning attack on a single flat update.

    ``benign`` is the (K_b, d) stack of benign updates (for omniscient
    attacks).  ``name`` is any entry of ``ATTACK_NAMES``; label_flip is
    a no-op here (handled in the data pipeline) so that the engine can
    treat all attacks uniformly.  The adaptive attacks run in their
    no-``DefenseView`` form on this single-update entry (band_rider
    degrades to ALIE mimicry; min_max keeps its benign-radius caps) —
    the view-threaded forms live on ``apply_matrix_attack``.
    """
    cfg = cfg or AttackConfig(name=name)
    if name in ("none", "label_flip"):
        return update
    if name == "noise":
        return noise_attack(update, key, cfg.noise_mu, cfg.noise_sigma)
    if name == "sign_flip":
        return sign_flip_attack(update)
    if name == "alie":
        return alie_attack(benign, cfg.alie_zmax)
    if name == "ipm_0.5":
        return ipm_attack(benign, 0.5)
    if name == "ipm_100":
        return ipm_attack(benign, 100.0)
    if name == "ipm":
        return ipm_attack(benign, cfg.ipm_eps)
    if name in ADAPTIVE_ATTACKS:
        stack = jnp.concatenate([update[None], benign], axis=0)
        mal = jnp.zeros((stack.shape[0],), bool).at[0].set(True)
        if name == "band_rider":
            return band_rider_attack(stack, mal, None, cfg)[0].astype(update.dtype)
        return min_max_attack(stack, mal, cfg)[0].astype(update.dtype)
    raise ValueError(f"unknown attack {name!r}")


def _ipm_eps(name: str, cfg: AttackConfig) -> float:
    if name == "ipm_0.5":
        return 0.5
    if name == "ipm_100":
        return 100.0
    return cfg.ipm_eps


def apply_matrix_attack(
    name: str,
    models: Array,             # (K, ...) candidate stack (leading K axis)
    malicious: Array,          # (K,) bool
    key: Array,
    cfg: Optional[AttackConfig] = None,
    view: Optional[DefenseView] = None,
) -> Array:
    """Replace the malicious rows of a stacked candidate array.

    The single jit-safe implementation of the vectorized model-poisoning
    math: benign-cohort statistics come from masked sums (``malicious``
    may be traced, so no boolean indexing), and only Byzantine rows are
    replaced.  Both the mode-A engine (flat (N, d) model matrix) and the
    mode-B stacked layout (per-leaf (K, *shape)) route through here —
    previously each carried its own copy of this math.

    ``view`` feeds the adaptive attacks (``ADAPTIVE_ATTACKS``) the
    defense state they ride; it is optional (and ignored by the
    oblivious/omniscient attacks) so every caller threads it — or
    ``None`` — through one uniform signature.
    """
    cfg = cfg or AttackConfig(name=name)
    if name in ("none", "label_flip"):
        return models
    K = models.shape[0]
    mal = malicious.reshape((K,) + (1,) * (models.ndim - 1))
    if name == "noise":
        attacked = noise_attack(models, key, cfg.noise_mu, cfg.noise_sigma)
        return jnp.where(mal, attacked.astype(models.dtype), models)
    if name == "sign_flip":
        return jnp.where(mal, -models, models)
    if name in ADAPTIVE_ATTACKS:
        flat = models.reshape(K, -1)
        if name == "band_rider":
            attacked = band_rider_attack(flat, malicious, view, cfg)
        else:
            attacked = min_max_attack(flat, malicious, cfg)
        attacked = attacked.reshape(models.shape).astype(models.dtype)
        return jnp.where(mal, attacked, models)
    benign_w = (~malicious).reshape(mal.shape).astype(jnp.float32)
    n_benign = jnp.maximum(K - malicious.sum(), 1).astype(jnp.float32)
    mf = models.astype(jnp.float32)
    mu = jnp.sum(mf * benign_w, axis=0, keepdims=True) / n_benign
    if name.startswith("ipm"):
        attacked = -_ipm_eps(name, cfg) * mu
    elif name == "alie":
        var = jnp.sum(benign_w * (mf - mu) ** 2, axis=0, keepdims=True) / n_benign
        attacked = mu - cfg.alie_zmax * jnp.sqrt(var)
    else:
        raise ValueError(f"unknown attack {name!r}")
    return jnp.where(mal, jnp.broadcast_to(attacked, mf.shape).astype(models.dtype),
                     models)


# Adaptive (defense-aware) attacks: consume the DefenseView.
ADAPTIVE_ATTACKS = ("band_rider", "min_max")

# THE attack registry: every attack-choice surface (engine configs, CLI
# --attack flags, the robustness matrix, benchmark tables) derives its
# choices from this tuple — do not re-enumerate attack names elsewhere.
ATTACK_NAMES = ("none", "noise", "sign_flip", "label_flip",
                "ipm_0.5", "ipm_100", "ipm", "alie") + ADAPTIVE_ATTACKS
