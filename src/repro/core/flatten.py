"""Pytree <-> flat-vector utilities.

All aggregation rules in this library operate on flat parameter/update
vectors (the paper's theta_j in R^d).  Models are pytrees; these helpers
bridge the two representations without copying more than once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def tree_ravel(tree):
    """Flatten a pytree to (vec, unravel_fn)."""
    return ravel_pytree(tree)


def tree_stack_ravel(trees):
    """Stack a list of pytrees into a (K, d) matrix + shared unravel fn."""
    vecs = []
    unravel = None
    for t in trees:
        v, unravel = ravel_pytree(t)
        vecs.append(v)
    return jnp.stack(vecs), unravel


def vmap_ravel(batched_tree):
    """Ravel a pytree whose leaves carry a leading axis K -> (K, d).

    Returns (mat, unravel_one) where unravel_one maps a single (d,) vector
    back to an unbatched pytree.
    """
    one = jax.tree.map(lambda x: x[0], batched_tree)
    _, unravel_one = ravel_pytree(one)
    mat = jax.vmap(lambda t: ravel_pytree(t)[0])(batched_tree)
    return mat, unravel_one


def tree_size(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))
