"""Evaluation metrics (paper Section V-C)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def micro_accuracy(logits: Array, labels: Array) -> Array:
    """Micro-averaged multi-class accuracy = total TP / |D_test| (Eq. 6)."""
    pred = jnp.argmax(logits, axis=-1)
    return jnp.mean((pred == labels).astype(jnp.float32))


def r_squared(vectors: Array, weights: Array | None = None) -> Array:
    """Multivariate R^2 consistency metric (Eq. 7).

    R^2 = 1 - SSR/SST with
      SSR = sum_i ||v_i - mean||^2   (dispersion around the mean vector)
      SST = sum_i ||v_i||^2          (normalizer)

    Applied to the flat local models of the *benign* nodes: ~1 means the
    decentralized models have converged to a consensus.  ``weights``
    selects the cohort with a TRACED (0/1) mask instead of boolean
    indexing — dynamic Byzantine sets can't be indexed under jit.
    """
    if weights is None:
        vbar = jnp.mean(vectors, axis=0)
        ssr = jnp.sum((vectors - vbar[None, :]) ** 2)
        sst = jnp.sum(vectors**2)
    else:
        w = weights.astype(vectors.dtype)
        n = jnp.maximum(w.sum(), 1.0)
        vbar = jnp.einsum("n,nd->d", w, vectors) / n
        ssr = jnp.sum(w[:, None] * (vectors - vbar[None, :]) ** 2)
        sst = jnp.sum(w[:, None] * vectors**2)
    return 1.0 - ssr / jnp.maximum(sst, 1e-12)


def consensus_distance(vectors: Array) -> Array:
    """Mean squared distance to the cohort mean (complementary to R^2)."""
    vbar = jnp.mean(vectors, axis=0)
    return jnp.mean(jnp.sum((vectors - vbar[None, :]) ** 2, axis=-1))


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean softmax cross-entropy with integer labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
