"""DFL communication topologies (paper Section V-A).

The paper models the network as an undirected graph; experiments use a
20-node 8-regular ring lattice (Watts-Strogatz with rewiring p=0), 10%
malicious nodes placed so every node has at most 25% malicious neighbors.

Irregular graphs (erdos_renyi, or any hand-built adjacency) are
represented with a PADDED neighbor table: ``neighbor_indices`` is
(N, K_max) where padded slots repeat the node's own index (a safe row to
DMA — the self model is always finite) and ``neighbor_valid`` marks the
real edges.  The gather-free aggregation kernels and the WFAgg mask
logic honor the valid mask, so per-node degrees may differ freely —
including degree 0 (a churned-out node gets an all-invalid row and the
aggregation falls back to its own model; see robust_stats' empty-median
guard).

Dynamic topologies are a SCHEDULE of padded tables: ``TopologySchedule``
stacks one (N, K) neighbor table + valid mask + malicious mask per round
(K = the max degree over ALL rounds, so every round shares one shape and
a jitted round function compiles exactly once).  ``dfl.dynamics`` builds
schedules from composable scenario generators (churn, link failure,
partition, mobility, sleeper attackers).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    n_nodes: int
    adjacency: np.ndarray          # (N, N) bool, symmetric, no self-loops
    neighbor_indices: np.ndarray   # (N, K) int32 - padded to the max degree
    malicious: np.ndarray          # (N,) bool
    neighbor_valid: np.ndarray = None   # (N, K) bool - False on padded slots

    def __post_init__(self):
        if self.neighbor_valid is None:
            object.__setattr__(
                self, "neighbor_valid",
                np.ones(self.neighbor_indices.shape, dtype=bool))

    @property
    def degree(self) -> int:
        """Neighbor-table width K (= max degree for irregular graphs)."""
        return int(self.neighbor_indices.shape[1])

    @property
    def degrees(self) -> np.ndarray:
        """Per-node true degree (valid neighbor count)."""
        return self.neighbor_valid.sum(axis=1)

    @property
    def is_regular(self) -> bool:
        return bool(self.neighbor_valid.all())

    def malicious_neighbor_count(self) -> np.ndarray:
        """Per node, how many of its neighbors are malicious."""
        return (self.adjacency & self.malicious[None, :]).sum(axis=1)


def ring_lattice(n: int, degree: int) -> np.ndarray:
    """c-regular ring lattice (Watts-Strogatz p=0): each node connects to
    its degree/2 nearest neighbors on each side."""
    if degree % 2 != 0:
        raise ValueError("ring lattice degree must be even")
    if degree >= n:
        raise ValueError("degree must be < n")
    adj = np.zeros((n, n), dtype=bool)
    half = degree // 2
    for i in range(n):
        for off in range(1, half + 1):
            j = (i + off) % n
            adj[i, j] = adj[j, i] = True
    return adj


def complete_graph(n: int) -> np.ndarray:
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def erdos_renyi(n: int, p: float, seed: int = 0, min_degree: int = 1) -> np.ndarray:
    """Random G(n, p) graph, patched to ensure min_degree (adds ring edges).

    ``min_degree=0`` skips the patching and may leave isolated nodes —
    the padded-table path represents those as all-invalid rows and the
    aggregation keeps their local model (mobility scenarios use this).
    """
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, 1)
    adj = adj | adj.T
    # guarantee connectivity floor with a ring
    if min_degree > 0:
        for i in range(n):
            if adj[i].sum() < min_degree:
                adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    return adj


def spaced_malicious(n: int, n_mal: int) -> np.ndarray:
    """Evenly spaced malicious placement.

    For the paper's 20-node/2-malicious 8-regular setup this reproduces the
    'at most 25% malicious neighbors' property (and matches Fig. 7's nodes
    5 and 11 up to rotation).
    """
    mal = np.zeros(n, dtype=bool)
    if n_mal > 0:
        idx = (np.arange(n_mal) * n) // n_mal + n // (2 * max(n_mal, 1))
        mal[idx % n] = True
    return mal


def close_malicious(n: int, n_mal: int, degree: int = 8) -> np.ndarray:
    """Malicious nodes placed degree/2 apart on the ring so that some
    benign nodes see 0, some 1 and some 2 malicious neighbors — this is
    the placement that populates every 'decentralized m.n.' column of the
    paper's Table I (with spaced placement no node ever has 2)."""
    mal = np.zeros(n, dtype=bool)
    step = max(1, degree // 2)
    for i in range(n_mal):
        mal[(i * step) % n] = True
    return mal


def padded_neighbor_table(adj: np.ndarray, width: int = None):
    """(table (N, K_max) int32, valid (N, K_max) bool) for ANY graph.

    Padded slots carry the node's OWN index: the indexed aggregation
    kernels DMA that row like any other candidate (always a finite,
    in-bounds address) and the valid mask excludes it from every
    median/mask/score computation downstream.  Degree-0 rows (a fully
    churned-out node) come back all-invalid and all-self — still a safe
    DMA target, and the valid-aware aggregation keeps the local model.

    ``width`` forces the table to a wider K than this graph needs — the
    schedule builders use it so every round of a dynamic topology shares
    ONE (N, K) shape (no retrace when the graph changes).
    """
    n = adj.shape[0]
    degs = adj.sum(axis=1).astype(np.int64)
    k_max = max(1, int(degs.max()))
    if width is not None:
        if width < k_max:
            raise ValueError(f"width {width} < max degree {k_max}")
        k_max = max(1, int(width))
    table = np.empty((n, k_max), dtype=np.int32)
    valid = np.zeros((n, k_max), dtype=bool)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        table[i, : len(nbrs)] = nbrs
        table[i, len(nbrs):] = i
        valid[i, : len(nbrs)] = True
    return table, valid


# ---------------------------------------------------------------------------
# topology schedules (dynamic graphs, one entry per gossip round)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A round-indexed stack of padded neighbor tables + Byzantine masks.

    Every round is padded to ONE common width K (the max degree over all
    rounds), so the whole schedule is scan-friendly: a jitted round
    function that takes ``(neighbor_idx[r], valid[r], malicious[r])`` as
    traced inputs compiles once and runs every round, however the graph
    changes.  Built by ``schedule_from_adjacencies`` (or the scenario
    generators in ``repro.dfl.dynamics``).
    """

    neighbor_idx: np.ndarray   # (R, N, K) int32, padded with self
    valid: np.ndarray          # (R, N, K) bool, False on padded slots
    malicious: np.ndarray      # (R, N) bool - per-round Byzantine set
    adjacency: np.ndarray      # (R, N, N) bool - kept for eval/diffing

    @property
    def rounds(self) -> int:
        return int(self.neighbor_idx.shape[0])

    @property
    def n_nodes(self) -> int:
        return int(self.neighbor_idx.shape[1])

    @property
    def width(self) -> int:
        """Common table width K (= max degree over all rounds)."""
        return int(self.neighbor_idx.shape[2])

    def degrees(self) -> np.ndarray:
        """(R, N) true per-round per-node degree."""
        return self.valid.sum(axis=2)

    def degree_stats(self) -> np.ndarray:
        """(R, 3) per-round [min, mean, max] degree."""
        d = self.degrees()
        return np.stack([d.min(axis=1), d.mean(axis=1), d.max(axis=1)],
                        axis=1)

    def diff(self) -> np.ndarray:
        """(R-1, 2) undirected edges [added, removed] at each transition —
        the round-over-round graph churn a scenario realizes."""
        a = np.triu(self.adjacency, 1)
        added = (~a[:-1] & a[1:]).sum(axis=(1, 2))
        removed = (a[:-1] & ~a[1:]).sum(axis=(1, 2))
        return np.stack([added, removed], axis=1)


def schedule_from_adjacencies(adjs: np.ndarray,
                              malicious: np.ndarray) -> TopologySchedule:
    """Pad a (R, N, N) adjacency stack into a ``TopologySchedule``.

    All rounds share one table width (the max degree over the whole
    schedule) so the downstream jitted round function never retraces.
    ``malicious`` may be static (N,) or per-round (R, N).
    """
    adjs = np.asarray(adjs, dtype=bool)
    R, n, _ = adjs.shape
    mal = np.asarray(malicious, dtype=bool)
    if mal.ndim == 1:
        mal = np.broadcast_to(mal, (R, n)).copy()
    if mal.shape != (R, n):
        raise ValueError(f"malicious shape {mal.shape} != {(R, n)}")
    k_max = max(1, int(adjs.sum(axis=2).max()))
    tables, valids = [], []
    for r in range(R):
        t, v = padded_neighbor_table(adjs[r], width=k_max)
        tables.append(t)
        valids.append(v)
    return TopologySchedule(
        neighbor_idx=np.stack(tables), valid=np.stack(valids),
        malicious=mal, adjacency=adjs)


def static_schedule(topo: Topology, rounds: int) -> TopologySchedule:
    """The trivial schedule: the same graph + malicious set every round."""
    adjs = np.broadcast_to(topo.adjacency, (rounds,) + topo.adjacency.shape)
    return schedule_from_adjacencies(adjs, topo.malicious)


def make_topology(
    n_nodes: int = 20,
    degree: int = 8,
    n_malicious: int = 2,
    kind: str = "ring",
    seed: int = 0,
    placement: str = "spaced",    # spaced | close
) -> Topology:
    if kind == "ring":
        adj = ring_lattice(n_nodes, degree)
    elif kind == "complete":
        adj = complete_graph(n_nodes)
        degree = n_nodes - 1
    elif kind == "erdos_renyi":
        adj = erdos_renyi(n_nodes, degree / (n_nodes - 1), seed=seed)
    else:
        raise ValueError(f"unknown topology kind {kind!r}")
    mal = (close_malicious(n_nodes, n_malicious, degree)
           if placement == "close" else spaced_malicious(n_nodes, n_malicious))
    table, valid = padded_neighbor_table(adj)
    return Topology(n_nodes=n_nodes, adjacency=adj, neighbor_indices=table,
                    malicious=mal, neighbor_valid=valid)


def paper_topology() -> Topology:
    """The paper's validation scenario: 20 nodes, 8-regular ring, 2 malicious."""
    return make_topology(n_nodes=20, degree=8, n_malicious=2, kind="ring")
