"""WFAgg trust-weight derivation from O(K) sufficient statistics.

This is the scoring stage of WFAgg (Alg. 1 lines 9-22 + the valid-aware
filter masks) factored out of ``core.wfagg`` so that it can run in TWO
places off the exact same code:

  * on the host, between the stats and combine kernel launches of the
    two-launch fused path (``core.wfagg._wfagg_batch_indexed``), vmapped
    over the N receiving nodes;
  * INSIDE the single-launch round kernel
    (``kernels.robust_stats.kernel._wfagg_round_indexed_kernel``), at the
    phase boundary, on the VMEM-resident ``(1, K)`` accumulators of one
    node — which is what lets the kernel derive the WFAgg-E weights and
    combine without a host round-trip.

Everything here is O(K)/O(K^2) plain-jnp logic on tiny arrays; the only
import from the kernels package is the ``RobustStats`` container (pure
data, no Pallas), so the kernel body can import this module without a
cycle.  The WFAgg-T thresholds are NOT derived here — the EWMA bands
depend on the (W, K) metric history, which lives outside the kernel, so
callers precompute them with ``temporal_bands`` and the decision reduces
to four compares against the kernel's own temporal statistics
(bit-identical to ``wfagg_t_decide``'s in-band test).

``cfg`` arguments are duck-typed ``core.wfagg.WFAggConfig`` instances,
and ``stats`` arguments are duck-typed ``kernels.robust_stats.ref.
RobustStats`` containers (read-only attribute access) — this module
imports from NEITHER package, which is what keeps it importable from
both sides (``core.wfagg`` and the kernel body) without a cycle.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg

Array = jax.Array
RobustStats = Any   # duck-typed: .dist2/.norm2/.cosine_to_median()/...
_EPS = 1e-12


# ---------------------------------------------------------------------------
# scoring + EWMA primitives (moved from core.wfagg; re-exported there)
# ---------------------------------------------------------------------------

def wfagg_scores(mask_d: Array, mask_c: Array, mask_t: Array, cfg) -> Array:
    """Alg. 1 lines 9-22: tau-weighted filter votes with a 2-filter floor."""
    w = (
        cfg.tau1 * mask_d.astype(jnp.float32)
        + cfg.tau2 * mask_c.astype(jnp.float32)
        + cfg.tau3 * mask_t.astype(jnp.float32)
    )
    return jnp.where(w < cfg.accept_threshold - 1e-9, 0.0, w)


def ewma_mean_std(hist: Array, count: Array, decay: float) -> Tuple[Array, Array]:
    """Exponentially weighted mean/std over a ring buffer hist (W, K).

    hist[0] is the most recent entry.  Entries beyond ``count`` are masked.
    """
    W = hist.shape[0]
    ages = jnp.arange(W, dtype=jnp.float32)
    valid = ages < count.astype(jnp.float32)
    w = jnp.where(valid, decay ** ages, 0.0)
    w = w / jnp.maximum(w.sum(), _EPS)
    mu = jnp.einsum("w,wk->k", w, hist)
    var = jnp.einsum("w,wk->k", w, (hist - mu[None, :]) ** 2)
    return mu, jnp.sqrt(jnp.maximum(var, 0.0))


def push_history(hist_s: Array, hist_b: Array, count: Array, t: Array,
                 s_t: Array, b_t: Array) -> Tuple[Array, Array, Array, Array]:
    """WFAgg-T ring-buffer advance (most recent at index 0, count capped
    at the window) — the state half of the Alg. 4 decision, single-
    sourced so every backend updates the history identically."""
    hist_s = jnp.roll(hist_s, 1, axis=0).at[0].set(s_t)
    hist_b = jnp.roll(hist_b, 1, axis=0).at[0].set(b_t)
    return hist_s, hist_b, jnp.minimum(count + 1, hist_s.shape[0]), t + 1


def temporal_bands(hist_s: Array, hist_b: Array, count: Array, t: Array,
                   cfg) -> Array:
    """Precompute the WFAgg-T acceptance bands as a FLAT (4K,) vector
    ``[lo_d | hi_d | lo_c | hi_c]`` (the kernel reshapes to (4, K)).

    Encodes ``wfagg_t_decide``'s whole decision: a candidate passes iff
    ``lo_d <= s_t <= hi_d`` and ``lo_c <= b_t <= hi_c``.  The transient /
    empty-history gate folds into the bands themselves — inactive rounds
    get ``(+inf, -inf)`` bands no finite metric can satisfy — so the
    in-kernel test is four compares with no extra flag input.  The band
    edges are the exact ``mu -/+ sd`` expressions of the decision core,
    so masks agree bit-for-bit with the host path.  (Flat rather than
    (4, K): the vmapped per-node bands must not materialize any 3-D
    O(K)-sized buffer — the round's (N, K, d)-free HLO assertions grep
    by rank, and K can collide with the literal 4.)
    """
    mu_d, sd_d = ewma_mean_std(hist_s, count, cfg.ewma_decay)
    mu_c, sd_c = ewma_mean_std(hist_b, count, cfg.ewma_decay)
    active = (t > cfg.transient) & (count > 0)
    inf = jnp.float32(jnp.inf)
    return jnp.concatenate([
        jnp.where(active, mu_d - sd_d, inf),
        jnp.where(active, mu_d + sd_d, -inf),
        jnp.where(active, mu_c - sd_c, inf),
        jnp.where(active, mu_c + sd_c, -inf),
    ])


# ---------------------------------------------------------------------------
# Gram expansions
# ---------------------------------------------------------------------------

def sq_dists_from_gram(gram: Array, norm2: Array) -> Array:
    """(K, K) squared distances from a Gram matrix + squared norms."""
    d2 = norm2[..., :, None] + norm2[..., None, :] - 2.0 * gram
    K = gram.shape[-1]
    d2 = d2 * (1.0 - jnp.eye(K, dtype=d2.dtype))
    return jnp.maximum(d2, 0.0)


def cosine_dist_from_gram(gram: Array, norm2: Array) -> Array:
    """(K, K) cosine distance matrix from a Gram matrix + squared norms."""
    n = jnp.sqrt(jnp.maximum(norm2, _EPS))
    return 1.0 - gram / jnp.maximum(n[..., :, None] * n[..., None, :], _EPS)


def needs_gram(cfg) -> bool:
    """True when an Alt-WFAgg filter consumes the (K, K) candidate Gram."""
    return cfg.distance_filter == "multi_krum" or cfg.similarity_filter == "clustering"


# ---------------------------------------------------------------------------
# filter masks from sufficient statistics (single node, (K,)-shaped)
# ---------------------------------------------------------------------------

def fused_distance_mask(stats: RobustStats, gram: Optional[Array],
                        cfg) -> Array:
    K = stats.dist2.shape[-1]
    if cfg.distance_filter == "wfagg_d":
        return agg.smallest_k_mask(stats.dist2, K - int(cfg.f) - 1)
    if cfg.distance_filter == "multi_krum":
        scores = agg.krum_scores_from_sq_dists(
            sq_dists_from_gram(gram, stats.norm2), cfg.f)
        m = cfg.multi_krum_m or max(1, K // 4)
        return agg.smallest_k_mask(scores, m)
    raise ValueError(f"unknown distance filter {cfg.distance_filter!r}")


def fused_similarity_mask(stats: RobustStats, gram: Optional[Array],
                          cfg) -> Array:
    K = stats.dist2.shape[-1]
    if cfg.similarity_filter == "wfagg_c":
        # cosine to the median model is invariant to the norm clipping of
        # Alg. 3, so the fused filter ranks the kernel's dot/norm stats
        # directly — same selection as wfagg_c_select.
        return agg.smallest_k_mask(stats.cosine_to_median(), K - int(cfg.f) - 1)
    if cfg.similarity_filter == "clustering":
        return agg.clustering_select_from_dist(
            cosine_dist_from_gram(gram, stats.norm2))
    raise ValueError(f"unknown similarity filter {cfg.similarity_filter!r}")


def fused_distance_mask_valid(stats: RobustStats, gram: Optional[Array],
                              valid: Array, cfg) -> Array:
    """Valid-aware distance mask for one node of a padded (irregular)
    slate: keep counts scale with the node's TRUE degree v (traced), and
    padded slots score +inf so they can never be selected.  Bit-identical
    to ``fused_distance_mask`` when every slot is valid."""
    K = stats.dist2.shape[-1]
    v = valid.sum()
    if cfg.distance_filter == "wfagg_d":
        scores = jnp.where(valid, stats.dist2, jnp.inf)
        return agg.smallest_k_mask_dyn(scores, v - int(cfg.f) - 1)
    if cfg.distance_filter == "multi_krum":
        d2 = sq_dists_from_gram(gram, stats.norm2)
        vpair = valid[:, None] & valid[None, :]
        scores = agg.krum_scores_from_sq_dists_dyn(
            jnp.where(vpair, d2, jnp.inf), cfg.f, v)
        m = cfg.multi_krum_m or max(1, K // 4)
        return agg.smallest_k_mask_dyn(
            jnp.where(valid, scores, jnp.inf), jnp.minimum(m, v))
    raise ValueError(f"unknown distance filter {cfg.distance_filter!r}")


def fused_similarity_mask_valid(stats: RobustStats, gram: Optional[Array],
                                valid: Array, cfg) -> Array:
    """Valid-aware similarity mask (see ``fused_distance_mask_valid``)."""
    v = valid.sum()
    if cfg.similarity_filter == "wfagg_c":
        scores = jnp.where(valid, stats.cosine_to_median(), jnp.inf)
        return agg.smallest_k_mask_dyn(scores, v - int(cfg.f) - 1)
    if cfg.similarity_filter == "clustering":
        return agg.clustering_select_from_dist_dyn(
            cosine_dist_from_gram(gram, stats.norm2), valid)
    raise ValueError(f"unknown similarity filter {cfg.similarity_filter!r}")


# ---------------------------------------------------------------------------
# the full scoring stage: stats -> (masks, trust weights, combine coeffs)
# ---------------------------------------------------------------------------

def derive_trust_weights(
    stats: RobustStats,
    gram: Optional[Array],
    valid: Array,          # (K,) float32, 1.0 on real edges
    tbands: Optional[Array],   # (4, K) from temporal_bands, or None
    cfg,
) -> Tuple[Array, Array, Array, Array]:
    """One node's WFAgg scoring stage: (mask_d, mask_c, mask_t, weights).

    Pure O(K)/O(K^2) logic on the sufficient statistics — THE shared code
    between the host path and the in-kernel phase boundary.  ``weights``
    already carries the valid mask (padded slots weigh 0), so a degree-0
    node scores an all-zero vector and the combine falls back to its
    local model.
    """
    valid_b = valid.astype(bool)
    mask_d = fused_distance_mask_valid(stats, gram, valid_b, cfg)
    mask_c = fused_similarity_mask_valid(stats, gram, valid_b, cfg)
    if tbands is None:
        mask_t = jnp.zeros(valid_b.shape, dtype=bool)
    else:
        s_t = stats.prev_dist2
        b_t = stats.cosine_to_prev()
        mask_t = ((s_t >= tbands[0]) & (s_t <= tbands[1])
                  & (b_t >= tbands[2]) & (b_t <= tbands[3]) & valid_b)
    weights = wfagg_scores(mask_d, mask_c, mask_t, cfg) * valid.astype(jnp.float32)
    return mask_d, mask_c, mask_t, weights


def combine_coefficients(weights: Array, alpha: float, valid: Array,
                         mean_fallback: bool) -> Tuple[Array, Array]:
    """Normalize trust weights into the WFAgg-E combine coefficients:
    returns ``(alpha_eff * w_norm (K,), 1 - alpha_eff ())``, matching the
    host-side preparation of the two-launch combine kernel bit-for-bit.

    ``mean_fallback=True`` is the mode-B (robust all-reduce) convention:
    when every candidate is rejected the combine degrades to the uniform
    mean of the VALID candidates (there is no meaningful "local" model on
    a gradient all-reduce); False is the DFL/Eq. 3 convention — the node
    keeps its local model.
    """
    wsum = weights.sum()
    w_norm = weights / jnp.maximum(wsum, _EPS)
    if mean_fallback:
        vsum = valid.sum()
        uniform = valid / jnp.maximum(vsum, 1.0)
        w_norm = jnp.where(wsum > 0, w_norm, uniform)
        # an all-invalid (degree-0) slate has no mean to fall back to
        # either: keep the local anchor rather than emitting zeros
        eff_alpha = jnp.where(vsum > 0, alpha, 0.0).astype(jnp.float32)
    else:
        eff_alpha = jnp.where(wsum > 0, alpha, 0.0).astype(jnp.float32)
    return eff_alpha * w_norm, 1.0 - eff_alpha
