"""WFAgg: the paper's Byzantine-robust aggregation algorithm (Section IV).

Components (each maps to a paper algorithm):
  wfagg_d_select   Alg. 2 - distance filter around the coordinate-wise median
  wfagg_c_select   Alg. 3 - cosine-similarity filter with norm clipping
  wfagg_t_select   Alg. 4 - temporal EWMA filter over round-to-round metrics
  wfagg_e          Eq. 3  - exponential-smoothing weighted aggregation
  wfagg            Alg. 1 - full pipeline: 3 filters -> tau-weighted scoring
                   (accept needs >= 2 filters) -> WFAgg-E aggregation
  alt_wfagg        paper SsVI-B2 - same scoring, with Multi-Krum as the
                   distance filter and Clustering as the similarity filter

All selectors take ``updates: (K, d)`` and return boolean masks ``(K,)``;
everything is jit/vmap-safe with static K, so the same code runs per-node
in the mode-A DFL engine and (chunked) inside the mode-B multi-pod
training step.

Execution backends (``WFAggConfig.backend``):
  reference  the plain-jnp pipeline above — each filter reads the (K, d)
             candidate matrix again (~7 full passes per aggregation).
             With a ``valid`` mask it runs the valid-aware dynamic-count
             variant (the oracle for irregular/dynamic topologies).
  fused      the Pallas path.  On the gather-free indexed batch entry
             this is the SINGLE-LAUNCH round kernel: one pallas_call
             streams the neighbor blocks, accumulates every filter
             statistic, derives the WFAgg-E trust weights at an
             in-kernel phase boundary (``core.trust``), and writes the
             trust-weighted combine — ~1 candidate pass per round.  On
             single-node / gathered entries it is the stats-kernel +
             host-scoring + combine pipeline (2 passes).
  fused_two_launch
             forces the two-launch shape on the indexed entry as well
             (stats launch, host scoring, combine launch) — the parity
             fallback for validating the single-launch kernel.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg
from repro.core import trust
from repro.kernels.pairwise_dist.ops import pairwise_gram
from repro.kernels.robust_stats.ops import (
    robust_stats, robust_stats_batch, robust_stats_indexed,
    wfagg_round_indexed)
from repro.kernels.robust_stats.ref import RobustStats, robust_stats_indexed_ref
from repro.kernels.weighted_agg.ops import weighted_agg, weighted_agg_indexed

Array = jax.Array
_EPS = 1e-12

# Fused execution backends: "fused" routes the gather-free indexed path
# through the SINGLE-LAUNCH round kernel (stats + in-kernel weight
# derivation + combine in one pallas_call); "fused_two_launch" keeps the
# separate stats and combine launches with the scoring stage on the host
# — the parity fallback (and the shape every non-indexed fused entry
# still uses, where no single-launch op exists).
_FUSED_BACKENDS = ("fused", "fused_two_launch")


@dataclasses.dataclass(frozen=True)
class WFAggConfig:
    """Hyper-parameters (defaults = paper Section V-A)."""

    f: int = 2                  # estimated number of malicious candidates
    tau1: float = 0.4           # weight of the distance filter (WFAgg-D)
    tau2: float = 0.4           # weight of the similarity filter (WFAgg-C)
    tau3: float = 0.2           # weight of the temporal filter (WFAgg-T)
    alpha: float = 0.8          # WFAgg-E smoothing factor
    window: int = 3             # W - temporal window length
    transient: int = 3          # T_th - rounds before WFAgg-T activates
    ewma_decay: float = 0.5     # lambda of the exponentially weighted window
    use_temporal: bool = True   # disable to drop the (K, d) prev-update state
    # Alt-WFAgg: swap in SOTA filters of the same family.
    distance_filter: str = "wfagg_d"     # or "multi_krum"
    similarity_filter: str = "wfagg_c"   # or "clustering"
    multi_krum_m: Optional[int] = None   # Multi-Krum m (default K//4)
    # Execution backend: "fused" (Pallas filter bank; the gather-free
    # indexed batch runs the SINGLE-LAUNCH round kernel),
    # "fused_two_launch" (separate stats + combine launches — parity
    # fallback), or "reference" (plain-jnp multi-pass pipeline).  Same
    # masks/aggregate up to float tolerance; see memory_passes().
    backend: str = "fused"
    # Non-finite payload sanitizer (chaos transport, dfl/faults.py): a
    # NaN/Inf candidate row is zeroed and its edges demoted to invalid
    # BEFORE any filter statistic on every backend — the indexed
    # kernel's median/mean must never see a NaN (0 * NaN = NaN would
    # otherwise leak through even a zero combine weight).  A no-op on
    # finite inputs (bit-exact), so it defaults on.
    sanitize: bool = True

    @property
    def accept_threshold(self) -> float:
        """A model must be accepted by >= 2 filters (Alg. 1 line 19)."""
        pairs = (self.tau1 + self.tau2, self.tau1 + self.tau3, self.tau2 + self.tau3)
        return min(pairs)


class TemporalState(NamedTuple):
    """Per-receiving-node WFAgg-T state (Alg. 4).

    Each node stores only the last model per neighbor plus a ring buffer of
    the last W distance/cosine metrics (paper: 'Each node only needs to
    store the history of the distance metrics and only the last model sent
    by each neighboring node').
    """

    prev: Array      # (K, d)  last update from each neighbor
    hist_s: Array    # (W, K)  ring buffer of squared-distance metrics
    hist_b: Array    # (W, K)  ring buffer of cosine-distance metrics
    count: Array     # ()      number of metric rounds recorded so far
    t: Array         # ()      current round index


def init_temporal_state(K: int, d: int, window: int, dtype=jnp.float32) -> TemporalState:
    return TemporalState(
        prev=jnp.zeros((K, d), dtype=dtype),
        hist_s=jnp.zeros((window, K), dtype=jnp.float32),
        hist_b=jnp.zeros((window, K), dtype=jnp.float32),
        count=jnp.zeros((), dtype=jnp.int32),
        t=jnp.zeros((), dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# Filters
# ---------------------------------------------------------------------------

def wfagg_d_select(updates: Array, f: int) -> Array:
    """Alg. 2: keep the K-f-1 candidates closest (L2) to the median model."""
    K = updates.shape[0]
    med = agg.coordinate_median(updates)
    d2 = jnp.sum((updates - med[None, :]) ** 2, axis=-1)
    return agg.smallest_k_mask(d2, K - int(f) - 1)


def wfagg_c_stats(updates: Array) -> Tuple[Array, Array]:
    """Cosine distances of norm-clipped candidates to the median model.

    Returns (alpha_j (K,), clipped updates (K, d)).  Note that positive
    rescaling cannot change a cosine, so clipping affects downstream
    magnitude only — selection matches the paper's Alg. 3 either way.
    """
    med = agg.coordinate_median(updates)
    norms = jnp.linalg.norm(updates, axis=-1)
    tau_med = jnp.median(norms)
    scale = jnp.minimum(1.0, tau_med / jnp.maximum(norms, _EPS))
    clipped = updates * scale[:, None]
    med_n = jnp.linalg.norm(med)
    cnorms = jnp.linalg.norm(clipped, axis=-1)
    cos = (clipped @ med) / jnp.maximum(cnorms * med_n, _EPS)
    return 1.0 - cos, clipped


def wfagg_c_select(updates: Array, f: int) -> Array:
    """Alg. 3: keep the K-f-1 candidates with smallest cosine distance."""
    K = updates.shape[0]
    alpha_j, _ = wfagg_c_stats(updates)
    return agg.smallest_k_mask(alpha_j, K - int(f) - 1)


# EWMA over a (W, K) ring buffer — single-sourced in core.trust (the
# single-launch kernel's band precomputation shares it).
_ewma_mean_std = trust.ewma_mean_std


def wfagg_t_decide(hist_s: Array, hist_b: Array, count: Array, t: Array,
                   s_t: Array, b_t: Array, cfg: WFAggConfig):
    """Alg. 4 decision core on precomputed round-over-round metrics.

    Factored out so callers that compute s_t/b_t elsewhere (the sharded
    per-leaf aggregation path computes them exactly from each worker's own
    previous gradient) share the EWMA thresholds and ring-buffer update.
    Returns (mask, hist_s', hist_b', count', t')."""
    mu_d, sd_d = _ewma_mean_std(hist_s, count, cfg.ewma_decay)
    mu_c, sd_c = _ewma_mean_std(hist_b, count, cfg.ewma_decay)

    in_d = (s_t >= mu_d - sd_d) & (s_t <= mu_d + sd_d)
    in_c = (b_t >= mu_c - sd_c) & (b_t <= mu_c + sd_c)
    active = (t > cfg.transient) & (count > 0)
    mask = jnp.where(active, in_d & in_c, jnp.zeros_like(in_d))
    return (mask, *trust.push_history(hist_s, hist_b, count, t, s_t, b_t))


def wfagg_t_select(state: TemporalState, updates: Array, cfg: WFAggConfig) -> Tuple[Array, TemporalState]:
    """Alg. 4: flag updates whose round-over-round change is abrupt.

    Returns (mask, new_state).  During the transient (t <= T_th) no model is
    classified benign by this filter (T3 = empty set), but metric history is
    still accumulated so the window is warm when the filter activates.
    """
    prev = state.prev
    # Both backends share this elementwise metric pass: standalone WFAgg-T
    # is already single-pass in jnp, so launching the robust_stats kernel
    # here would pay the sorting network for outputs nobody reads.  The
    # fused gain for the temporal metrics comes from the FULL wfagg
    # pipeline, where _wfagg_fused folds them into the shared kernel pass.
    s_t = jnp.sum((updates - prev) ** 2, axis=-1)
    num = jnp.sum(updates * prev, axis=-1)
    den = jnp.maximum(
        jnp.linalg.norm(updates, axis=-1) * jnp.linalg.norm(prev, axis=-1), _EPS
    )
    b_t = 1.0 - num / den

    mask, hist_s, hist_b, count, t = wfagg_t_decide(
        state.hist_s, state.hist_b, state.count, state.t, s_t, b_t, cfg)
    new_state = TemporalState(prev=updates, hist_s=hist_s, hist_b=hist_b,
                              count=count, t=t)
    return mask, new_state


# ---------------------------------------------------------------------------
# Scoring + aggregation
# ---------------------------------------------------------------------------

# Alg. 1 lines 9-22 scoring — single-sourced in core.trust so the
# in-kernel weight derivation of the single-launch round runs it too.
wfagg_scores = trust.wfagg_scores


def wfagg_e(local: Array, updates: Array, weights: Array, alpha: float) -> Array:
    """Eq. 3: theta_i <- (1-a)*theta_i + a * sum_j w'_ij theta_j.

    If every neighbor was rejected (sum w = 0) the node keeps its local
    model (the neighbor term vanishes rather than dividing by zero).
    """
    wsum = weights.sum()
    w_norm = weights / jnp.maximum(wsum, _EPS)
    neighbor = jnp.einsum("k,kd->d", w_norm, updates)
    eff_alpha = jnp.where(wsum > 0, alpha, 0.0)
    return (1.0 - eff_alpha) * local + eff_alpha * neighbor


def _distance_mask(updates: Array, cfg: WFAggConfig) -> Array:
    if cfg.distance_filter == "wfagg_d":
        return wfagg_d_select(updates, cfg.f)
    if cfg.distance_filter == "multi_krum":
        K = updates.shape[0]
        m = cfg.multi_krum_m or max(1, K // 4)
        scores = agg.krum_scores(updates, cfg.f)
        return agg.smallest_k_mask(scores, m)
    raise ValueError(f"unknown distance filter {cfg.distance_filter!r}")


def _similarity_mask(updates: Array, cfg: WFAggConfig) -> Array:
    if cfg.similarity_filter == "wfagg_c":
        return wfagg_c_select(updates, cfg.f)
    if cfg.similarity_filter == "clustering":
        return agg.clustering_select(updates)
    raise ValueError(f"unknown similarity filter {cfg.similarity_filter!r}")


# ---------------------------------------------------------------------------
# fused backend: one-pass filter bank on the robust_stats Pallas kernel
# ---------------------------------------------------------------------------
# The mask derivations live in ``core.trust`` — pure O(K)/O(K^2) logic on
# the kernel's sufficient statistics, shared verbatim with the in-kernel
# phase boundary of the single-launch round (the aliases keep this
# module's historical private names working).

_sq_dists_from_gram = trust.sq_dists_from_gram
_cosine_dist_from_gram = trust.cosine_dist_from_gram
_fused_distance_mask = trust.fused_distance_mask
_fused_similarity_mask = trust.fused_similarity_mask
_fused_distance_mask_valid = trust.fused_distance_mask_valid
_fused_similarity_mask_valid = trust.fused_similarity_mask_valid
_needs_gram = trust.needs_gram


def _wfagg_fused(
    local: Array,
    updates: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
) -> Tuple[Array, Optional[TemporalState], dict]:
    """Single-node fused WFAgg: every filter statistic from ONE read of the
    candidates (robust_stats kernel; + the pairwise Gram kernel when the
    Alt-WFAgg filters need the (K, K) distances), one more read for the
    fused WFAgg-E combine."""
    temporal = cfg.use_temporal and state is not None
    prev = state.prev if temporal else None
    # need_center=False: the filter bank consumes only the O(K)
    # accumulators, so the kernel skips its (D,)-sized median/trim writes
    stats = robust_stats(updates, prev=prev, need_center=False)
    gram = pairwise_gram(updates)[0] if _needs_gram(cfg) else None
    mask_d = _fused_distance_mask(stats, gram, cfg)
    mask_c = _fused_similarity_mask(stats, gram, cfg)
    if temporal:
        mask_t, hist_s, hist_b, count, t = wfagg_t_decide(
            state.hist_s, state.hist_b, state.count, state.t,
            stats.prev_dist2, stats.cosine_to_prev(), cfg)
        new_state = TemporalState(prev=updates, hist_s=hist_s, hist_b=hist_b,
                                  count=count, t=t)
    else:
        mask_t = jnp.zeros((updates.shape[0],), dtype=bool)
        new_state = state
    weights = wfagg_scores(mask_d, mask_c, mask_t, cfg)
    out = weighted_agg(local, updates, weights, alpha=cfg.alpha)
    info = {
        "mask_d": mask_d,
        "mask_c": mask_c,
        "mask_t": mask_t,
        "weights": weights,
        "n_accepted": (weights > 0).sum(),
    }
    return out, new_state, info


def wfagg(
    local: Array,
    updates: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
) -> Tuple[Array, Optional[TemporalState], dict]:
    """Full WFAgg (Alg. 1).  Returns (aggregated, new_state, info)."""
    if cfg.backend in _FUSED_BACKENDS:
        # single-node calls have no single-launch variant — both fused
        # flavors run the stats-kernel + host-scoring + combine pipeline
        return _wfagg_fused(local, updates, state, cfg)
    if cfg.backend != "reference":
        raise ValueError(f"unknown backend {cfg.backend!r}")
    mask_d = _distance_mask(updates, cfg)
    mask_c = _similarity_mask(updates, cfg)
    if cfg.use_temporal and state is not None:
        mask_t, new_state = wfagg_t_select(state, updates, cfg)
    else:
        mask_t = jnp.zeros((updates.shape[0],), dtype=bool)
        new_state = state
    weights = wfagg_scores(mask_d, mask_c, mask_t, cfg)
    out = wfagg_e(local, updates, weights, cfg.alpha)
    info = {
        "mask_d": mask_d,
        "mask_c": mask_c,
        "mask_t": mask_t,
        "weights": weights,
        "n_accepted": (weights > 0).sum(),
    }
    return out, new_state, info


def wfagg_batch(
    local: Array,
    updates: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
    neighbor_idx: Optional[Array] = None,
    valid: Optional[Array] = None,
    prev_idx: Optional[Array] = None,
) -> Tuple[Array, Optional[TemporalState], dict]:
    """Batched full WFAgg over all N receiving nodes of a gossip round.

    ``local (N, d)``, ``updates (N, K, d)``, ``state`` with a leading N
    axis on every leaf.  The fused backend runs ONE robust_stats kernel
    launch with a 2-D (node, D-block) grid — a vmap of single-node Pallas
    calls would serialize into an outer per-node loop instead — and one
    batched combine; only the O(K)/O(K^2) mask logic is vmapped.  The
    reference backend vmaps the plain-jnp pipeline (same semantics,
    multi-pass traffic).

    Gather-free path: with ``neighbor_idx (N, K)``, ``updates`` is the
    (M, d) MODEL MATRIX instead of a gathered tensor — the fused kernels
    DMA each neighbor's d-blocks straight from it, so the (N, K, d)
    gossip tensor never exists in HBM.  Under the default
    backend="fused" this is ONE single-launch round kernel (stats,
    in-kernel trust weights, WFAgg-E combine — ~1 candidate pass);
    backend="fused_two_launch" keeps the stats + combine launch pair.
    ``valid (N, K)`` marks the real edges of padded irregular topologies
    (None = regular); the temporal ``prev`` state may be per-edge
    (N, K, d) or a previous-round model matrix (M, d) read through the
    same index table (in which case the new state stays a matrix and the
    round is (N, K, d)-free end to end).  ``prev_idx (N, K)`` points the
    matrix-form temporal ``prev`` at rows OTHER than the live neighbor
    table — the chaos transport's staleness re-keying (dfl/faults.py),
    where the payload an edge served last round need not be the row it
    reads this round.
    """
    if neighbor_idx is not None:
        return _wfagg_batch_indexed(local, updates, state, cfg,
                                    neighbor_idx, valid, prev_idx)
    if prev_idx is not None:
        raise ValueError("prev_idx requires neighbor_idx (indexed path)")
    if valid is not None:
        raise ValueError("valid requires neighbor_idx (padded indexed path)")
    if cfg.backend == "reference":
        if state is not None:
            return jax.vmap(lambda l, u, s: wfagg(l, u, s, cfg))(
                local, updates, state)
        out, _, info = jax.vmap(lambda l, u: wfagg(l, u, None, cfg))(
            local, updates)
        return out, None, info
    if cfg.backend not in _FUSED_BACKENDS:
        raise ValueError(f"unknown backend {cfg.backend!r}")

    N, K, _ = updates.shape
    temporal = cfg.use_temporal and state is not None
    prev = state.prev if temporal else None
    stats = robust_stats_batch(updates, prev=prev, need_center=False)
    gram = None
    if _needs_gram(cfg):
        # one extra read of the candidates: batched Gram via the MXU
        gram = jnp.einsum("nkd,njd->nkj", updates, updates,
                          preferred_element_type=jnp.float32)
    if gram is not None:
        mask_d = jax.vmap(lambda s, g: _fused_distance_mask(s, g, cfg))(stats, gram)
        mask_c = jax.vmap(lambda s, g: _fused_similarity_mask(s, g, cfg))(stats, gram)
    else:
        mask_d = jax.vmap(lambda s: _fused_distance_mask(s, None, cfg))(stats)
        mask_c = jax.vmap(lambda s: _fused_similarity_mask(s, None, cfg))(stats)
    if temporal:
        mask_t, hist_s, hist_b, count, t = jax.vmap(
            lambda hs, hb, c, tt, s, b: wfagg_t_decide(hs, hb, c, tt, s, b, cfg)
        )(state.hist_s, state.hist_b, state.count, state.t,
          stats.prev_dist2, stats.cosine_to_prev())
        new_state = TemporalState(prev=updates, hist_s=hist_s, hist_b=hist_b,
                                  count=count, t=t)
    else:
        mask_t = jnp.zeros((N, K), dtype=bool)
        new_state = state
    weights = wfagg_scores(mask_d, mask_c, mask_t, cfg)
    # batched WFAgg-E combine: the second and last (K, d)-sized pass
    out = jax.vmap(lambda l, u, w: wfagg_e(l, u, w, cfg.alpha))(
        local, updates, weights)
    info = {
        "mask_d": mask_d,
        "mask_c": mask_c,
        "mask_t": mask_t,
        "weights": weights,
        "n_accepted": (weights > 0).sum(axis=-1),
    }
    return out, new_state, info


def _indexed_scoring(
    stats: RobustStats,
    valid_b: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
    models: Array,
    neighbor_idx: Array,
) -> Tuple[Array, Array, Array, Array, Optional[TemporalState]]:
    """Host-side scoring stage shared by the two-launch fused path and
    the valid-aware reference oracle: vmapped trust masks, the WFAgg-T
    decision + ring-buffer update, and the tau-weighted scores.  Returns
    (mask_d, mask_c, mask_t, weights, new_state)."""
    N, K = valid_b.shape
    temporal = cfg.use_temporal and state is not None
    matrix_prev = temporal and state.prev.ndim == 2
    gram = stats.gram
    stats = stats._replace(gram=None)  # keep the vmapped mask fns uniform
    if gram is not None:
        mask_d = jax.vmap(lambda s, g, v: _fused_distance_mask_valid(s, g, v, cfg))(
            stats, gram, valid_b)
        mask_c = jax.vmap(lambda s, g, v: _fused_similarity_mask_valid(s, g, v, cfg))(
            stats, gram, valid_b)
    else:
        mask_d = jax.vmap(lambda s, v: _fused_distance_mask_valid(s, None, v, cfg))(
            stats, valid_b)
        mask_c = jax.vmap(lambda s, v: _fused_similarity_mask_valid(s, None, v, cfg))(
            stats, valid_b)
    if temporal:
        mask_t, hist_s, hist_b, count, t = jax.vmap(
            lambda hs, hb, c, tt, s, b: wfagg_t_decide(hs, hb, c, tt, s, b, cfg)
        )(state.hist_s, state.hist_b, state.count, state.t,
          stats.prev_dist2, stats.cosine_to_prev())
        mask_t = mask_t & valid_b
        new_state = TemporalState(
            prev=models if matrix_prev else models[neighbor_idx],
            hist_s=hist_s, hist_b=hist_b, count=count, t=t)
    else:
        mask_t = jnp.zeros((N, K), dtype=bool)
        new_state = state
    weights = wfagg_scores(mask_d, mask_c, mask_t, cfg) * valid_b
    return mask_d, mask_c, mask_t, weights, new_state


def _push_temporal_history(state: TemporalState, prev_new: Array,
                           s_t: Array, b_t: Array) -> TemporalState:
    """Batched WFAgg-T ring-buffer push (the state-update half of
    ``wfagg_t_decide``): the single-launch path takes its masks from the
    kernel, so only the history advance happens on the host."""
    hist_s, hist_b, count, t = jax.vmap(trust.push_history)(
        state.hist_s, state.hist_b, state.count, state.t, s_t, b_t)
    return TemporalState(prev=prev_new, hist_s=hist_s, hist_b=hist_b,
                         count=count, t=t)


def _wfagg_batch_indexed(
    local: Array,
    models: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
    neighbor_idx: Array,
    valid: Optional[Array],
    prev_idx: Optional[Array] = None,
) -> Tuple[Array, Optional[TemporalState], dict]:
    """Gather-free batched WFAgg.

    backend="fused" (default): ONE kernel launch per gossip round — the
    round kernel streams neighbor blocks (phase 0), derives the trust
    weights at the in-kernel phase boundary, and writes the WFAgg-E
    combine (phase 1).  backend="fused_two_launch": the previous shape —
    a stats launch, the scoring stage on the host, a combine launch —
    kept as the parity fallback.  backend="reference": pure-jnp oracle;
    with a ``valid`` mask it runs the valid-aware multi-pass pipeline
    (same dynamic keep counts as the fused paths), without one it keeps
    the bit-parity static-count per-node pipeline.

    ``cfg.sanitize`` (default on) zeroes non-finite candidate rows and
    demotes their edges to invalid before ANY statistic, on every
    backend — corrupted payloads degrade to rejected slots instead of
    poisoning the median (a no-op on finite inputs).  On the static
    reference path (``valid=None``, dispatch is trace-time) the zeroed
    row participates as a finite zero candidate instead.
    """
    N, K = neighbor_idx.shape
    valid_b = jnp.ones((N, K), dtype=bool) if valid is None else valid.astype(bool)
    temporal = cfg.use_temporal and state is not None
    matrix_prev = temporal and state.prev.ndim == 2
    if prev_idx is not None and not matrix_prev:
        prev_idx = None        # nothing matrix-formed to re-key
    if cfg.sanitize:
        finite = jnp.isfinite(models).all(axis=-1)
        models = jnp.where(finite[:, None], models, 0.0)
        valid_b = valid_b & finite[neighbor_idx]
        if temporal:
            pf = jnp.isfinite(state.prev).all(axis=-1)
            state = state._replace(
                prev=jnp.where(pf[..., None], state.prev, 0.0))
    prev = state.prev if temporal else None

    if cfg.backend == "reference":
        if valid is not None:
            return _wfagg_batch_indexed_reference_valid(
                local, models, state, cfg, neighbor_idx, valid_b, prev_idx)
        gathered = models[neighbor_idx]
        if state is not None:
            edge_state = (state._replace(prev=state.prev[
                neighbor_idx if prev_idx is None else prev_idx])
                          if matrix_prev else state)
            out, new_state, info = jax.vmap(
                lambda l, u, s: wfagg(l, u, s, cfg))(local, gathered, edge_state)
            if matrix_prev:
                new_state = new_state._replace(prev=models)
            return out, new_state, info
        out, _, info = jax.vmap(lambda l, u: wfagg(l, u, None, cfg))(
            local, gathered)
        return out, None, info

    if cfg.backend == "fused_two_launch":
        # the Alt-WFAgg (K, K) Gram rides along in the SAME kernel pass,
        # accumulated from the resident candidate tile — no extra read
        stats = robust_stats_indexed(
            models, neighbor_idx, valid_b if cfg.sanitize else valid,
            prev=prev, need_gram=_needs_gram(cfg), prev_idx=prev_idx)
        mask_d, mask_c, mask_t, weights, new_state = _indexed_scoring(
            stats, valid_b, state, cfg, models, neighbor_idx)
        # gather-free WFAgg-E combine: neighbor rows DMA'd by the same table
        out = weighted_agg_indexed(local, models, neighbor_idx, weights,
                                   alpha=cfg.alpha)
    elif cfg.backend == "fused":
        # single launch: stats, in-kernel weight derivation AND combine in
        # one pallas_call.  The WFAgg-T EWMA bands are the only O(K)
        # precompute (they need the host-resident metric history); the
        # ring buffers advance afterwards off the kernel's temporal tail.
        tbands = None
        if temporal:
            tbands = jax.vmap(
                lambda hs, hb, c, tt: trust.temporal_bands(hs, hb, c, tt, cfg)
            )(state.hist_s, state.hist_b, state.count, state.t)
        out, weights, mask_d, mask_c, mask_t, stats = wfagg_round_indexed(
            local, models, neighbor_idx,
            valid_b if cfg.sanitize else valid, cfg,
            prev=prev, tbands=tbands, prev_idx=prev_idx)
        new_state = state
        if temporal:
            new_state = _push_temporal_history(
                state, models if matrix_prev else models[neighbor_idx],
                stats.prev_dist2, stats.cosine_to_prev())
    else:
        raise ValueError(f"unknown backend {cfg.backend!r}")

    info = {
        "mask_d": mask_d,
        "mask_c": mask_c,
        "mask_t": mask_t,
        "valid": valid_b,
        "weights": weights,
        "n_accepted": (weights > 0).sum(axis=-1),
    }
    return out, new_state, info


def _wfagg_batch_indexed_reference_valid(
    local: Array,
    models: Array,
    state: Optional[TemporalState],
    cfg: WFAggConfig,
    neighbor_idx: Array,
    valid_b: Array,
    prev_idx: Optional[Array] = None,
) -> Tuple[Array, Optional[TemporalState], dict]:
    """Valid-aware pure-jnp reference pipeline: the oracle for irregular
    and dynamic (padded, possibly degree-0) topologies.

    Statistics come from ``robust_stats_indexed_ref`` (plain gathered
    einsums — no Pallas anywhere), the masks from the same dynamic-count
    trust logic the fused paths use (so selections agree with the kernels
    on the true per-node degree), and the combine is the vmapped Eq. 3.
    Previously this configuration raised NotImplementedError, leaving
    irregular/dynamic runs without a reference to diff against.
    """
    N, K = neighbor_idx.shape
    temporal = cfg.use_temporal and state is not None
    prev = state.prev if temporal else None
    stats = robust_stats_indexed_ref(models, neighbor_idx, valid_b, prev,
                                     need_gram=_needs_gram(cfg),
                                     prev_idx=prev_idx)
    mask_d, mask_c, mask_t, weights, new_state = _indexed_scoring(
        stats, valid_b, state, cfg, models, neighbor_idx)
    gathered = models[neighbor_idx].astype(jnp.float32)
    out = jax.vmap(lambda l, u, w: wfagg_e(l, u, w, cfg.alpha))(
        local, gathered, weights)
    info = {
        "mask_d": mask_d,
        "mask_c": mask_c,
        "mask_t": mask_t,
        "valid": valid_b,
        "weights": weights,
        "n_accepted": (weights > 0).sum(axis=-1),
    }
    return out, new_state, info


def realign_temporal_history(state: TemporalState,
                             prev_idx: Array, prev_valid: Array,
                             idx: Array, valid: Array) -> TemporalState:
    """Re-key the slot-positional WFAgg-T ring buffers to a new slate.

    ``hist_s``/``hist_b`` are (N, W, K) and keyed by neighbor SLOT; on a
    round-varying topology a neighbor may occupy a different slot than
    last round (padded tables pack valid neighbors as a prefix), so
    without remapping the EWMA thresholds of Alg. 4 would score each
    neighbor against some OTHER neighbor's history — a rejoining
    attacker could inherit a clean record.  This matches slots by
    neighbor IDENTITY: column k_new receives the history of the k_old
    with ``idx[n, k_new] == prev_idx[n, k_old]`` (both slots valid), and
    a neighbor unseen last round starts with a zeroed column — its
    near-degenerate EWMA band makes the temporal filter abstain rather
    than vouch for a stranger.  The (N, d) matrix ``prev`` needs no
    remap (it is indexed by node id, identity-keyed by construction),
    and on a static slate the match is the identity permutation (no-op).
    """
    match = ((idx[:, :, None] == prev_idx[:, None, :])
             & valid.astype(bool)[:, :, None]
             & prev_valid.astype(bool)[:, None, :])   # (N, K_new, K_old)
    m = match.astype(state.hist_s.dtype)
    return state._replace(
        hist_s=jnp.einsum("nkj,nwj->nwk", m, state.hist_s),
        hist_b=jnp.einsum("nkj,nwj->nwk", m, state.hist_b),
    )


def memory_passes(cfg: WFAggConfig, include_gather: bool = False,
                  indexed: bool = False) -> int:
    """Number of (K, d)-sized HBM passes per full-WFAgg aggregation.

    reference: each filter re-reads the candidates — distance filter
    (median sort + distances = 2, or 1 Gram pass for Multi-Krum),
    similarity filter (median + norms/clip + cosine dots = 3, or 1 Gram
    pass for Clustering), temporal metrics (1), WFAgg-E combine (1).
    fused: ONE robust_stats read covers D/C/T statistics, plus the
    combine (+ 1 Gram pass only when an Alt-WFAgg filter needs K x K
    distances).  See kernels/README.md for the accounting.

    ``include_gather`` also counts the gossip-exchange materialization a
    DFL round pays BEFORE aggregating: building the (N, K, d) gathered
    tensor costs one more candidate-sized pass (write ~= read) — unless
    ``indexed`` (the gather-free neighbor-indexed path), which DMAs
    neighbor blocks straight from the (N, d) model matrix and never
    materializes the tensor.  The indexed path also folds the Alt-WFAgg
    (K, K) Gram into the stats pass (accumulated off the resident tile),
    dropping the separate Gram pass as well.

    On the indexed path, backend="fused" is the SINGLE-LAUNCH round
    kernel: stats, in-kernel weight derivation and combine in one
    pallas_call — ~1 candidate pass (the phase-1 combine re-walks the
    neighbor blocks through the same index maps, but those are the tiles
    the stats phase just made resident, so the streamed HBM traffic is
    one candidate read whenever a node's (K, d) slab fits VMEM).
    backend="fused_two_launch" keeps the separate stats + combine
    launches (2 passes) for parity runs.
    """
    t = 1 if cfg.use_temporal else 0
    gather = 1 if (include_gather and not indexed) else 0
    if cfg.backend in _FUSED_BACKENDS:
        if indexed and cfg.backend == "fused":
            return 1 + gather      # single launch: one streamed read
        gram = 1 if (_needs_gram(cfg) and not indexed) else 0
        return 2 + gram + gather
    d_passes = 1 if cfg.distance_filter == "multi_krum" else 2
    c_passes = 1 if cfg.similarity_filter == "clustering" else 3
    return d_passes + c_passes + t + 1 + gather


def alt_wfagg_config(**kw) -> WFAggConfig:
    """Alt-WFAgg (paper SsVI-B2): Multi-Krum + Clustering as the filters."""
    return WFAggConfig(distance_filter="multi_krum", similarity_filter="clustering", **kw)


# Standalone aggregators (Table I columns WFAgg-D / WFAgg-C / WFAgg-E / WFAgg-T)
def wfagg_d_agg(updates: Array, f: int = 2,
                backend: str = "reference") -> Tuple[Array, Array]:
    if backend == "fused":
        stats = robust_stats(updates, need_center=False)
        mask = agg.smallest_k_mask(stats.dist2, updates.shape[0] - int(f) - 1)
    else:
        mask = wfagg_d_select(updates, f)
    return agg.masked_mean(updates, mask), mask


def wfagg_c_agg(updates: Array, f: int = 2,
                backend: str = "reference") -> Tuple[Array, Array]:
    if backend == "fused":
        stats = robust_stats(updates, need_center=False)
        mask = agg.smallest_k_mask(stats.cosine_to_median(),
                                   updates.shape[0] - int(f) - 1)
    else:
        mask = wfagg_c_select(updates, f)
    return agg.masked_mean(updates, mask), mask


def wfagg_e_agg(local: Array, updates: Array, alpha: float = 0.8) -> Array:
    """WFAgg-E alone: uniform weights over all neighbors (no filtering)."""
    K = updates.shape[0]
    return wfagg_e(local, updates, jnp.ones((K,), jnp.float32), alpha)
