"""Input specs per (architecture x input shape).

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model
input (the dry-run path: weak-type-correct, shardable, zero allocation).
``dummy_batch`` materializes small real arrays for smoke tests.

Modality carve-out (per task rules): audio/vision frontends are stubs —
the specs provide precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.model import MODAL_EMBED_DIM

SDS = jax.ShapeDtypeStruct

ENC_LEN_DECODE = 4096  # audio encoder output length assumed during decode


def train_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Specs for train_step / prefill batches."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "frames": SDS((B, S, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": SDS((B, S), jnp.int32),
        }
    if cfg.modality == "vision":
        n_img = cfg.n_modal_tokens
        return {
            "patch_embeds": SDS((B, n_img, MODAL_EMBED_DIM), jnp.dtype(cfg.dtype)),
            "tokens": SDS((B, S - n_img), jnp.int32),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_token_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    return {"tokens": SDS((shape.global_batch, 1), jnp.int32)}


def dummy_batch(cfg: ArchConfig, batch: int, seq: int, key=None) -> Dict[str, Any]:
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(k1, (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size),
        }
    if cfg.modality == "vision":
        n_img = cfg.n_modal_tokens
        return {
            "patch_embeds": jax.random.normal(k1, (batch, n_img, MODAL_EMBED_DIM), jnp.dtype(cfg.dtype)),
            "tokens": jax.random.randint(k2, (batch, max(seq - n_img, 8)), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)}
