"""Deterministic synthetic datasets (the container has no dataset access).

Two generators:

* ``TokenStream`` — language-model token batches with learnable structure:
  a seeded order-1 Markov chain over an effective vocabulary embedded into
  the model's vocab.  Loss decreases quickly on it, which the end-to-end
  training examples assert.

* ``SyntheticImages`` — the MNIST stand-in for the paper reproduction:
  10 fixed class templates (seeded, 28x28) + Gaussian pixel noise, IID
  sharded across DFL nodes.  Linearly separable enough that LeNet/MLP
  reach high accuracy within a round or two, reproducing the paper's
  accuracy-convergence structure without the MNIST download.

Both are stateless: ``batch(step)`` is a pure function of (seed, step), so
data is reproducible, checkpoint-restart-safe, and needs no host state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    effective_vocab: int = 64   # Markov chain order

    def _chain(self) -> Array:
        """Transition table (effective_vocab,) -> deterministic successor
        distribution expressed as 8 plausible successors per token."""
        key = jax.random.PRNGKey(self.seed)
        return jax.random.randint(key, (self.effective_vocab, 8), 0, self.effective_vocab)

    def batch(self, step: int | Array) -> Dict[str, Array]:
        succ = self._chain()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k0, k1 = jax.random.split(key)
        x0 = jax.random.randint(k0, (self.batch_size,), 0, self.effective_vocab)
        picks = jax.random.randint(k1, (self.batch_size, self.seq_len), 0, 8)

        def gen(tok, pick):
            nxt = succ[tok, pick]
            return nxt, nxt

        _, toks = jax.lax.scan(
            lambda c, p: gen(c, p), x0, picks.T
        )
        tokens = toks.T % self.vocab_size
        return {"tokens": tokens.astype(jnp.int32)}


@dataclasses.dataclass(frozen=True)
class SyntheticImages:
    """MNIST-shaped 10-class task: template + noise."""

    n_classes: int = 10
    noise: float = 0.35
    seed: int = 0

    def templates(self) -> Array:
        key = jax.random.PRNGKey(self.seed + 17)
        t = jax.random.normal(key, (self.n_classes, 28, 28, 1))
        # smooth the templates a little so they resemble strokes, not static
        k = jnp.ones((3, 3)) / 9.0
        t = jax.vmap(
            lambda img: jax.scipy.signal.convolve2d(img[..., 0], k, mode="same")
        )(t)[..., None]
        return t

    def batch(self, key: Array, batch_size: int) -> Tuple[Array, Array]:
        """Returns (images (B,28,28,1), labels (B,))."""
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (batch_size,), 0, self.n_classes)
        tpl = self.templates()
        imgs = tpl[labels] + self.noise * jax.random.normal(k2, (batch_size, 28, 28, 1))
        return imgs, labels

    def node_batch(self, node: int, rnd: int, batch_size: int) -> Tuple[Array, Array]:
        """IID per-node batch, deterministic in (seed, node, round)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), node), rnd
        )
        return self.batch(key, batch_size)

    def test_set(self, n: int = 1000) -> Tuple[Array, Array]:
        return self.batch(jax.random.PRNGKey(self.seed + 999), n)
