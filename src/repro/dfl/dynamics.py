"""Topology-dynamics scenario engine: round-varying gossip graphs.

The paper motivates WFAgg with "the adverse conditions ... of dynamic
decentralized topologies", and the follow-up literature (DART, arXiv
2407.08652; the topology-resilience study, arXiv 2407.05141) shows
Byzantine robustness swings sharply once the graph varies round to
round.  This module turns those conditions into data: each scenario
generator precomputes a scan-friendly ``TopologySchedule`` — an
(R, N, K) neighbor-table + valid-mask stack padded to ONE width across
all rounds, plus an (R, N) per-round Byzantine mask — which the engine
threads through ``round_fn(state, neighbor_idx, valid, mal_mask)`` as
traced inputs.  One compile serves the whole schedule; the gather-free
indexed kernels take the table as a jit argument, so a changing graph
costs exactly one (N, K) index upload per round.

Scenarios (``SCENARIOS`` registry, mirroring ``AGGREGATOR_NAMES``):

  churn         nodes leave/rejoin via a 2-state Markov chain; a down
                node loses every incident edge (degree may hit 0 — the
                padded row goes all-invalid and the node keeps its local
                model until it rejoins)
  link_failure  every base-graph edge fails independently per round
  partition     the graph splits into two halves for a window of rounds,
                then heals (all cross-partition edges cut while split)
  mobility      periodic rewiring: the graph is resampled Erdos-Renyi
                every ``every`` rounds (nodes "move", neighborhoods
                change wholesale)
  sleeper       static graph, time-varying Byzantine set: attackers
                behave benignly until their wake round (late-joining /
                sleeper adversaries)

Topology ATTACKS (the adversary rewires the graph, arXiv 2407.05141):

  eclipse       Byzantine nodes monopolize one victim's slate: every
                benign edge of the victim is cut and all attackers
                connect to it, so its whole padded slate is poisoned
  dos           a chosen node's edges are dropped for a window of
                rounds (denial of service / jamming — the degree-0
                self-fallback path under adversarial timing)
  collusion     attackers abandon their assigned positions and rewire
                onto a shared set of high-degree victims, concentrating
                f Byzantine neighbors where placement="spaced" promised
                dispersion

All generators are deterministic in (topology, rounds, seed) and
composable through ``schedule_from_adjacencies`` — hand-build any
(R, N, N) adjacency stack + (R, N) malicious stack for conditions not
listed here.

The TRANSPORT faults — what happens to a payload on an edge that does
exist (drop, stale delivery, duplication, bit-corruption, crash-restart)
— live one layer down in ``repro.dfl.faults`` and compose with any
schedule built here through the valid mask; ``make_faulty_schedule``
pairs the two in one call (docs/FAULTS.md).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.topology import (
    Topology,
    TopologySchedule,
    erdos_renyi,
    schedule_from_adjacencies,
    static_schedule,
)

__all__ = [
    "SCENARIOS", "SCENARIO_NAMES", "make_schedule", "make_faulty_schedule",
    "churn_schedule", "link_failure_schedule", "partition_schedule",
    "mobility_schedule", "sleeper_schedule", "static_schedule",
    "eclipse_schedule", "dos_schedule", "collusion_schedule",
]


def _cut_node(adj: np.ndarray, down: np.ndarray) -> np.ndarray:
    """Remove every edge incident to a down node (symmetric)."""
    up = ~down
    return adj & up[:, None] & up[None, :]


def churn_schedule(topo: Topology, rounds: int, seed: int = 0,
                   p_leave: float = 0.15, p_join: float = 0.5,
                   ) -> TopologySchedule:
    """Node churn: each round an up node leaves w.p. ``p_leave`` and a
    down node rejoins w.p. ``p_join`` (2-state Markov chain per node).
    A down node exchanges with nobody — all its edges vanish in both
    directions, so neighbors see a shrunken slate and the node itself
    gets an all-invalid row (self-fallback aggregate).  Malicious nodes
    churn like everyone else: a down attacker is also marked benign for
    the round (it sends nothing to poison)."""
    rng = np.random.default_rng(seed)
    n = topo.n_nodes
    down = np.zeros(n, dtype=bool)
    adjs, mals = [], []
    for _ in range(rounds):
        u = rng.random(n)
        down = np.where(down, u >= p_join, u < p_leave)
        adjs.append(_cut_node(topo.adjacency, down))
        mals.append(topo.malicious & ~down)
    return schedule_from_adjacencies(np.stack(adjs), np.stack(mals))


def link_failure_schedule(topo: Topology, rounds: int, seed: int = 0,
                          p_fail: float = 0.2) -> TopologySchedule:
    """Random link failure: every base edge drops independently w.p.
    ``p_fail`` each round (symmetric — a failed link is failed for both
    endpoints, as a lossy radio link would be)."""
    rng = np.random.default_rng(seed)
    n = topo.n_nodes
    adjs = []
    for _ in range(rounds):
        keep = rng.random((n, n)) >= p_fail
        keep = np.triu(keep, 1)
        keep = keep | keep.T
        adjs.append(topo.adjacency & keep)
    return schedule_from_adjacencies(np.stack(adjs), topo.malicious)


def partition_schedule(topo: Topology, rounds: int, seed: int = 0,
                       split_at: int = None, heal_at: int = None,
                       ) -> TopologySchedule:
    """Partition-and-heal: from round ``split_at`` (default R//3) to
    ``heal_at`` (default 2R//3) the network splits into two halves and
    every cross-partition edge is cut; outside that window the base
    graph is intact.  The halves are a random balanced bisection."""
    rng = np.random.default_rng(seed)
    n = topo.n_nodes
    split_at = rounds // 3 if split_at is None else split_at
    heal_at = (2 * rounds) // 3 if heal_at is None else heal_at
    side = np.zeros(n, dtype=bool)
    side[rng.permutation(n)[: n // 2]] = True
    same_side = side[:, None] == side[None, :]
    adjs = []
    for r in range(rounds):
        partitioned = split_at <= r < heal_at
        adjs.append(topo.adjacency & same_side if partitioned
                    else topo.adjacency)
    return schedule_from_adjacencies(np.stack(adjs), topo.malicious)


def mobility_schedule(topo: Topology, rounds: int, seed: int = 0,
                      every: int = 2, min_degree: int = 0,
                      ) -> TopologySchedule:
    """Mobility as periodic rewiring: every ``every`` rounds the graph is
    resampled Erdos-Renyi at the base topology's mean degree (nodes move,
    whole neighborhoods change).  ``min_degree=0`` allows transiently
    isolated nodes — the realistic mobile case the padded degree-0 path
    exists for."""
    n = topo.n_nodes
    p = float(topo.degrees.mean()) / max(n - 1, 1)
    adjs, cur = [], None
    for r in range(rounds):
        if cur is None or r % max(every, 1) == 0:
            cur = erdos_renyi(n, p, seed=seed + r, min_degree=min_degree)
        adjs.append(cur)
    return schedule_from_adjacencies(np.stack(adjs), topo.malicious)


def sleeper_schedule(topo: Topology, rounds: int, seed: int = 0,
                     wake_at: int = None) -> TopologySchedule:
    """Sleeper attackers on a static graph: the Byzantine set is empty
    until round ``wake_at`` (default R//2), when the topology's malicious
    nodes switch on — the late-joining adversary that defeats purely
    temporal trust (a sleeper builds perfect history first)."""
    wake_at = rounds // 2 if wake_at is None else wake_at
    n = topo.n_nodes
    mal = np.zeros((rounds, n), dtype=bool)
    mal[wake_at:] = topo.malicious
    adjs = np.broadcast_to(topo.adjacency, (rounds, n, n))
    return schedule_from_adjacencies(adjs, mal)


# ---------------------------------------------------------------------------
# topology attacks (adversarial graphs as scenarios)
# ---------------------------------------------------------------------------

def _default_victim(topo: Topology, prefer_malicious_neighbors: bool) -> int:
    """Deterministic victim choice: the benign node with the most
    malicious base-graph neighbors (eclipse — the cheapest node to
    surround) or the highest-degree benign node (dos — the most
    connective node to silence).  Ties break to the lowest id."""
    mal = topo.malicious
    if prefer_malicious_neighbors:
        score = (topo.adjacency & mal[None, :]).sum(axis=1)
    else:
        score = topo.degrees.copy()
    score = np.where(mal, -1, score)
    return int(np.argmax(score))


def eclipse_schedule(topo: Topology, rounds: int, seed: int = 0,
                     victim: int = None, start: int = 0,
                     ) -> TopologySchedule:
    """Eclipse attack: from round ``start`` on, every benign edge of the
    victim is cut and EVERY Byzantine node connects to it — the victim's
    whole padded slate is malicious senders, the strongest per-node
    poisoning ratio any aggregation rule can face (an f-out-of-f slate
    defeats every f-robust rule; what the grid measures is the collateral
    on the REST of the network and how fast the victim re-converges once
    schedules compose).  ``victim`` defaults to the benign node the base
    placement already surrounds most."""
    mal = topo.malicious
    if not mal.any():
        return static_schedule(topo, rounds)
    if victim is None:
        victim = _default_victim(topo, prefer_malicious_neighbors=True)
    n = topo.n_nodes
    adj_e = topo.adjacency.copy()
    adj_e[victim, :] = False
    adj_e[:, victim] = False
    attackers = mal & (np.arange(n) != victim)
    adj_e[victim, attackers] = True
    adj_e[attackers, victim] = True
    adjs = np.stack([topo.adjacency if r < start else adj_e
                     for r in range(rounds)])
    return schedule_from_adjacencies(adjs, mal)


def dos_schedule(topo: Topology, rounds: int, seed: int = 0,
                 victim: int = None, start: int = None, length: int = None,
                 ) -> TopologySchedule:
    """Denial of service: the victim's edges all drop for the window
    ``[start, start + length)`` (default: the middle third of the run) —
    jamming, not poisoning.  The victim rides the degree-0 self-fallback
    path (all-invalid padded row) and its neighbors lose a benign voice
    exactly while the poisoning attacks continue elsewhere."""
    start = rounds // 3 if start is None else start
    length = max(1, rounds // 3) if length is None else length
    if victim is None:
        victim = _default_victim(topo, prefer_malicious_neighbors=False)
    n = topo.n_nodes
    down = np.zeros(n, dtype=bool)
    down[victim] = True
    adj_d = _cut_node(topo.adjacency, down)
    adjs = np.stack([adj_d if start <= r < start + length else topo.adjacency
                     for r in range(rounds)])
    return schedule_from_adjacencies(adjs, topo.malicious)


def collusion_schedule(topo: Topology, rounds: int, seed: int = 0,
                       shared: int = None) -> TopologySchedule:
    """Collusion placement: the attackers abandon their base-graph
    positions (all their edges drop, including attacker-attacker edges —
    colluders don't waste links on each other) and ALL connect to the
    same ``shared`` victims, chosen as the highest-degree benign nodes
    (ties to the lowest id).  Each victim then sees every attacker at
    once — the worst-case placement a "spaced" deployment assumes away,
    static across rounds so its effect is attributable to placement
    alone.  ``shared`` defaults to the max attacker base degree, so the
    attackers spend exactly the edge budget they had."""
    mal = topo.malicious
    if not mal.any():
        return static_schedule(topo, rounds)
    n = topo.n_nodes
    benign_ids = np.flatnonzero(~mal)
    if shared is None:
        shared = int(topo.degrees[mal].max())
    shared = max(1, min(shared, benign_ids.size))
    # highest-degree benign victims, ties to the lowest id
    order = benign_ids[np.lexsort((benign_ids, -topo.degrees[benign_ids]))]
    victims = order[:shared]
    adj_c = topo.adjacency.copy()
    adj_c[mal, :] = False
    adj_c[:, mal] = False
    att_ids = np.flatnonzero(mal)
    adj_c[np.ix_(att_ids, victims)] = True
    adj_c[np.ix_(victims, att_ids)] = True
    adjs = np.broadcast_to(adj_c, (rounds, n, n))
    return schedule_from_adjacencies(adjs, mal)


ScenarioFn = Callable[..., TopologySchedule]

SCENARIOS: Dict[str, ScenarioFn] = {
    "static": static_schedule,
    "churn": churn_schedule,
    "link_failure": link_failure_schedule,
    "partition": partition_schedule,
    "mobility": mobility_schedule,
    "sleeper": sleeper_schedule,
    "eclipse": eclipse_schedule,
    "dos": dos_schedule,
    "collusion": collusion_schedule,
}

SCENARIO_NAMES = tuple(SCENARIOS)


def make_schedule(name: str, topo: Topology, rounds: int,
                  seed: int = 0, **params) -> TopologySchedule:
    """Build a named scenario's schedule (the registry entry point)."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"choose from {SCENARIO_NAMES}")
    if name == "static":
        return static_schedule(topo, rounds, **params)
    return SCENARIOS[name](topo, rounds, seed=seed, **params)


def make_faulty_schedule(scenario: str, topo: Topology, rounds: int,
                         fault: str = "chaos", intensity: float = 0.3,
                         seed: int = 0, fault_seed: int = 0,
                         fault_config=None, **params):
    """One-call chaos pairing: ``(TopologySchedule, FaultSchedule)``.

    The topology layer decides which edges EXIST each round (this
    module); the transport layer (``repro.dfl.faults``) decides what
    happens to the payloads riding the edges that do — drop, stale
    delivery, duplication, bit-corruption, crash-restart.  The two
    compose through the valid mask: a fault schedule is generated
    against a topology schedule's shape and the engine ANDs fault
    delivery into ``valid`` inside the scan, so ``make_schedule(...)``
    plus ``faults.make_fault_schedule(...)`` is all this is — one
    deterministic call for the chaos matrix and the tests.  ``params``
    go to the scenario generator; pick the fault kind's knobs (lag
    depth, restart probability, ...) via ``fault_config`` /
    ``faults.FAULTS``.
    """
    from repro.dfl import faults as flt

    sched = make_schedule(scenario, topo, rounds, seed=seed, **params)
    fs = flt.make_fault_schedule(fault, sched, intensity, seed=fault_seed,
                                 config=fault_config)
    return sched, fs
