"""Mode-A DFL round engine: the paper's experiment, faithfully.

Each of N nodes owns an independent local model.  One round =
  1. local training (minibatch momentum-SGD on the node's IID shard;
     Label-Flipping nodes poison their labels),
  2. model-poisoning attacks replace Byzantine nodes' models,
  3. gossip: every node receives its K graph neighbors' models,
  4. per-node Byzantine-robust aggregation (any rule from the registry),
     with WFAgg keeping per-node temporal state (Alg. 4).

The whole round is ONE jitted function, vmapped over nodes — 20 nodes x
LeNet/MLP train concurrently.  On a TPU mesh the node axis shards over
'data' (annotated below), which is the faithful decentralized execution
the paper simulates with Python threads.

Dynamic topologies: ``build_round_fn(..., dynamic=True)`` returns the
round with the (N, K) neighbor table, (N, K) valid mask and (N,)
Byzantine mask as TRACED inputs, and ``run_dynamic_experiment`` scans a
``TopologySchedule`` (see ``repro.dfl.dynamics``) through it — the
graph and the attacker set change every round on one compile, with the
per-round accuracy/consistency series computed inside the scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.lenet_mnist import PaperDFLConfig
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk
from repro.core import metrics as met
from repro.core import trust
from repro.core import wfagg as wf
from repro.core.topology import Topology, TopologySchedule
from repro.data.synthetic import SyntheticImages
from repro.dfl import faults as flt
from repro.obs import decision as obs_decision
from repro.models.lenet import init_lenet, init_mlp_classifier, lenet_fwd, mlp_classifier_fwd

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    aggregator: str = "wfagg"
    attack: str = "none"
    model: str = "mlp"            # mlp | lenet
    centralized: bool = False     # CFL baseline (server over all N models)
    paper: PaperDFLConfig = PaperDFLConfig()
    batches_per_round: int = 4
    seed: int = 0
    # Model-poisoning attack hyper-parameters (ALIE z_max, noise mu/sigma,
    # IPM eps) — routed through core.attacks.apply_matrix_attack.
    attack_params: atk.AttackConfig = atk.AttackConfig()
    # WFAgg execution backend: "fused" runs the whole gossip round —
    # stats, in-kernel trust-weight derivation AND the WFAgg-E combine —
    # through ONE single-launch Pallas kernel (see core.wfagg.wfagg_batch
    # / kernels.robust_stats.ops.wfagg_round_indexed);
    # "fused_two_launch" keeps the separate stats + combine launches
    # (parity fallback); "reference" is the multi-pass jnp oracle (valid-
    # aware, so irregular and dynamic topologies run under it too).
    wfagg_backend: str = "fused"
    # > 1 shards the model dimension of the WFAgg gossip round over that
    # many devices of a (1, S) ('data', 'model') mesh via shard_map
    # (distributed/spmd.py): per-shard filter statistics, one O(N*K)
    # psum, shard-local combine.  Requires >= S visible devices; the
    # round boundary stays replicated (pad/shard/unshard inside), so the
    # rest of the engine is unchanged.  0/1 = single-process (default).
    mesh_model_shards: int = 0

    def wfagg_config(self, use_temporal=True, backend: Optional[str] = None) -> wf.WFAggConfig:
        p = self.paper
        return wf.WFAggConfig(
            f=p.f, tau1=p.tau1, tau2=p.tau2, tau3=p.tau3, alpha=p.alpha,
            window=p.window, transient=p.transient, use_temporal=use_temporal,
            backend=backend or self.wfagg_backend,
        )


class DFLState(NamedTuple):
    node_params: Any       # pytree, leading axis N
    node_momentum: Any     # pytree, leading axis N
    temporal: Optional[wf.TemporalState]   # leading axis N (per receiving node)
    rnd: Array


AGGREGATOR_NAMES = (
    "mean", "median", "trimmed_mean", "krum", "multi_krum", "clustering",
    "wfagg_d", "wfagg_c", "wfagg_t", "wfagg_e", "wfagg", "alt_wfagg",
)


def _model_fns(cfg: DFLConfig):
    if cfg.model == "lenet":
        return init_lenet, lenet_fwd
    return init_mlp_classifier, mlp_classifier_fwd


def init_dfl_state(cfg: DFLConfig, topo: Topology,
                   degree: Optional[int] = None) -> DFLState:
    """Fresh per-node models + temporal state.  ``degree`` overrides the
    neighbor-table width K (dynamic schedules are padded to the max
    degree over ALL rounds, which may exceed the base topology's)."""
    init_fn, _ = _model_fns(cfg)
    N = topo.n_nodes
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), N)
    params = jax.vmap(init_fn)(keys)
    momentum = jax.tree.map(jnp.zeros_like, params)
    flat_one, _ = ravel_pytree(jax.tree.map(lambda x: x[0], params))
    d = flat_one.shape[0]
    K = degree if degree is not None else (
        topo.n_nodes if cfg.centralized else topo.degree)
    temporal = None
    if cfg.aggregator in ("wfagg", "alt_wfagg") and not cfg.centralized:
        # Gather-free gossip rounds keep the temporal ``prev`` as the
        # previous round's (N, d) MODEL MATRIX instead of a per-edge
        # (N, K, d) tensor — prev[idx[n, k]] is exactly edge (n, k)'s
        # last received model, the indexed kernel reads it through the
        # same neighbor table, and the K-fold state buffer disappears.
        temporal = wf.TemporalState(
            prev=jnp.zeros((N, d), jnp.float32),
            hist_s=jnp.zeros((N, cfg.paper.window, K), jnp.float32),
            hist_b=jnp.zeros((N, cfg.paper.window, K), jnp.float32),
            count=jnp.zeros((N,), jnp.int32),
            t=jnp.zeros((N,), jnp.int32),
        )
    elif cfg.aggregator in ("wfagg", "alt_wfagg", "wfagg_t"):
        temporal = jax.vmap(lambda _: wf.init_temporal_state(K, d, cfg.paper.window))(
            jnp.arange(1 if cfg.centralized else N)
        )
    return DFLState(params, momentum, temporal, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# local training
# ---------------------------------------------------------------------------

def _local_train(cfg: DFLConfig, data: SyntheticImages, malicious: Array,
                 params, momentum, rnd: Array):
    """One round of local minibatch SGD for every node (vmapped).

    ``malicious`` is the round's (N,) Byzantine mask — a traced input, so
    time-varying attacker sets (sleeper scenarios) reuse one compile."""
    _, fwd = _model_fns(cfg)
    p = cfg.paper
    label_flip = cfg.attack == "label_flip"

    def node_train(node_id, params_i, mom_i):
        def one_batch(carry, b):
            params_i, mom_i = carry
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(data.seed), node_id), rnd * 1000 + b
            )
            imgs, labels = data.batch(key, p.batch_size)
            if label_flip:
                bad = malicious[node_id]
                labels = jnp.where(bad, atk.flip_labels(labels, data.n_classes), labels)

            def loss(pp):
                return met.cross_entropy(fwd(pp, imgs), labels)

            grads = jax.grad(loss)(params_i)
            mom_i = jax.tree.map(lambda m, g: p.momentum * m + g, mom_i, grads)
            params_i = jax.tree.map(lambda w, m: w - p.lr * m, params_i, mom_i)
            return (params_i, mom_i), None

        (params_i, mom_i), _ = jax.lax.scan(
            one_batch, (params_i, mom_i), jnp.arange(cfg.batches_per_round)
        )
        return params_i, mom_i

    node_ids = jnp.arange(malicious.shape[0])
    return jax.vmap(node_train)(node_ids, params, momentum)


# ---------------------------------------------------------------------------
# attacks on trained models
# ---------------------------------------------------------------------------

def _apply_attacks(cfg: DFLConfig, malicious: Array, flat_models: Array,
                   rnd: Array,
                   view: Optional[atk.DefenseView] = None) -> Array:
    """Replace Byzantine rows of (N, d) with attacked models.

    Routed through ``core.attacks.apply_matrix_attack`` (the shared
    masked-stack implementation) so AttackConfig hyper-parameters — ALIE
    z_max, noise mu/sigma, IPM eps — are honored instead of hardcoded.
    ``malicious`` is traced: dynamic scenarios swap the Byzantine set
    round to round without retracing (apply_matrix_attack's benign-cohort
    statistics are masked sums, never boolean indexing).  ``view`` feeds
    the defense-aware adaptive attacks (``atk.ADAPTIVE_ATTACKS``) the
    round's filter state; assembled by ``_defense_view``."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 77), rnd)
    return atk.apply_matrix_attack(
        cfg.attack, flat_models, malicious, key, cfg.attack_params,
        view=view)


def _defense_view(cfg: DFLConfig, state: "DFLState", neighbor_idx: Array,
                  neighbor_valid: Optional[Array]) -> Optional[atk.DefenseView]:
    """Assemble the adaptive adversary's ``DefenseView`` for this round.

    Only built when the configured attack actually consumes it (the view
    is statically ``None`` otherwise, so oblivious runs trace zero extra
    work).  The WFAgg-T acceptance bands are precomputed EXACTLY as the
    defense's own fused path does (vmapped ``trust.temporal_bands`` over
    the pre-round temporal state) — the adversary sees the very bands it
    will be filtered by this round.  Bands/prev exist only on the
    matrix-prev gossip form (wfagg/alt_wfagg, where ``temporal.prev`` is
    the (N, d) previous model matrix, i.e. per-SENDER); aggregators
    without that state get a bandless view and the attacks degrade to
    their mimicry fallback — which is the honest threat model: there is
    no temporal filter to ride."""
    if cfg.attack not in atk.ADAPTIVE_ATTACKS or cfg.centralized:
        return None
    tbands = prev = None
    if (state.temporal is not None and state.temporal.prev.ndim == 2
            and cfg.aggregator in ("wfagg", "alt_wfagg")):
        wcfg = _wfagg_full_config(cfg, neighbor_idx.shape[1])
        if wcfg.use_temporal:
            tbands = jax.vmap(
                lambda hs, hb, c, tt: trust.temporal_bands(hs, hb, c, tt, wcfg)
            )(state.temporal.hist_s, state.temporal.hist_b,
              state.temporal.count, state.temporal.t)
            prev = state.temporal.prev
    return atk.DefenseView(neighbor_idx=neighbor_idx, valid=neighbor_valid,
                           prev=prev, tbands=tbands, f=cfg.paper.f)


# ---------------------------------------------------------------------------
# aggregation dispatch
# ---------------------------------------------------------------------------

def _wfagg_full_config(cfg: DFLConfig, K: int,
                       backend: Optional[str] = None) -> wf.WFAggConfig:
    """WFAggConfig for the full wfagg/alt_wfagg pipeline at candidate count K."""
    wcfg = cfg.wfagg_config(backend=backend)
    if cfg.aggregator == "alt_wfagg":
        wcfg = dataclasses.replace(
            wcfg, distance_filter="multi_krum", similarity_filter="clustering",
            multi_krum_m=max(1, int(cfg.paper.multi_krum_m_frac * K)),
        )
    return wcfg


def _aggregate_one(cfg: DFLConfig, local: Array, updates: Array,
                   t_state: Optional[wf.TemporalState],
                   wfagg_backend: Optional[str] = None):
    """Aggregate K received models for one node.  Returns (new_model,
    new_temporal_state).  ``wfagg_backend`` overrides the configured WFAgg
    backend — the vmapped per-node call sites force "reference" because a
    vmap of the fused Pallas path serializes node-by-node (the batched
    fused route is ``wf.wfagg_batch`` in build_round_fn)."""
    p = cfg.paper
    name = cfg.aggregator
    K = updates.shape[0]
    if name in ("mean", "median", "trimmed_mean", "krum", "multi_krum", "clustering"):
        kw: Dict[str, Any] = {"f": p.f}
        if name == "trimmed_mean":
            kw = {"beta": p.trim_beta}
        if name == "multi_krum":
            kw["m"] = max(1, int(p.multi_krum_m_frac * K))
        if name == "clustering":
            kw = {}
        out, _ = agg_lib.AGGREGATORS[name](updates, **kw)
        return out, t_state
    if name == "wfagg_d":
        out, _ = wf.wfagg_d_agg(updates, p.f)
        return out, t_state
    if name == "wfagg_c":
        out, _ = wf.wfagg_c_agg(updates, p.f)
        return out, t_state
    if name == "wfagg_e":
        return wf.wfagg_e_agg(local, updates, p.alpha), t_state
    if name == "wfagg_t":
        mask, new_t = wf.wfagg_t_select(
            t_state, updates, cfg.wfagg_config(backend=wfagg_backend))
        out = wf.wfagg_e(local, updates, mask.astype(jnp.float32), p.alpha)
        return out, new_t
    if name in ("wfagg", "alt_wfagg"):
        wcfg = _wfagg_full_config(cfg, K, backend=wfagg_backend)
        out, new_t, _ = wf.wfagg(local, updates, t_state, wcfg)
        return out, new_t
    raise ValueError(name)


def _aggregate_one_dyn(cfg: DFLConfig, local: Array, updates: Array,
                       valid: Array) -> Array:
    """Baseline aggregation over one PADDED slate: the valid-mask-aware
    ``core.aggregators.DYN_AGGREGATORS`` variants, with the same paper
    hyper-parameters as the static dispatch (Multi-Krum's keep count
    scales with the traced valid degree).  Degree-0 nodes (DoS'd /
    partitioned away) keep their local model — there is nothing to
    aggregate."""
    p = cfg.paper
    name = cfg.aggregator
    kw: Dict[str, Any] = {"f": p.f}
    if name == "trimmed_mean":
        kw = {"beta": p.trim_beta}
    if name == "clustering":
        kw = {}
    if name == "multi_krum":
        v = valid.astype(bool).sum()
        kw["m"] = jnp.maximum(
            (p.multi_krum_m_frac * v.astype(jnp.float32)).astype(jnp.int32), 1)
    out, _ = agg_lib.DYN_AGGREGATORS[name](updates, valid, **kw)
    return jnp.where(valid.astype(bool).sum() > 0, out, local)


# ---------------------------------------------------------------------------
# the round function
# ---------------------------------------------------------------------------

def build_round_fn(cfg: DFLConfig, topo: Topology, data: SyntheticImages,
                   dynamic: bool = False, telemetry: bool = False,
                   faults: Optional[flt.FaultConfig] = None) -> Callable:
    """One jitted DFL round.

    ``dynamic=False`` (default): returns ``round_fn(state)`` closed over
    the static topology — the paper's experiment.

    ``dynamic=True``: returns ``round_fn(state, neighbor_idx, valid,
    mal_mask)`` taking the round's (N, K) neighbor table, (N, K) valid
    mask and (N,) Byzantine mask as TRACED inputs — one compile serves a
    whole round-varying schedule (churn, link failure, mobility, sleeper
    attackers), graph after graph, with no retrace.  wfagg/alt_wfagg run
    the gather-free fused path; the mean/median/trimmed_mean/krum/
    multi_krum/clustering baselines route through the valid-mask-aware
    ``DYN_AGGREGATORS`` variants (a plain gather + per-node vmap — the
    baseline rows of the robustness matrix, not a kernel path).

    ``telemetry=True``: the round additionally returns a
    ``repro.obs.DecisionRecord`` — the packed per-edge verdict bitmask
    plus per-node accepted counts / mean-fallback flags / trust-weight
    entropy — as a second output: ``round_fn(...) -> (state, record)``.
    The record is built from masks the round already computes (pure
    traced jnp, no host callbacks, no extra kernel launch; see
    docs/OBSERVABILITY.md), so the model trajectory is bit-identical
    with telemetry on or off.

    NOTE: the WFAgg-T ring buffers in ``state.temporal`` are keyed by
    neighbor SLOT.  ``run_dynamic_experiment`` re-keys them to each
    round's slate by neighbor identity (``wf.realign_temporal_history``)
    before calling this; a caller driving rounds by hand on a changing
    slate must do the same, or neighbors inherit each other's histories
    when their slot shifts.
    """
    if telemetry and cfg.centralized:
        raise NotImplementedError(
            "telemetry records per-EDGE gossip verdicts; the CFL "
            "baseline has one server and no edges")
    if faults is not None and not dynamic:
        raise NotImplementedError(
            "fault injection rides the dynamic round form (traced "
            "per-round inputs); pass dynamic=True")
    if dynamic:
        if cfg.centralized:
            raise NotImplementedError("dynamic schedules are a gossip "
                                      "(decentralized) feature")
        if cfg.aggregator not in ("wfagg", "alt_wfagg") \
                and cfg.aggregator not in agg_lib.DYN_AGGREGATORS:
            raise NotImplementedError(
                f"aggregator {cfg.aggregator!r} has no valid-mask-aware "
                "form; dynamic schedules run through the wfagg/alt_wfagg "
                "gather-free path or the DYN_AGGREGATORS baselines")
        # any wfagg backend works here: the fused paths AND the reference
        # oracle all honor per-round valid masks (dynamic keep counts)
        return jax.jit(_make_round_core(cfg, data, telemetry=telemetry,
                                        faults=faults))

    neighbor_idx = jnp.asarray(topo.neighbor_indices)  # (N, K) padded
    # None on regular graphs: the indexed kernels then skip the mask and
    # the reference backend stays available for parity runs.
    neighbor_valid = (None if topo.is_regular
                      else jnp.asarray(topo.neighbor_valid))
    if neighbor_valid is not None and not cfg.centralized \
            and cfg.aggregator not in ("wfagg", "alt_wfagg") \
            and cfg.aggregator not in agg_lib.DYN_AGGREGATORS:
        raise NotImplementedError(
            f"aggregator {cfg.aggregator!r} has no valid-mask-aware form; "
            "irregular (padded) topologies are supported by the "
            "wfagg/alt_wfagg gather-free path or the DYN_AGGREGATORS "
            "baselines")
    malicious = jnp.asarray(topo.malicious)
    core = _make_round_core(cfg, data, telemetry=telemetry)
    return jax.jit(lambda state: core(state, neighbor_idx, neighbor_valid,
                                      malicious))


def _make_round_core(cfg: DFLConfig, data: SyntheticImages,
                     telemetry: bool = False,
                     faults: Optional[flt.FaultConfig] = None) -> Callable:
    """The round body, parameterized by the per-round topology inputs.
    With ``telemetry`` the body returns ``(DFLState, DecisionRecord)``;
    the record is derived from the masks/weights the aggregation already
    produced (baselines get :func:`repro.obs.record_uniform` — accepted
    = valid, no filter bits).

    With ``faults`` (a :class:`repro.dfl.faults.FaultConfig`) the body is
    the CHAOS round: it additionally takes the scan-carried
    ``TransportState`` and the round's ``FaultRound`` surface, routes the
    gossip through :func:`repro.dfl.faults.apply_transport` (drop / stale
    / duplicate / corrupt / crash re-keying over the stacked ring
    matrix), and returns ``(DFLState, TransportState[, record])``."""
    if faults is not None:
        return _make_chaos_round_core(cfg, data, telemetry, faults)

    def round_core(state: DFLState, neighbor_idx: Array,
                   neighbor_valid: Optional[Array],
                   mal_mask: Array) -> DFLState:
        # CFL: the server's WFAgg-E reference is the PREVIOUS round's
        # global model (captured before local training — the mean of
        # freshly-received models would itself be poisoned under IPM).
        prev_flat, _ = _ravel_nodes(state.node_params)
        params, momentum = _local_train(
            cfg, data, mal_mask, state.node_params, state.node_momentum,
            state.rnd
        )
        flat, unravel_one = _ravel_nodes(params)
        view = _defense_view(cfg, state, neighbor_idx, neighbor_valid)
        flat = _apply_attacks(cfg, mal_mask, flat, state.rnd, view)

        record = None
        if cfg.centralized:
            if telemetry:
                raise NotImplementedError(
                    "telemetry records per-EDGE gossip verdicts; the CFL "
                    "baseline has one server and no edges")
            # one server-side aggregation over all N received models
            t0 = jax.tree.map(lambda x: x[0], state.temporal) if state.temporal is not None else None
            global_prev = prev_flat[0]  # all nodes share the global model in CFL
            new_global, new_t0 = _aggregate_one(cfg, global_prev, flat, t0)
            new_flat = jnp.broadcast_to(new_global, flat.shape)
            new_temporal = (
                jax.tree.map(lambda x: x[None], new_t0) if new_t0 is not None else None
            )
        else:
            if cfg.aggregator in ("wfagg", "alt_wfagg"):
                # gather-free gossip: all N per-node aggregations in one
                # neighbor-indexed kernel launch — the (N, K, d) gossip
                # tensor never exists, the kernels DMA each neighbor's
                # d-blocks straight from the (N, d) model matrix (the
                # reference backend gathers, for parity runs)
                wcfg = _wfagg_full_config(cfg, neighbor_idx.shape[1])
                if cfg.mesh_model_shards > 1:
                    from repro.distributed import spmd
                    new_flat, new_temporal, info = spmd.wfagg_batch_sharded(
                        flat, flat, state.temporal, wcfg,
                        neighbor_idx, neighbor_valid,
                        mesh=spmd.aggregation_mesh(cfg.mesh_model_shards))
                else:
                    new_flat, new_temporal, info = wf.wfagg_batch(
                        flat, flat, state.temporal, wcfg,
                        neighbor_idx=neighbor_idx, valid=neighbor_valid)
                if telemetry:
                    # the indexed info dict carries the full 2-of-3 vote
                    # (mask_d/mask_c/mask_t/valid/weights) — pack it
                    record = obs_decision.record_from_info(info)
            elif state.temporal is not None:
                gathered = flat[neighbor_idx]  # (N, K, d) gossip exchange
                new_flat, new_temporal = jax.vmap(
                    lambda loc, upd, ts: _aggregate_one(
                        cfg, loc, upd, ts, wfagg_backend="reference")
                )(flat, gathered, state.temporal)
            elif neighbor_valid is not None:
                # baseline aggregators on a padded/dynamic slate: gossip
                # gather + the valid-mask-aware DYN_AGGREGATORS variants
                gathered = flat[neighbor_idx]  # (N, K, d) gossip exchange
                new_flat = jax.vmap(
                    lambda loc, upd, v: _aggregate_one_dyn(cfg, loc, upd, v)
                )(flat, gathered, neighbor_valid)
                new_temporal = None
            else:
                gathered = flat[neighbor_idx]  # (N, K, d) gossip exchange
                new_flat, _ = jax.vmap(
                    lambda loc, upd: _aggregate_one(cfg, loc, upd, None)
                )(flat, gathered)
                new_temporal = None
            if telemetry and record is None:
                # baselines have no per-edge filter verdicts: uniform
                # accept over the valid slate (degree-0 still tracked)
                valid_all = (neighbor_valid if neighbor_valid is not None
                             else jnp.ones(neighbor_idx.shape, bool))
                record = obs_decision.record_uniform(valid_all)

        new_params = jax.vmap(unravel_one)(new_flat)
        new_state = DFLState(new_params, momentum, new_temporal, state.rnd + 1)
        if telemetry:
            return new_state, record
        return new_state

    return round_core


def _make_chaos_round_core(cfg: DFLConfig, data: SyntheticImages,
                           telemetry: bool, fcfg: flt.FaultConfig) -> Callable:
    """The fault-injected round body (see ``_make_round_core``).

    Differences from the clean round, in execution order:
      * crash freeze — a down node neither trains nor transmits: its
        model row and momentum are held at last round's values, and its
        own slate is all-invalid (it keeps its local model);
      * transport — :func:`repro.dfl.faults.apply_transport` re-keys the
        neighbor table over the sanitized stacked ring matrix (fresh /
        stale / corrupt-bank rows), yielding the effective table, the
        surviving valid mask, and the WFAgg-T ``prev_idx`` staleness
        re-keying;
      * history hygiene — an edge with NO accepted delivery this round
        re-centers its WFAgg-T band at the pre-round EWMA mean instead
        of recording a metric against a payload it never saw.

    Everything is pure traced jnp on scan-carried state: no host
    transfer, no extra kernel launch, no (N, K, d) tensor on the
    wfagg/alt_wfagg path (the ``chaos_scan`` lint entry pins all three).
    """
    if cfg.centralized:
        raise NotImplementedError("fault injection is a gossip (decentral"
                                  "ized) feature; CFL has no transport")
    if cfg.mesh_model_shards > 1:
        raise NotImplementedError(
            "chaos transport + model-dim sharding: the stacked ring "
            "matrix is not sharded yet (see docs/FAULTS.md)")

    def round_core(state: DFLState, neighbor_idx: Array,
                   neighbor_valid: Optional[Array], mal_mask: Array,
                   ts: flt.TransportState, fr: flt.FaultRound):
        prev_flat, _ = _ravel_nodes(state.node_params)
        params, momentum = _local_train(
            cfg, data, mal_mask, state.node_params, state.node_momentum,
            state.rnd
        )
        flat, unravel_one = _ravel_nodes(params)
        view = _defense_view(cfg, state, neighbor_idx, neighbor_valid)
        flat = _apply_attacks(cfg, mal_mask, flat, state.rnd, view)
        # crash freeze: a down node broadcasts (and keeps) its stored
        # model; its training step and momentum advance are discarded
        down = fr.down.astype(bool)
        flat = jnp.where(down[:, None], prev_flat, flat)
        momentum = jax.tree.map(
            lambda old, new: jnp.where(
                down.reshape((-1,) + (1,) * (new.ndim - 1)), old, new),
            state.node_momentum, momentum)

        valid = (neighbor_valid if neighbor_valid is not None
                 else jnp.ones(neighbor_idx.shape, bool))
        tout = flt.apply_transport(flat, ts, neighbor_idx, valid, fr, fcfg,
                                   state.rnd)

        record = None
        if cfg.aggregator in ("wfagg", "alt_wfagg"):
            wcfg = _wfagg_full_config(cfg, neighbor_idx.shape[1])
            t_in = state.temporal
            mu_s = mu_b = None
            matrix_prev = t_in is not None and t_in.prev.ndim == 2
            if matrix_prev:
                if wcfg.use_temporal:
                    # pre-round EWMA centers: the hygiene value a no-
                    # delivery edge pushes instead of a garbage metric
                    mu_s, _ = jax.vmap(
                        lambda h, c: trust.ewma_mean_std(h, c, wcfg.ewma_decay)
                    )(t_in.hist_s, t_in.count)
                    mu_b, _ = jax.vmap(
                        lambda h, c: trust.ewma_mean_std(h, c, wcfg.ewma_decay)
                    )(t_in.hist_b, t_in.count)
                # the carried (N, d) prev is superseded by the stacked
                # matrix + prev_idx (the payload each edge ACTUALLY
                # served last round, aged one round)
                t_in = t_in._replace(prev=tout.full)
            new_flat, new_temporal, info = wf.wfagg_batch(
                flat, tout.full, t_in, wcfg,
                neighbor_idx=tout.eff_idx, valid=tout.eff_valid,
                prev_idx=tout.prev_idx)
            if matrix_prev and new_temporal is not None:
                hist_s, hist_b = new_temporal.hist_s, new_temporal.hist_b
                if mu_s is not None:
                    hist_s = hist_s.at[:, 0, :].set(
                        jnp.where(tout.eff_valid, hist_s[:, 0, :], mu_s))
                    hist_b = hist_b.at[:, 0, :].set(
                        jnp.where(tout.eff_valid, hist_b[:, 0, :], mu_b))
                new_temporal = new_temporal._replace(
                    prev=flat, hist_s=hist_s, hist_b=hist_b)
            if telemetry:
                record = obs_decision.record_from_info(info)
        else:
            # baselines gather (they already do on the dynamic path);
            # the valid-aware variants see the post-fault slate
            gathered = tout.full[tout.eff_idx]
            new_flat = jax.vmap(
                lambda loc, upd, v: _aggregate_one_dyn(cfg, loc, upd, v)
            )(flat, gathered, tout.eff_valid)
            new_temporal = None
            if telemetry:
                record = obs_decision.record_uniform(tout.eff_valid)
        if telemetry:
            record = obs_decision.with_fault_bits(
                record, tout.dropped, tout.stale, tout.corrupt)

        # a down receiver aggregates nothing (its slate is all-invalid so
        # this is already true on the wfagg path; make it explicit)
        new_flat = jnp.where(down[:, None], prev_flat, new_flat)
        new_params = jax.vmap(unravel_one)(new_flat)
        new_ts = flt.advance_ring(ts, flat, tout.served_lag)
        new_state = DFLState(new_params, momentum, new_temporal,
                             state.rnd + 1)
        if telemetry:
            return new_state, new_ts, record
        return new_state, new_ts

    return round_core


def _ravel_nodes(params):
    one = jax.tree.map(lambda x: x[0], params)
    _, unravel_one = ravel_pytree(one)
    flat = jax.vmap(lambda t: ravel_pytree(t)[0])(params)
    return flat, unravel_one


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def evaluate(cfg: DFLConfig, topo: Topology, data: SyntheticImages,
             state: DFLState, n_test: int = 512,
             malicious: Optional[np.ndarray] = None,
             adjacency: Optional[np.ndarray] = None) -> Dict[str, Any]:
    """Per-node accuracy + consistency snapshot.

    ``malicious``/``adjacency`` override the static topology's — dynamic
    scenarios pass the schedule's ever-malicious set and the evaluation
    round's graph, so the benign cohort excludes every attacker and the
    malicious-neighbor buckets reflect the graph the nodes actually
    saw."""
    _, fwd = _model_fns(cfg)
    imgs, labels = data.test_set(n_test)
    accs = jax.vmap(lambda p: met.micro_accuracy(fwd(p, imgs), labels))(state.node_params)
    accs = np.asarray(accs)
    mal = np.asarray(topo.malicious if malicious is None else malicious)
    adj = np.asarray(topo.adjacency if adjacency is None else adjacency)
    benign = ~mal
    mal_nb = (adj & mal[None, :]).sum(axis=1)
    flat, _ = _ravel_nodes(state.node_params)
    r2 = float(met.r_squared(jnp.asarray(np.asarray(flat)[benign])))
    by_mn = {}
    # bucket by every malicious-neighbor count the topology realizes
    # (dense placements can put >= 3 malicious nodes next to a benign
    # one; hardcoded buckets would silently drop those nodes)
    for m in range(max(2, int(mal_nb.max(initial=0))) + 1):
        sel = benign & (mal_nb == m)
        by_mn[m] = float(accs[sel].mean()) if sel.any() else float("nan")
    return {
        "acc_benign_mean": float(accs[benign].mean()),
        "acc_by_malicious_neighbors": by_mn,
        "r_squared": r2,
        "acc_all": accs.tolist(),
    }


def _series_from_trace(trace) -> Dict[str, list]:
    """Columnar per-round time series (plottable) from a trace of
    ``evaluate`` dicts."""
    return {
        "round": [e["round"] for e in trace],
        "acc_benign_mean": [e["acc_benign_mean"] for e in trace],
        "r_squared": [e["r_squared"] for e in trace],
    }


def _telemetry_out(record, neighbor_idx, valid, malicious) -> Dict[str, Any]:
    """Host-side telemetry bundle: the stacked (R, …) ``DecisionRecord``
    fields plus the slate context (``(R, N, K)`` tables, ``(R, N)``
    Byzantine masks) a report needs to split attacker from benign edges
    (``repro.obs.report.filter_rates``)."""
    return {
        "verdict": np.asarray(record.verdict),
        "accepted": np.asarray(record.accepted),
        "mean_fallback": np.asarray(record.mean_fallback),
        "degree_zero": np.asarray(record.degree_zero),
        "entropy": np.asarray(record.entropy),
        "neighbor_idx": np.asarray(neighbor_idx),
        "valid": np.asarray(valid),
        "malicious": np.asarray(malicious),
    }


def run_experiment(cfg: DFLConfig, topo: Topology, data: SyntheticImages,
                   rounds: Optional[int] = None, eval_every: int = 1,
                   telemetry: bool = False) -> Dict[str, Any]:
    """Run a full DFL experiment; returns the per-round metric trace and
    the columnar ``series`` time series (accuracy, consistency).

    Decentralized runs always track the per-node mean-fallback /
    degree-0 flags (the masks are already computed; a node silently
    keeping its local model is an event worth a series column) —
    ``series["mean_fallback_count"]`` / ``series["degree_zero_count"]``
    per round, plus ``trace[i]["mean_fallback_nodes"]`` at evaluation
    rounds.  ``telemetry=True`` additionally returns the full
    per-round/per-edge record under ``out["telemetry"]`` (see
    ``repro.obs`` / docs/OBSERVABILITY.md).
    """
    rounds = rounds or cfg.paper.rounds
    if telemetry and cfg.centralized:
        raise NotImplementedError(
            "telemetry records per-EDGE gossip verdicts; the CFL "
            "baseline has one server and no edges")
    track = not cfg.centralized
    state = init_dfl_state(cfg, topo)
    round_fn = build_round_fn(cfg, topo, data, telemetry=track)
    trace = []
    records = []
    fallback_counts, degree_zero_counts = [], []
    mf = None
    for r in range(rounds):
        if track:
            state, rec = round_fn(state)
            mf = np.asarray(rec.mean_fallback)
            fallback_counts.append(int(mf.sum()))
            degree_zero_counts.append(int(np.asarray(rec.degree_zero).sum()))
            if telemetry:
                records.append(jax.device_get(rec))
        else:
            state = round_fn(state)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            e = evaluate(cfg, topo, data, state)
            e["round"] = r + 1
            if mf is not None:
                e["mean_fallback_nodes"] = np.flatnonzero(mf).tolist()
            trace.append(e)
    series = _series_from_trace(trace)
    if track:
        series["mean_fallback_count"] = fallback_counts
        series["degree_zero_count"] = degree_zero_counts
    out = {"trace": trace, "final": trace[-1], "series": series,
           "aggregator": cfg.aggregator,
           "attack": cfg.attack, "centralized": cfg.centralized}
    if telemetry:
        record = jax.tree.map(lambda *xs: np.stack(xs), *records)
        R = len(records)
        nv = (np.ones_like(topo.neighbor_indices, bool)
              if topo.is_regular else np.asarray(topo.neighbor_valid))
        out["telemetry"] = _telemetry_out(
            record,
            np.broadcast_to(np.asarray(topo.neighbor_indices), (R,) + nv.shape),
            np.broadcast_to(nv, (R,) + nv.shape),
            np.broadcast_to(np.asarray(topo.malicious),
                            (R, topo.n_nodes)))
    return out


# ---------------------------------------------------------------------------
# dynamic-topology experiments (round-varying schedules)
# ---------------------------------------------------------------------------

def build_dynamic_scan_fn(cfg: DFLConfig, topo: Topology,
                          data: SyntheticImages,
                          schedule: TopologySchedule,
                          n_test: int = 256, telemetry: bool = False,
                          faults: Optional[flt.FaultSchedule] = None):
    """The ONE-jit schedule scan behind ``run_dynamic_experiment``.

    Returns ``(state, run, sched)``: the initial state, the jitted
    ``run(state, neighbor_idx, valid, malicious) -> (state, series)``
    scan, and the schedule's ``(R, N, K)`` / ``(R, N)`` arrays.  Exposed
    separately so the static-analysis entry registry (``repro.analysis``)
    lints the EXACT computation the experiment driver runs — same jit,
    same scan body — not a re-derived lookalike.

    ``telemetry=True`` appends the per-round ``DecisionRecord`` to the
    scan outputs — ``run(...) -> (state, (accs, acc_benign, r2,
    record))`` with the record's leaves stacked to leading axis R.  The
    record is a pure traced output of masks the round already computes:
    no host callback enters the scan body (the ``dynamic_scan_telemetry``
    lint entry pins launch count and the no-host-transfer rule).

    ``faults`` (a :class:`repro.dfl.faults.FaultSchedule`) switches to
    the CHAOS form: the first return value becomes the full scan CARRY
    ``(state, prev_idx, prev_val, TransportState)``, ``run(carry,
    neighbor_idx, valid, malicious, drop, lag, dup, corrupt, down)``
    takes that carry explicitly and returns the FINAL carry (so a
    checkpointed run can stop and resume mid-schedule; see
    train/checkpoint.py and docs/FAULTS.md), and ``sched`` grows the
    five fault stacks.  Still one jit, one scan, one compile.
    """
    if schedule.n_nodes != topo.n_nodes:
        raise ValueError(
            f"schedule is for {schedule.n_nodes} nodes, topology has "
            f"{topo.n_nodes}")
    state = init_dfl_state(cfg, topo, degree=schedule.width)
    round_core = build_round_fn(cfg, topo, data, dynamic=True,
                                telemetry=telemetry,
                                faults=faults.config if faults else None)
    _, fwd = _model_fns(cfg)
    imgs, labels = data.test_set(n_test)
    sched = (jnp.asarray(schedule.neighbor_idx),
             jnp.asarray(schedule.valid),
             jnp.asarray(schedule.malicious))
    # Evaluation cohort: a node that is malicious in ANY round is an
    # attacker, full stop — a churned-out (or not-yet-woken) attacker
    # sends nothing to poison that round (the per-round mask drives the
    # ATTACK side), but its own stored model is still attacker state and
    # must not dilute the benign accuracy/consistency series.
    ever_mal = jnp.asarray(schedule.malicious.any(axis=0))

    def eval_out(st):
        accs = jax.vmap(
            lambda p: met.micro_accuracy(fwd(p, imgs), labels)
        )(st.node_params)
        benign = ~ever_mal
        bw = benign.astype(jnp.float32)
        acc_benign = jnp.sum(accs * bw) / jnp.maximum(bw.sum(), 1.0)
        flat, _ = _ravel_nodes(st.node_params)
        return (accs, acc_benign, met.r_squared(flat, weights=bw))

    if faults is not None:
        if faults.rounds != schedule.rounds:
            raise ValueError(
                f"fault schedule has {faults.rounds} rounds, topology "
                f"schedule has {schedule.rounds}")
        flat0, _ = _ravel_nodes(state.node_params)
        ts0 = flt.init_transport_state(
            faults.config, topo.n_nodes, schedule.width, flat0.shape[1])
        sched = sched + faults.xs()

        @jax.jit
        def run_chaos(carry, neighbor_idx, valid, malicious,
                      drop, lag, dup, corrupt, down):
            def body(carry, xs):
                st, prev_idx, prev_val, ts = carry
                idx, val, mal = xs[:3]
                fr = flt.FaultRound(*xs[3:])
                if st.temporal is not None:
                    st = st._replace(temporal=wf.realign_temporal_history(
                        st.temporal, prev_idx, prev_val, idx, val))
                # the served-lag table is slot-keyed like the temporal
                # history: re-key it to this round's slate too
                ts = ts._replace(served_lag=flt.realign_served_lag(
                    ts.served_lag, prev_idx, prev_val, idx, val))
                if telemetry:
                    st, ts, record = round_core(st, idx, val, mal, ts, fr)
                else:
                    st, ts = round_core(st, idx, val, mal, ts, fr)
                out = eval_out(st)
                if telemetry:
                    out = out + (record,)
                return (st, idx, val, ts), out
            carry, out = jax.lax.scan(
                body, carry,
                (neighbor_idx, valid, malicious, drop, lag, dup, corrupt,
                 down))
            return carry, out

        carry0 = (state, sched[0][0], sched[1][0], ts0)
        return carry0, run_chaos, sched

    @jax.jit
    def run(state, neighbor_idx, valid, malicious):
        def body(carry, xs):
            st, prev_idx, prev_val = carry
            idx, val, mal = xs
            if st.temporal is not None:
                # the WFAgg-T ring buffers are slot-keyed: re-key them to
                # this round's slate by neighbor IDENTITY, so a neighbor
                # that shifted slots (or rejoined) is scored against ITS
                # history, not whoever held the slot before
                st = st._replace(temporal=wf.realign_temporal_history(
                    st.temporal, prev_idx, prev_val, idx, val))
            if telemetry:
                st, record = round_core(st, idx, val, mal)
            else:
                st = round_core(st, idx, val, mal)
            out = eval_out(st)
            if telemetry:
                out = out + (record,)
            return (st, idx, val), out
        # the round-0 "previous" slate is round 0's own (identity match:
        # the buffers are all-zero anyway, any remap is a no-op)
        init = (state, neighbor_idx[0], valid[0])
        (st, _, _), out = jax.lax.scan(
            body, init, (neighbor_idx, valid, malicious))
        return st, out

    return state, run, sched


def run_dynamic_experiment(cfg: DFLConfig, topo: Topology,
                           data: SyntheticImages,
                           schedule: TopologySchedule,
                           n_test: int = 256,
                           telemetry: bool = False,
                           faults: Optional[flt.FaultSchedule] = None,
                           stop_after: Optional[int] = None,
                           checkpoint_dir: Optional[str] = None,
                           checkpoint_name: str = "chaos",
                           resume_from: Optional[str] = None) -> Dict[str, Any]:
    """Run a DFL experiment under a round-varying topology schedule.

    ONE jit: ``lax.scan`` over the (R, N, K) neighbor-table / valid-mask
    / (R, N) malicious-mask schedule, with the round function taking all
    three as traced per-round inputs — the graph and the Byzantine set
    change every round, the compile happens once.  Per-round accuracy
    and consistency are computed INSIDE the scan (a DART-style
    robustness time series), so dynamic scenarios are plottable without
    host round-trips.  The returned dict keeps ``run_experiment``'s
    shape (trace / final / series).

    ``telemetry=True`` turns on the decision plane: the scan emits the
    per-round (N, K) verdict bitmask + per-node summaries as extra
    traced outputs, returned under ``out["telemetry"]`` alongside the
    schedule context, with the mean-fallback / degree-0 / accepted-count
    time series joined into ``series``.  Model trajectories are
    bit-identical with telemetry on or off (the record only READS masks
    the round already computes).

    Chaos transport (``faults``, a ``repro.dfl.faults.FaultSchedule``):
    the scan additionally consumes the per-round fault surface and
    carries the delivery ring (see docs/FAULTS.md).  Fault runs are
    CHECKPOINTABLE: ``stop_after=r`` runs only rounds [0, r) and — with
    ``checkpoint_dir`` — snapshots the full scan carry (models,
    momentum, WFAgg-T ring buffers, transport ring, round counter; the
    in-scan PRNG streams all derive from the carried round counter) plus
    the in-flight topology + fault schedules via ``train/checkpoint.py``.
    ``resume_from=dir`` restores that snapshot and runs the REMAINING
    rounds, reproducing the uninterrupted trajectory bit-exactly (use a
    ``make_fault_schedule("none", ...)`` schedule to checkpoint a
    fault-free run).  ``out["rounds_run"]`` records the [start, end)
    window a partial run covered.
    """
    if (stop_after is not None or resume_from is not None
            or checkpoint_dir is not None) and faults is None:
        raise NotImplementedError(
            "checkpoint/resume rides the chaos scan form (the run "
            "function must return its carry); pass faults="
            "make_fault_schedule('none', schedule, 0.0) for a "
            "fault-free checkpointable run")
    state, run, sched = build_dynamic_scan_fn(cfg, topo, data, schedule,
                                              n_test=n_test,
                                              telemetry=telemetry,
                                              faults=faults)
    ever_mal = jnp.asarray(schedule.malicious.any(axis=0))
    record = None
    R = schedule.rounds
    r0, r_end = 0, R
    if faults is None:
        if telemetry:
            state, (acc_all, acc_benign, r2, record) = run(state, *sched)
        else:
            state, (acc_all, acc_benign, r2) = run(state, *sched)
    else:
        from repro.train import checkpoint as ckpt
        carry = state
        if resume_from is not None:
            # the snapshot carries the schedules too: the resumed scan
            # replays the IN-FLIGHT fault surface, not a reconstruction
            carry, sched, meta = ckpt.restore_experiment_checkpoint(
                resume_from, checkpoint_name, carry, sched)
            r0 = int(meta["round"])
        r_end = R if stop_after is None else int(stop_after)
        if not r0 < r_end <= R:
            raise ValueError(
                f"round window [{r0}, {r_end}) is empty or exceeds the "
                f"{R}-round schedule")
        xs = tuple(a[r0:r_end] for a in sched)
        if telemetry:
            carry, (acc_all, acc_benign, r2, record) = run(carry, *xs)
        else:
            carry, (acc_all, acc_benign, r2) = run(carry, *xs)
        state = carry[0]
        if checkpoint_dir is not None:
            ckpt.save_experiment_checkpoint(
                checkpoint_dir, checkpoint_name, carry, sched,
                metadata={"round": r_end, "rounds_total": R,
                          "fault_config":
                              dataclasses.asdict(faults.config),
                          "fault_summary": faults.summary()})
    acc_all = np.asarray(acc_all)
    acc_benign = np.asarray(acc_benign)
    r2 = np.asarray(r2)
    trace = [{
        "round": r0 + i + 1,
        "acc_benign_mean": float(acc_benign[i]),
        "r_squared": float(r2[i]),
        "acc_all": acc_all[i].tolist(),
    } for i in range(r_end - r0)]
    # full evaluation (incl. malicious-neighbor buckets) under the FINAL
    # round's graph, with the ever-malicious cohort (same n_test as the
    # in-scan series, so final agrees with trace[-1])
    final = evaluate(cfg, topo, data, state, n_test=n_test,
                     malicious=np.asarray(ever_mal),
                     adjacency=schedule.adjacency[r_end - 1])
    final["round"] = r_end
    series = _series_from_trace(trace)
    series["degree_min_mean_max"] = (
        schedule.degree_stats()[r0:r_end].tolist())
    out = {"trace": trace, "final": final, "series": series,
           "aggregator": cfg.aggregator, "attack": cfg.attack,
           "centralized": cfg.centralized}
    if faults is not None:
        out["faults"] = faults.summary()
        out["rounds_run"] = [r0, r_end]
    if record is not None:
        record = jax.device_get(record)
        series["mean_fallback_count"] = (
            np.asarray(record.mean_fallback).sum(axis=1).astype(int).tolist())
        series["degree_zero_count"] = (
            np.asarray(record.degree_zero).sum(axis=1).astype(int).tolist())
        series["accepted_mean"] = [
            float(x) for x in np.asarray(record.accepted).mean(axis=1)]
        out["telemetry"] = _telemetry_out(
            record, schedule.neighbor_idx[r0:r_end],
            schedule.valid[r0:r_end], schedule.malicious[r0:r_end])
    return out
