"""Chaos transport: fault-injected gossip delivery for the DFL engine.

The dynamics engine (``repro.dfl.dynamics``) varies WHO talks to whom;
this module varies HOW WELL the talking goes.  Every message that the
topology schedule says is delivered can independently be

  dropped        the packet never arrives (lossy link),
  stale          a straggler delivers the sender's model from ``lag``
                 rounds ago instead of the fresh one,
  duplicated     the network re-delivers last round's packet,
  corrupted      the payload arrives bit-damaged — NaN / +-Inf rows or
                 finite garbage, generated in-scan from a PRNG keyed by
                 (round, edge),
  crashed        the sender is down for the round: it neither trains nor
                 transmits, and everything it would have received is lost
                 (crash-restart: when the node comes back it resumes from
                 its frozen state).

Like the topology scenarios, fault schedules are precomputed host-side
by deterministic numpy generators into scan-friendly ``(R, N, K)`` /
``(R, N)`` stacks (:class:`FaultSchedule`), so a whole faulty experiment
still compiles ONCE and runs through ``jax.lax.scan``.

The delivery mechanics are the *stacked-ring-matrix* trick: the scan
carries a bounded L-deep ring of past post-attack model matrices
(:class:`TransportState`), and :func:`apply_transport` builds one 2-D
``((L+1)*M + C, d)`` stacked matrix

    [ flat (M rows) | ring (L*M rows) | corrupt bank (C rows) ]

then *re-keys the neighbor table* instead of materializing per-edge
payloads: a fresh delivery reads row ``idx``, a lag-l delivery reads row
``l*M + idx``, a corrupted delivery reads a bank row.  The gossip
kernels are untouched — they DMA rows from a 2-D matrix exactly as
before, the (N, K, d) tensor still never exists, and the launch count
stays 1 (the ``chaos_scan`` lint entry pins it).

Graceful degradation, in order:
  * sanitizer — non-finite rows of the stacked matrix are zeroed and the
    edges that read them demoted to invalid BEFORE filter statistics, so
    the indexed kernel's median/mean never sees a NaN;
  * retry-as-redundancy — a dropped/duplicated delivery falls back to
    re-serving the last delivered payload, aged one round
    (``served_lag + 1``), valid while within ``staleness_budget``;
  * staleness pricing — the per-edge ``prev`` index table points at the
    payload the edge ACTUALLY served last round, so WFAgg-T's
    round-over-round metrics price the lag instead of comparing against
    a model the receiver never saw.

See docs/FAULTS.md for the taxonomy and the resume workflow.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import TopologySchedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static transport parameters (hashable: rides into jit closures).

    ``ring_depth`` L bounds how old a served payload can be (the scan
    carries L past model matrices); ``staleness_budget`` is the oldest
    lag a receiver ACCEPTS — a delivery older than the budget is demoted
    to invalid and the node's slate shrinks.  ``bank_size`` C is the
    number of corrupt-payload rows appended to the stacked matrix;
    ``garbage_scale`` sizes the finite-garbage corruption rows (those
    must survive the sanitizer and be caught by the filters instead).
    """

    ring_depth: int = 3
    staleness_budget: int = 2
    bank_size: int = 4
    max_lag: int = 2          # largest scheduled straggler lag
    garbage_scale: float = 1e3
    seed: int = 0             # keys the in-scan corruption PRNG

    def __post_init__(self):
        if self.ring_depth < 1:
            raise ValueError("ring_depth must be >= 1")
        if self.max_lag > self.ring_depth:
            raise ValueError(
                f"max_lag={self.max_lag} exceeds ring_depth={self.ring_depth}"
                " — the ring cannot serve a payload that old")
        if self.bank_size < 1:
            raise ValueError("bank_size must be >= 1")


class FaultRound(NamedTuple):
    """One round's fault surface (the per-round xs of the scan)."""

    drop: Array      # (N, K) bool  packet lost on this edge
    lag: Array       # (N, K) int32 scheduled straggler lag (0 = fresh)
    dup: Array       # (N, K) bool  re-delivery of last round's packet
    corrupt: Array   # (N, K) bool  payload bit-damaged on the wire
    down: Array      # (N,)   bool  node crashed for this round


class TransportState(NamedTuple):
    """Scan-carried delivery state.

    ``ring[l]`` is the post-attack model matrix from ``l + 1`` rounds ago
    (``ring[0]`` = last round), so the stacked matrix serves lag ``l``
    from row block ``l * M``.  ``served_lag[n, k]`` is the age of the
    payload edge (n, k) actually delivered last round — the anchor for
    both the retry fallback and the WFAgg-T prev re-keying.
    """

    ring: Array        # (L, M, d) f32
    served_lag: Array  # (N, K) int32


class TransportOut(NamedTuple):
    """What :func:`apply_transport` hands the aggregation stage."""

    full: Array        # ((L+1)*M + C, d) sanitized stacked matrix
    eff_idx: Array     # (N, K) int32 re-keyed neighbor table into ``full``
    eff_valid: Array   # (N, K) bool  surviving edges after faults + budget
    prev_idx: Array    # (N, K) int32 last round's delivery, aged, in ``full``
    served_lag: Array  # (N, K) int32 next round's served_lag carry
    dropped: Array     # (N, K) bool  telemetry: delivery was dropped
    stale: Array       # (N, K) bool  telemetry: delivered but lag > 0
    corrupt: Array     # (N, K) bool  telemetry: corruption hit the edge


def init_transport_state(cfg: FaultConfig, n_nodes: int, width: int,
                         d: int) -> TransportState:
    return TransportState(
        ring=jnp.zeros((cfg.ring_depth, n_nodes, d), jnp.float32),
        served_lag=jnp.zeros((n_nodes, width), jnp.int32),
    )


def corrupt_bank(cfg: FaultConfig, d: int, rnd: Array) -> Array:
    """(C, d) corrupted-payload rows for round ``rnd``, generated in-scan.

    Rows cycle NaN / +Inf / -Inf / finite-garbage with the round, so
    every corruption flavor is exercised; the PRNG is keyed by
    (cfg.seed, round) — bit-reproducible, and a resumed scan regenerates
    the identical bank from the carried round counter.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 9173), rnd)
    noise = cfg.garbage_scale * jax.random.normal(
        key, (cfg.bank_size, d), jnp.float32)
    kind = ((jnp.arange(cfg.bank_size, dtype=jnp.int32) + rnd) % 4)[:, None]
    bank = jnp.where(kind == 0, jnp.nan, noise)
    bank = jnp.where(kind == 1, jnp.inf, bank)
    return jnp.where(kind == 2, -jnp.inf, bank)


def apply_transport(flat: Array, ts: TransportState, neighbor_idx: Array,
                    valid: Array, fr: FaultRound, cfg: FaultConfig,
                    rnd: Array) -> TransportOut:
    """Re-key one round's gossip through the fault surface.

    Pure traced jnp on scan-carried state — no host transfers, no new
    kernel launches, no (N, K, d) tensor (everything d-sized stays 2-D).
    """
    M, d = flat.shape
    N, K = neighbor_idx.shape
    L, C = cfg.ring_depth, cfg.bank_size
    valid_b = valid.astype(bool)

    bank = corrupt_bank(cfg, d, rnd)
    full = jnp.concatenate([flat, ts.ring.reshape(L * M, d), bank], axis=0)

    # --- which payload age does each edge get? ---------------------------
    # re-serving last round's delivery makes it one round older, capped at
    # the ring depth (the oldest representable payload)
    relag = jnp.minimum(ts.served_lag + 1, L)
    sender_down = fr.down[neighbor_idx]
    drop = (fr.drop | sender_down) & valid_b
    lag = jnp.clip(fr.lag, 0, L)
    lag = jnp.where(fr.dup & valid_b, relag, lag)
    lag = jnp.where(drop, relag, lag)         # retry-as-redundancy fallback
    # a payload older than the round count does not exist (the ring is
    # zero-initialized), and one older than the budget is not accepted
    ok = (lag <= cfg.staleness_budget) & (lag <= rnd)
    eff_valid = valid_b & ok & ~fr.down[:, None]

    eff_idx = lag * M + neighbor_idx
    corrupt = fr.corrupt & eff_valid
    slot = ((jnp.arange(N, dtype=jnp.int32)[:, None] * K
             + jnp.arange(K, dtype=jnp.int32)[None, :] + rnd) % C)
    eff_idx = jnp.where(corrupt, (L + 1) * M + slot, eff_idx)

    # --- sanitizer: the kernels must never see a non-finite row ----------
    finite = jnp.isfinite(full).all(axis=1)
    full = jnp.where(finite[:, None], full, 0.0)
    eff_valid = eff_valid & finite[eff_idx]

    # --- staleness pricing: where was last round's delivery? -------------
    # the payload edge (n, k) served last round is one round older now;
    # WFAgg-T compares against what the receiver ACTUALLY saw
    prev_idx = relag * M + neighbor_idx

    # an edge that delivered records its lag; an edge that did not keeps
    # (re-ages) its last delivery — consecutive drops walk down the ring
    # until the budget demotes them
    served_lag = jnp.where(eff_valid, lag, relag)

    return TransportOut(
        full=full, eff_idx=eff_idx, eff_valid=eff_valid, prev_idx=prev_idx,
        served_lag=served_lag,
        dropped=drop | (valid_b & ~ok),
        stale=eff_valid & (lag > 0) & ~corrupt,
        corrupt=fr.corrupt & valid_b,
    )


def advance_ring(ts: TransportState, flat: Array,
                 served_lag: Array) -> TransportState:
    """Post-round carry: push this round's (post-attack, post-freeze)
    model matrix into ring slot 0 and adopt the new served-lag table."""
    return TransportState(
        ring=jnp.concatenate([flat[None], ts.ring[:-1]], axis=0),
        served_lag=served_lag,
    )


def realign_served_lag(served: Array, prev_idx: Array, prev_valid: Array,
                       idx: Array, valid: Array) -> Array:
    """Re-key the slot-positional served-lag table to a new slate.

    Same identity-match contraction as ``wf.realign_temporal_history``:
    column k_new inherits the served lag of the k_old with matching
    neighbor id (both slots valid); a neighbor unseen last round starts
    at lag 0 — its "previous delivery" defaults to the freshest ring
    entry, mirroring the zeroed history column the temporal realign
    gives strangers.
    """
    match = ((idx[:, :, None] == prev_idx[:, None, :])
             & valid.astype(bool)[:, :, None]
             & prev_valid.astype(bool)[:, None, :])   # (N, K_new, K_old)
    m = match.astype(jnp.float32)
    return jnp.einsum("nkj,nj->nk", m, served.astype(jnp.float32)
                      ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# schedules: deterministic host-side generators (mirrors dynamics.SCENARIOS)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Precomputed per-round fault surface for a whole experiment.

    Array stacks match :class:`FaultRound` with a leading R axis; the
    static :class:`FaultConfig` travels with them so a checkpoint can
    reconstruct the exact transport semantics on resume.
    """

    drop: np.ndarray     # (R, N, K) bool
    lag: np.ndarray      # (R, N, K) int32
    dup: np.ndarray      # (R, N, K) bool
    corrupt: np.ndarray  # (R, N, K) bool
    down: np.ndarray     # (R, N) bool
    config: FaultConfig = FaultConfig()

    @property
    def rounds(self) -> int:
        return self.drop.shape[0]

    def xs(self):
        """The scan xs: device arrays in FaultRound field order."""
        return (jnp.asarray(self.drop), jnp.asarray(self.lag),
                jnp.asarray(self.dup), jnp.asarray(self.corrupt),
                jnp.asarray(self.down))

    def summary(self) -> Dict[str, float]:
        return {
            "drop_rate": float(self.drop.mean()),
            "stale_rate": float((self.lag > 0).mean()),
            "dup_rate": float(self.dup.mean()),
            "corrupt_rate": float(self.corrupt.mean()),
            "down_rate": float(self.down.mean()),
        }


def _zeros(rounds: int, n: int, k: int):
    return (np.zeros((rounds, n, k), bool), np.zeros((rounds, n, k), np.int32),
            np.zeros((rounds, n, k), bool), np.zeros((rounds, n, k), bool),
            np.zeros((rounds, n), bool))


def _gen_none(rng, rounds, n, k, intensity, cfg, **_):
    return _zeros(rounds, n, k)


def _gen_drop(rng, rounds, n, k, intensity, cfg, **_):
    drop, lag, dup, corrupt, down = _zeros(rounds, n, k)
    drop[:] = rng.random((rounds, n, k)) < intensity
    return drop, lag, dup, corrupt, down


def _gen_stale(rng, rounds, n, k, intensity, cfg, max_lag=None, **_):
    drop, lag, dup, corrupt, down = _zeros(rounds, n, k)
    ml = int(max_lag if max_lag is not None else cfg.max_lag)
    hit = rng.random((rounds, n, k)) < intensity
    lag[:] = np.where(hit, rng.integers(1, ml + 1, (rounds, n, k)), 0)
    return drop, lag, dup, corrupt, down


def _gen_duplicate(rng, rounds, n, k, intensity, cfg, **_):
    drop, lag, dup, corrupt, down = _zeros(rounds, n, k)
    dup[:] = rng.random((rounds, n, k)) < intensity
    return drop, lag, dup, corrupt, down


def _gen_corrupt(rng, rounds, n, k, intensity, cfg, **_):
    drop, lag, dup, corrupt, down = _zeros(rounds, n, k)
    corrupt[:] = rng.random((rounds, n, k)) < intensity
    return drop, lag, dup, corrupt, down


def _gen_crash_restart(rng, rounds, n, k, intensity, cfg,
                       p_restart=0.5, **_):
    """Markov crash/restart per node: up -> down with p = intensity per
    round, down -> up with ``p_restart`` — nodes freeze while down and
    resume from their stored state when back."""
    drop, lag, dup, corrupt, down = _zeros(rounds, n, k)
    state = np.zeros((n,), bool)
    for r in range(rounds):
        crash = rng.random(n) < intensity
        restart = rng.random(n) < p_restart
        state = np.where(state, ~restart, crash)
        down[r] = state
    return drop, lag, dup, corrupt, down


def _gen_chaos(rng, rounds, n, k, intensity, cfg, **params):
    """Everything at once, scaled so total disruption tracks intensity:
    drop + stale at intensity/2, duplicate/corrupt/crash at intensity/4."""
    drop, lag, dup, corrupt, down = _gen_drop(
        rng, rounds, n, k, intensity / 2, cfg)
    _, lag, _, _, _ = _gen_stale(rng, rounds, n, k, intensity / 2, cfg,
                                 **params)
    dup[:] = rng.random((rounds, n, k)) < intensity / 4
    corrupt[:] = rng.random((rounds, n, k)) < intensity / 4
    _, _, _, _, down = _gen_crash_restart(rng, rounds, n, k, intensity / 4,
                                          cfg)
    return drop, lag, dup, corrupt, down


FAULTS = {
    "none": _gen_none,
    "drop": _gen_drop,
    "stale": _gen_stale,
    "duplicate": _gen_duplicate,
    "corrupt": _gen_corrupt,
    "crash_restart": _gen_crash_restart,
    "chaos": _gen_chaos,
}

FAULT_NAMES = tuple(FAULTS)


def make_fault_schedule(name: str, schedule: TopologySchedule,
                        intensity: float, seed: int = 0,
                        config: Optional[FaultConfig] = None,
                        **params) -> FaultSchedule:
    """Build a named fault schedule shaped to a topology schedule.

    Deterministic in (name, shape, intensity, seed, params) — the same
    arguments always produce the identical byte-for-byte schedule, which
    is what makes kill-and-resume (and CI reproduction) exact.
    """
    if name not in FAULTS:
        raise ValueError(f"unknown fault scenario {name!r}; "
                         f"choose from {sorted(FAULTS)}")
    cfg = config or FaultConfig()
    rng = np.random.default_rng(seed)
    drop, lag, dup, corrupt, down = FAULTS[name](
        rng, schedule.rounds, schedule.n_nodes, schedule.width,
        float(intensity), cfg, **params)
    return FaultSchedule(drop=drop, lag=np.clip(lag, 0, cfg.ring_depth),
                         dup=dup, corrupt=corrupt, down=down, config=cfg)
