"""Logical-axis sharding annotations (flax-style rules, dependency-free).

Model code annotates activations with *logical* axis names:

    h = shard(h, "batch", "seq", "embed")

The launcher installs a mesh + a logical->mesh-axis rule table; outside a
`use_sharding` context the annotations are no-ops, so the same model code
runs single-device (tests, smoke) and multi-pod (dry-run, production).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[None, str, Tuple[str, ...]]


def _get_abstract_mesh():
    """Version compat: jax.sharding.get_abstract_mesh is only public in
    newer jax; the pinned 0.4.x keeps it in jax._src.mesh."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        from jax._src.mesh import get_abstract_mesh as fn
    return fn()


_state = threading.local()


def _ctx() -> Optional[Tuple[Mesh, Dict[str, MeshAxis]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Dict[str, MeshAxis]):
    """Install (mesh, logical->mesh rules) for the enclosed region."""
    prev = _ctx()
    _state.ctx = (mesh, dict(rules))
    try:
        with mesh:
            yield
    finally:
        _state.ctx = prev


def logical_spec(*axes: Optional[str]) -> P:
    ctx = _ctx()
    rules = ctx[1] if ctx else {}
    return P(*[rules.get(a) if a else None for a in axes])


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    No-op outside a `use_sharding` context.  Extra trailing dims (beyond
    the names given) are unconstrained (replicated spec position).
    """
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = [rules.get(a) if a else None for a in axes[: x.ndim]]
    spec += [None] * (x.ndim - len(spec))
    # Use the CURRENT abstract mesh so axis types (Manual inside shard_map
    # regions vs Auto outside) match the trace context — a concrete-mesh
    # NamedSharding would poison downstream avals with Auto-typed axes and
    # break AD zero-instantiation inside partial-manual shard_map.
    cur = _get_abstract_mesh()
    use = cur if (cur is not None and not cur.empty) else mesh
    return jax.lax.with_sharding_constraint(x, NamedSharding(use, P(*spec)))


def current_mesh() -> Optional[Mesh]:
    ctx = _ctx()
    return ctx[0] if ctx else None


def current_rules() -> Dict[str, MeshAxis]:
    ctx = _ctx()
    return dict(ctx[1]) if ctx else {}
