"""Byzantine-robust all-reduce: WFAgg (and baselines) as a drop-in
replacement for the data-parallel mean-gradient all-reduce (mode B).

Runs INSIDE a partial-manual shard_map region: manual over the candidate
axis/axes (the data-parallel workers = DFL nodes), GSPMD-auto over the
'model' axis (so the flat gradient vector stays tensor-parallel sharded
throughout — no device ever holds a full gradient).

Memory discipline (the production constraint the paper never hits):
K full candidate gradients can NEVER coexist (K x P bytes; 7.5 TB for a
470B model on a 4 TB pod).  So aggregation is two-phase:

  phase 1 (streamed): scan gradient chunks; all-gather each (K, chunk)
          block transiently; accumulate sufficient statistics —
          chunk median -> WFAgg-D distances / WFAgg-C cosines, the
          K x K Gram (Krum / Multi-Krum / Clustering), count-sketches
          (temporal filter).  Transient memory = K x chunk only.
  phase 2 (free):     consensus weights w (identical on every worker)
          -> each worker scales ITS OWN gradient by w[me] and a plain
          psum produces the weighted mean.  No second gather.

Median / Trimmed-Mean baselines are not weighted means of candidates, so
they stream the OUTPUT chunk directly in phase 1 (single pass).

The temporal filter (WFAgg-T) runs on AMS count-sketches of the gradients
(inner-product preserving), so its state is (K, sketch_dim) instead of
(K, P) — this is the beyond-paper change that makes the paper's temporal
statistics affordable at LLM scale.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_lib
from repro.core import attacks as atk
from repro.core import trust
from repro.core.wfagg import (
    TemporalState, WFAggConfig, wfagg_scores, wfagg_t_decide, wfagg_t_select)
from repro.kernels.pairwise_dist.ops import pairwise_gram
from repro.kernels.robust_stats.ops import robust_stats, wfagg_round_indexed
from repro.obs import decision as obs_decision

Array = jax.Array
AxisNames = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class RobustAggConfig:
    method: str = "wfagg"        # mean | median | trimmed_mean | krum | multi_krum |
                                 # clustering | wfagg | alt_wfagg
    wfagg: WFAggConfig = WFAggConfig()
    trim_beta: float = 0.1
    multi_krum_m: Optional[int] = None
    chunk_size: int = 1 << 22    # coordinates per streamed chunk
    sketch_dim: int = 4096       # AMS count-sketch width (temporal filter)
    seed: int = 0
    # layout of the candidate gradients during aggregation:
    #   flat — ravel to one vector, stream chunks (paper-shaped baseline;
    #          the ravel forces a model-axis all-gather of the FULL
    #          gradient on every worker)
    #   stacked — candidates carry an explicit leading K axis sharded
    #          over the data mesh axes and aggregation runs in pure GSPMD
    #          (no manual collectives, every leaf keeps its TP sharding;
    #          GSPMD reshards K via all-to-all).  The temporal filter
    #          becomes EXACT (each worker stores its own previous
    #          gradient, candidate-sharded) instead of
    #          count-sketch-approximate.
    layout: str = "flat"
    gather_dtype: Optional[str] = None   # e.g. "bfloat16": gather candidates
                                         # in low precision (stats stay f32)
    # statistics backend for layout='stacked': "fused" runs the whole
    # wfagg/alt_wfagg aggregation — statistics, in-kernel trust-weight
    # derivation AND the weighted combine — through ONE single-launch
    # Pallas kernel over the concatenated (K, P) candidates (falls back
    # to the two-launch shape when gather_dtype quantization is on: the
    # temporal metrics must stay full-precision while the D/C stats
    # quantize, which one read cannot provide); "fused_two_launch"
    # forces the separate stats launch + host scoring + jnp combine;
    # "reference" keeps the per-leaf jnp loop.  The fused paths assume
    # the candidates fit one process (mode-A scale / shard_map-manual
    # regions); pure-GSPMD multi-pod sharding of the kernel is an open
    # item.
    backend: str = "reference"

    @property
    def needs_stats(self) -> bool:
        return self.method in ("krum", "multi_krum", "clustering", "wfagg", "alt_wfagg")

    @property
    def streaming_output(self) -> bool:
        return self.method in ("median", "trimmed_mean")


class AggState(NamedTuple):
    """Cross-step state: WFAgg-T temporal statistics over gradient sketches."""

    temporal: TemporalState


def init_agg_state(cfg: RobustAggConfig, n_candidates: int) -> AggState:
    return AggState(
        temporal=TemporalState(
            prev=jnp.zeros((n_candidates, cfg.sketch_dim), jnp.float32),
            hist_s=jnp.zeros((cfg.wfagg.window, n_candidates), jnp.float32),
            hist_b=jnp.zeros((cfg.wfagg.window, n_candidates), jnp.float32),
            count=jnp.zeros((), jnp.int32),
            t=jnp.zeros((), jnp.int32),
        )
    )


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _axes_tuple(axis: AxisNames) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axis_size(axis: AxisNames) -> int:
    return int(jax.lax.psum(1, _axes_tuple(axis)))


def my_index(axis: AxisNames) -> Array:
    axes = _axes_tuple(axis)
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        # psum-of-1 rather than jax.lax.axis_size: the latter only exists
        # in newer jax than the pinned 0.4.x
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def _pad_chunks(flat: Array, chunk: int) -> Tuple[Array, int]:
    P = flat.shape[0]
    n_chunks = max(1, -(-P // chunk))
    pad = n_chunks * chunk - P
    return jnp.pad(flat, (0, pad)), n_chunks


def _count_sketch(chunk: Array, chunk_idx: Array, m: int, seed: int) -> Array:
    """AMS count-sketch of one chunk: bucket + sign, seeded by chunk index."""
    L = chunk.shape[0]
    key = jax.random.fold_in(jax.random.PRNGKey(seed), chunk_idx)
    kb, ks = jax.random.split(key)
    buckets = jax.random.randint(kb, (L,), 0, m)
    signs = jax.random.rademacher(ks, (L,), jnp.float32)
    return jax.ops.segment_sum(chunk.astype(jnp.float32) * signs, buckets, num_segments=m)


# ---------------------------------------------------------------------------
# phase 1: streamed statistics
# ---------------------------------------------------------------------------

class ChunkStats(NamedTuple):
    dist2_med: Array   # (K,)  sum ||g_j - med||^2
    dot_med: Array     # (K,)  sum <g_j, med>
    med2: Array        # ()    ||med||^2
    gram: Array        # (K,K) candidate Gram matrix
    sketch: Array      # (m,)  local candidate count-sketch


def _stats_scan(flat: Array, axis: AxisNames, cfg: RobustAggConfig) -> ChunkStats:
    axes = _axes_tuple(axis)
    K = axis_size(axis)
    padded, n_chunks = _pad_chunks(flat, cfg.chunk_size)
    chunks = padded.reshape(n_chunks, cfg.chunk_size)

    def body(carry, xs):
        chunk_idx, chunk = xs
        g = jax.lax.all_gather(chunk, axes, tiled=False)     # (K, L) transient
        g = g.reshape(K, -1).astype(jnp.float32)
        med = jnp.median(g, axis=0)
        diff = g - med[None, :]
        st = ChunkStats(
            dist2_med=carry.dist2_med + jnp.sum(diff * diff, axis=1),
            dot_med=carry.dot_med + g @ med,
            med2=carry.med2 + jnp.sum(med * med),
            gram=carry.gram + jnp.dot(g, g.T, preferred_element_type=jnp.float32),
            sketch=carry.sketch + _count_sketch(chunk, chunk_idx, cfg.sketch_dim, cfg.seed),
        )
        return st, None

    init = ChunkStats(
        dist2_med=jnp.zeros((K,), jnp.float32),
        dot_med=jnp.zeros((K,), jnp.float32),
        med2=jnp.zeros((), jnp.float32),
        gram=jnp.zeros((K, K), jnp.float32),
        sketch=jnp.zeros((cfg.sketch_dim,), jnp.float32),
    )
    stats, _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), chunks))
    return stats


def _streaming_coordinate_agg(flat: Array, axis: AxisNames, cfg: RobustAggConfig) -> Array:
    """Median / trimmed-mean aggregation: stream output chunks directly."""
    axes = _axes_tuple(axis)
    K = axis_size(axis)
    padded, n_chunks = _pad_chunks(flat, cfg.chunk_size)
    chunks = padded.reshape(n_chunks, cfg.chunk_size)

    def body(_, chunk):
        g = jax.lax.all_gather(chunk, axes, tiled=False).reshape(K, -1).astype(jnp.float32)
        if cfg.method == "median":
            out = jnp.median(g, axis=0)
        else:
            t = int(cfg.trim_beta * K)
            srt = jnp.sort(g, axis=0)
            out = jnp.mean(srt[t : K - t] if t > 0 else srt, axis=0)
        return None, out.astype(flat.dtype)

    _, outs = jax.lax.scan(body, None, chunks)
    return outs.reshape(-1)[: flat.shape[0]]


# ---------------------------------------------------------------------------
# phase 2: consensus weights from statistics
# ---------------------------------------------------------------------------

def _weights_from_stats(
    stats: ChunkStats,
    sketches: Optional[Array],   # (K, m) gathered candidate sketches
    state: Optional[AggState],
    cfg: RobustAggConfig,
    temporal_mask: Optional[Array] = None,   # tree layout: exact WFAgg-T mask
) -> Tuple[Array, Optional[AggState], Dict[str, Array]]:
    K = stats.dist2_med.shape[0]
    norm2 = jnp.diag(stats.gram)
    info: Dict[str, Array] = {}
    w = cfg.wfagg

    def mask_d() -> Array:
        if cfg.method == "alt_wfagg" or w.distance_filter == "multi_krum":
            scores = _krum_scores_from_gram(stats.gram, w.f)
            # WFAggConfig.multi_krum_m is the filter's own knob (what the
            # mode-A path reads in core.wfagg._distance_mask); the
            # RobustAggConfig field is the standalone-method fallback.
            m = w.multi_krum_m or cfg.multi_krum_m or max(1, K // 4)
            return agg_lib.smallest_k_mask(scores, m)
        return agg_lib.smallest_k_mask(stats.dist2_med, K - w.f - 1)

    def mask_c() -> Array:
        if cfg.method == "alt_wfagg" or w.similarity_filter == "clustering":
            return _clustering_from_gram(stats.gram)
        cos_d = 1.0 - stats.dot_med / jnp.sqrt(jnp.maximum(norm2 * stats.med2, 1e-24))
        return agg_lib.smallest_k_mask(cos_d, K - w.f - 1)

    new_state = state
    if cfg.method in ("wfagg", "alt_wfagg"):
        md, mc = mask_d(), mask_c()
        if temporal_mask is not None:
            mt = temporal_mask
        elif w.use_temporal and state is not None:
            mt, new_t = wfagg_t_select(state.temporal, sketches, w)
            new_state = AggState(temporal=new_t)
        else:
            mt = jnp.zeros((K,), bool)
        weights = wfagg_scores(md, mc, mt, w)
        info.update(mask_d=md, mask_c=mc, mask_t=mt)
        # the flight-recorder decision record (repro.obs): the same
        # packed verdict bitmask mode-A rounds emit, so a mode-B
        # all-reduce is auditable by the same report tooling
        info["record"] = obs_decision.record_from_masks(
            md, mc, mt, jnp.ones(weights.shape, bool), weights)
    elif cfg.method == "krum":
        scores = _krum_scores_from_gram(stats.gram, w.f)
        weights = jax.nn.one_hot(jnp.argmin(scores), K, dtype=jnp.float32)
    elif cfg.method == "multi_krum":
        scores = _krum_scores_from_gram(stats.gram, w.f)
        m = cfg.multi_krum_m or max(1, K // 4)
        weights = agg_lib.smallest_k_mask(scores, m).astype(jnp.float32)
    elif cfg.method == "clustering":
        weights = _clustering_from_gram(stats.gram).astype(jnp.float32)
    elif cfg.method == "mean":
        weights = jnp.ones((K,), jnp.float32)
    else:
        raise ValueError(cfg.method)

    info["weights"] = weights
    info["n_accepted"] = (weights > 0).sum()
    return weights, new_state, info


def _krum_scores_from_gram(gram: Array, f: int) -> Array:
    n = jnp.diag(gram)
    d2 = jnp.maximum(n[:, None] + n[None, :] - 2.0 * gram, 0.0)
    return agg_lib.krum_scores_from_sq_dists(d2, f)


def _clustering_from_gram(gram: Array) -> Array:
    n = jnp.sqrt(jnp.maximum(jnp.diag(gram), 1e-24))
    D0 = 1.0 - gram / (n[:, None] * n[None, :])
    return agg_lib.clustering_select_from_dist(D0)


# ---------------------------------------------------------------------------
# tree layout: per-leaf sharded aggregation (the beyond-paper fast path)
# ---------------------------------------------------------------------------

class TreeAggState(NamedTuple):
    """Cross-step state for layout='tree'.

    ``prev`` holds THIS worker's previous gradient (same pytree as the
    grads, same TP sharding — never gathered), giving the WFAgg-T filter
    exact round-over-round metrics at the cost of one gradient-sized
    buffer per worker instead of the flat layout's (K, sketch_dim)
    approximation.
    """

    prev: Any
    hist_s: Array    # (W, K)
    hist_b: Array    # (W, K)
    count: Array
    t: Array


def init_tree_agg_state(cfg: RobustAggConfig, n_candidates: int, grads_like: Any) -> TreeAggState:
    """``prev`` carries a leading candidate axis (sharded over the data
    axes in the train state, so every worker stores exactly one previous
    gradient — its own)."""
    return TreeAggState(
        prev=jax.tree.map(
            lambda l: jnp.zeros((n_candidates,) + tuple(l.shape), jnp.float32),
            grads_like),
        hist_s=jnp.zeros((cfg.wfagg.window, n_candidates), jnp.float32),
        hist_b=jnp.zeros((cfg.wfagg.window, n_candidates), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        t=jnp.zeros((), jnp.int32),
    )


def _stacked_stats(stacked: Any, cfg: RobustAggConfig) -> ChunkStats:
    """WFAgg/Krum/Clustering statistics over stacked candidates.

    ``stacked`` leaves are (K, *param_shape), candidate axis sharded over
    the data mesh axes, inner dims TP-sharded.  All reductions below are
    plain jnp ops, so GSPMD reshards the candidate axis with an
    all-to-all (wire ~= ONE gradient shard per device, vs the flat
    layout's K-fold gather) and the (K,)/(K,K) statistic partials meet in
    a tiny all-reduce.  No unsharded gradient ever exists.
    """
    leaves = jax.tree.leaves(stacked)
    K = leaves[0].shape[0]
    gd = jnp.dtype(cfg.gather_dtype) if cfg.gather_dtype else None

    dist2 = jnp.zeros((K,), jnp.float32)
    dot_med = jnp.zeros((K,), jnp.float32)
    med2 = jnp.zeros((), jnp.float32)
    gram = jnp.zeros((K, K), jnp.float32)
    for leaf in leaves:
        g = (leaf.astype(gd) if gd is not None else leaf).astype(jnp.float32)
        rest = tuple(range(1, g.ndim))
        med = jnp.median(g, axis=0)
        diff = g - med[None]
        dist2 = dist2 + jnp.sum(diff * diff, axis=rest)
        dot_med = dot_med + jnp.tensordot(g, med, axes=(rest, tuple(range(med.ndim))))
        med2 = med2 + jnp.sum(med * med)
        gram = gram + jnp.tensordot(g, g, axes=(rest, rest))
    return ChunkStats(dist2_med=dist2, dot_med=dot_med, med2=med2, gram=gram,
                      sketch=jnp.zeros((0,), jnp.float32))


def _concat_candidates(tree: Any, dtype=None) -> Array:
    """Flatten a stacked candidate pytree to one (K, P) matrix (fused path)."""
    leaves = jax.tree.leaves(tree)
    K = leaves[0].shape[0]
    parts = [
        (l.astype(dtype) if dtype is not None else l).astype(jnp.float32).reshape(K, -1)
        for l in leaves
    ]
    return jnp.concatenate(parts, axis=1)


def _split_like(flat: Array, stacked: Any) -> Any:
    """Inverse of ``_concat_candidates`` for one aggregated (P,) vector:
    split it back into the stacked pytree's per-candidate leaf shapes
    (each leaf drops its leading K axis) and dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    out, off = [], 0
    for leaf in leaves:
        shape = leaf.shape[1:]
        n = math.prod(shape)
        out.append(flat[off:off + n].reshape(shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def _effective_wfagg_config(cfg: RobustAggConfig, K: int) -> WFAggConfig:
    """Resolve the WFAggConfig the trust-derivation stage should see:
    alt_wfagg swaps in the Multi-Krum/Clustering filters, and the
    Multi-Krum m follows ``_weights_from_stats``'s preference order
    (WFAggConfig.multi_krum_m, then RobustAggConfig's, then K // 4)."""
    w = cfg.wfagg
    if cfg.method == "alt_wfagg":
        w = dataclasses.replace(w, distance_filter="multi_krum",
                                similarity_filter="clustering")
    if w.distance_filter == "multi_krum":
        m = w.multi_krum_m or cfg.multi_krum_m or max(1, K // 4)
        w = dataclasses.replace(w, multi_krum_m=m)
    return w


def _stacked_stats_fused(
    stacked: Any, cfg: RobustAggConfig, prev: Optional[Any] = None,
):
    """One-pass statistics for the stacked layout via the robust_stats
    Pallas kernel: a single read of the concatenated (K, P) candidates
    yields the WFAgg-D/C metrics AND (with ``prev``) the exact WFAgg-T
    round-over-round metrics; the (K, K) Gram comes from the blocked
    pairwise kernel only when a Krum/Clustering-family rule needs it.

    Returns (ChunkStats, RobustStats) — the latter carries the temporal
    tail the caller feeds to wfagg_t_decide.
    """
    gd = jnp.dtype(cfg.gather_dtype) if cfg.gather_dtype else None
    flat = _concat_candidates(stacked, gd)
    pflat = _concat_candidates(prev) if prev is not None else None
    stats = robust_stats(flat, prev=pflat, need_center=False)
    w = cfg.wfagg
    needs_gram = (
        cfg.method in ("krum", "multi_krum", "clustering", "alt_wfagg")
        or w.distance_filter == "multi_krum"
        or w.similarity_filter == "clustering"
    )
    if needs_gram:
        gram, _ = pairwise_gram(flat)
    else:
        # _weights_from_stats only reads the diagonal (norm2) in this case
        gram = jnp.diag(stats.norm2)
    chunk = ChunkStats(
        dist2_med=stats.dist2,
        dot_med=stats.dotmed,
        med2=stats.mednorm2,
        gram=gram,
        sketch=jnp.zeros((0,), jnp.float32),
    )
    return chunk, stats


def _stacked_temporal_metrics(stacked: Any, prev: Any) -> Tuple[Array, Array]:
    """Exact per-candidate round-over-round metrics (vectorized over K)."""
    leaves = jax.tree.leaves(stacked)
    K = leaves[0].shape[0]
    s = jnp.zeros((K,), jnp.float32)
    dot = jnp.zeros((K,), jnp.float32)
    n_new = jnp.zeros((K,), jnp.float32)
    n_prev = jnp.zeros((K,), jnp.float32)
    for g, p in zip(leaves, jax.tree.leaves(prev)):
        gf, pf = g.astype(jnp.float32), p.astype(jnp.float32)
        rest = tuple(range(1, gf.ndim))
        s = s + jnp.sum((gf - pf) ** 2, axis=rest)
        dot = dot + jnp.sum(gf * pf, axis=rest)
        n_new = n_new + jnp.sum(gf * gf, axis=rest)
        n_prev = n_prev + jnp.sum(pf * pf, axis=rest)
    b = 1.0 - dot / jnp.maximum(jnp.sqrt(n_new * n_prev), 1e-24)
    return s, b


def apply_stacked_attack(
    stacked: Any,
    malicious: Array,          # (K,) bool
    attack: str,
    key: Array,
    noise_mu: float = 0.1,
    noise_sigma: float = 0.1,
    alie_zmax: float = 0.5,
    prev: Any = None,
) -> Any:
    """Vectorized model-poisoning attacks on stacked candidates (pure
    GSPMD — demo/integration use).  Thin per-leaf wrapper over
    ``core.attacks.apply_matrix_attack`` — the one implementation of the
    masked-stack attack math, shared with ``dfl.engine``.

    ``prev`` optionally carries the previous-round stacked candidates
    (e.g. ``TreeAggState.prev``) so the adaptive attacks see a per-leaf
    ``DefenseView`` in mode-B too; the all-to-all stacked layout has no
    neighbor table or per-victim temporal bands, so the view is
    prev-only and band_rider degrades to its mimicry fallback — the
    correct mode-B threat model (the filter state lives per-device)."""
    if attack in ("none", "label_flip"):
        return stacked
    acfg = atk.AttackConfig(name=attack, noise_mu=noise_mu,
                            noise_sigma=noise_sigma, alie_zmax=alie_zmax)
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    prev_leaves = (jax.tree_util.tree_leaves(prev) if prev is not None
                   else [None] * len(leaves))
    out = [
        atk.apply_matrix_attack(
            attack, leaf, malicious, jax.random.fold_in(key, i), acfg,
            view=(atk.DefenseView(prev=pl) if pl is not None else None))
        for i, (leaf, pl) in enumerate(zip(leaves, prev_leaves))
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def robust_allreduce_stacked(
    stacked: Any,
    cfg: RobustAggConfig,
    state: Optional[TreeAggState] = None,
) -> Tuple[Any, Optional[TreeAggState], Dict[str, Array]]:
    """Sharded robust aggregation over stacked candidate gradients.

    Pure-GSPMD fast path (layout='stacked'): no shard_map, no manual
    collectives.  Input leaves are (K, *param_shape) with the candidate
    axis sharded over the data mesh axes; the output drops the candidate
    axis.  Same consensus semantics as ``robust_allreduce``; the WFAgg-T
    filter uses exact metrics against ``state.prev`` (each worker's
    previous gradient, still candidate-sharded — one gradient per
    device).
    """
    leaves = jax.tree.leaves(stacked)
    K = leaves[0].shape[0]

    if cfg.method == "mean":
        out = jax.tree.map(lambda l: jnp.mean(l, axis=0), stacked)
        return out, state, {"weights": jnp.ones((K,), jnp.float32),
                            "n_accepted": jnp.asarray(K)}

    if cfg.streaming_output:
        def one(leaf):
            g = leaf.astype(jnp.float32)
            if cfg.method == "median":
                o = jnp.median(g, axis=0)
            else:
                t = int(cfg.trim_beta * K)
                srt = jnp.sort(g, axis=0)
                o = jnp.mean(srt[t: K - t] if t > 0 else srt, axis=0)
            return o.astype(leaf.dtype)
        out = jax.tree.map(one, stacked)
        return out, state, {"weights": jnp.ones((K,), jnp.float32),
                            "n_accepted": jnp.asarray(K)}

    fused = cfg.backend in ("fused", "fused_two_launch")
    temporal = (cfg.method in ("wfagg", "alt_wfagg") and cfg.wfagg.use_temporal
                and state is not None)
    # Single-launch route (backend="fused"): the whole wfagg/alt_wfagg
    # aggregation — statistics, in-kernel weight derivation, weighted
    # combine — in ONE round-kernel launch over the concatenated (K, P)
    # candidates.  gather_dtype forces the two-launch shape instead: the
    # temporal metrics must stay full-precision while the D/C statistics
    # quantize, which a single candidate read cannot provide.
    if (cfg.backend == "fused" and cfg.method in ("wfagg", "alt_wfagg")
            and cfg.gather_dtype is None):
        return _stacked_one_launch(stacked, cfg, state, temporal)
    # The temporal metrics are computed on FULL-precision candidates in
    # the reference path (gather_dtype only quantizes the D/C/Gram
    # statistics), so the fused kernel may only fold them into its pass
    # when no gather_dtype rounding is in effect — otherwise the masks
    # would diverge between backends.
    fuse_temporal = fused and temporal and cfg.gather_dtype is None
    if fused:
        stats, kstats = _stacked_stats_fused(
            stacked, cfg, prev=state.prev if fuse_temporal else None)
    else:
        stats = _stacked_stats(stacked, cfg)

    new_state = state
    temporal_mask = None
    if temporal:
        if fuse_temporal:
            s_all, b_all = kstats.prev_dist2, kstats.cosine_to_prev()
        else:
            s_all, b_all = _stacked_temporal_metrics(stacked, state.prev)
        temporal_mask, hist_s, hist_b, count, t = wfagg_t_decide(
            state.hist_s, state.hist_b, state.count, state.t,
            s_all, b_all, cfg.wfagg)
        new_state = TreeAggState(
            prev=jax.tree.map(lambda g: g.astype(jnp.float32), stacked),
            hist_s=hist_s, hist_b=hist_b, count=count, t=t)
    weights, _, info = _weights_from_stats(stats, None, None, cfg,
                                           temporal_mask=temporal_mask)

    wsum = jnp.maximum(weights.sum(), 1e-12)
    any_ok = weights.sum() > 0
    w_norm = jnp.where(any_ok, weights / wsum, jnp.full((K,), 1.0 / K))
    out = jax.tree.map(
        lambda l: jnp.tensordot(w_norm, l.astype(jnp.float32),
                                axes=(0, 0)).astype(l.dtype),
        stacked)
    return out, new_state, info


def _stacked_one_launch(
    stacked: Any,
    cfg: RobustAggConfig,
    state: Optional[TreeAggState],
    temporal: bool,
) -> Tuple[Any, Optional[TreeAggState], Dict[str, Array]]:
    """Single-launch stacked wfagg/alt_wfagg: one round-kernel call on
    the concatenated (K, P) candidates does statistics + in-kernel trust
    weights + the weighted combine (the N=1, all-valid, identity-table
    instance of the DFL round kernel).

    ``alpha=1.0`` + ``mean_fallback=True`` turn the kernel's WFAgg-E
    combine into the all-reduce convention: the output is the
    trust-weight-normalized mean of the candidates, degrading to the
    uniform mean when every candidate is rejected (same fallback as the
    reference path — a gradient all-reduce has no "local model" anchor).
    """
    leaves = jax.tree.leaves(stacked)
    K = leaves[0].shape[0]
    w = _effective_wfagg_config(cfg, K)
    flat = _concat_candidates(stacked)               # (K, P) f32
    nidx = jnp.arange(K, dtype=jnp.int32)[None, :]   # identity slate
    prev = tbands = None
    if temporal:
        prev = _concat_candidates(state.prev)        # (K, P) matrix form
        tbands = trust.temporal_bands(state.hist_s, state.hist_b,
                                      state.count, state.t, w)[None]
    local = jnp.zeros_like(flat[:1])                 # unused: lcoef = 0
    out_flat, weights, mask_d, mask_c, mask_t, kstats = wfagg_round_indexed(
        local, flat, nidx, None, w, prev=prev, tbands=tbands,
        alpha=1.0, mean_fallback=True)
    new_state = state
    if temporal:
        hist_s, hist_b, count, t = trust.push_history(
            state.hist_s, state.hist_b, state.count, state.t,
            kstats.prev_dist2[0], kstats.cosine_to_prev()[0])
        new_state = TreeAggState(
            prev=jax.tree.map(lambda g: g.astype(jnp.float32), stacked),
            hist_s=hist_s, hist_b=hist_b, count=count, t=t)
    out = _split_like(out_flat[0], stacked)
    info = {
        "mask_d": mask_d[0], "mask_c": mask_c[0], "mask_t": mask_t[0],
        "weights": weights[0], "n_accepted": (weights[0] > 0).sum(),
        "record": obs_decision.record_from_masks(
            mask_d[0], mask_c[0], mask_t[0],
            jnp.ones(weights[0].shape, bool), weights[0]),
    }
    return out, new_state, info


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def robust_allreduce(
    flat: Array,
    axis: AxisNames,
    cfg: RobustAggConfig,
    state: Optional[AggState] = None,
) -> Tuple[Array, Optional[AggState], Dict[str, Array]]:
    """Robust-aggregate local flat gradient across the candidate axis.

    Returns (aggregated flat gradient — identical on every worker,
    new_state, info).  Must be called inside shard_map manual over
    ``axis``.
    """
    axes = _axes_tuple(axis)
    K = axis_size(axis)

    if cfg.method == "mean":
        out = jax.lax.psum(flat, axes) / K
        return out, state, {"weights": jnp.ones((K,), jnp.float32),
                            "n_accepted": jnp.asarray(K)}

    if cfg.streaming_output:
        out = _streaming_coordinate_agg(flat, axis, cfg)
        return out, state, {"weights": jnp.ones((K,), jnp.float32),
                            "n_accepted": jnp.asarray(K)}

    stats = _stats_scan(flat, axis, cfg)
    sketches = jax.lax.all_gather(stats.sketch, axes, tiled=False).reshape(K, -1)
    weights, new_state, info = _weights_from_stats(stats, sketches, state, cfg)

    # phase 2: weighted mean without a second gather — scale own gradient.
    me = my_index(axis)
    wsum = jnp.maximum(weights.sum(), 1e-12)
    scaled = flat * (weights[me] / wsum).astype(flat.dtype)
    out = jax.lax.psum(scaled, axes)
    # all-zero weights (every candidate rejected): fall back to the mean
    fallback = jax.lax.psum(flat, axes) / K
    out = jnp.where(weights.sum() > 0, out, fallback)
    return out, new_state, info


# ---------------------------------------------------------------------------
# distributed attack injection (integration tests / robustness demos)
# ---------------------------------------------------------------------------

def apply_distributed_attack(
    flat: Array,
    axis: AxisNames,
    malicious: Array,      # (K,) bool — which workers are Byzantine
    attack: str,
    key: Array,
    noise_mu: float = 0.1,
    noise_sigma: float = 0.1,
    alie_zmax: float = 0.5,
) -> Array:
    """Transform the local gradient if this worker is malicious.

    Omniscient attacks (ALIE, IPM) use benign-cohort statistics computed
    with masked psums — no gradient gather needed.
    """
    axes = _axes_tuple(axis)
    K = axis_size(axis)
    me = my_index(axis)
    i_am_bad = malicious[me]
    n_benign = jnp.maximum(K - malicious.sum(), 1)

    if attack in ("none", "label_flip"):
        return flat
    if attack == "noise":
        noisy = flat + noise_mu + noise_sigma * jax.random.normal(key, flat.shape, flat.dtype)
        return jnp.where(i_am_bad, noisy, flat)
    if attack == "sign_flip":
        return jnp.where(i_am_bad, -flat, flat)

    benign_w = (~malicious)[me].astype(flat.dtype)
    mu = jax.lax.psum(flat * benign_w, axes) / n_benign
    if attack.startswith("ipm"):
        eps = 100.0 if attack == "ipm_100" else 0.5
        return jnp.where(i_am_bad, -eps * mu, flat)
    if attack == "alie":
        var = jax.lax.psum(benign_w * (flat - mu) ** 2, axes) / n_benign
        mal = mu - alie_zmax * jnp.sqrt(var)
        return jnp.where(i_am_bad, mal.astype(flat.dtype), flat)
    raise ValueError(f"unknown attack {attack!r}")
