"""Per-architecture PartitionSpecs (params, caches, activations).

Specs are derived from the param *name* (leaf key) + rank, applied to the
TRAILING dims (leading stacked-layer/group dims fill with None).  Two
modes:
  tp_only   params sharded over 'model' only (replicated across data) —
            required by the mode-B shard_map trainer.
  fsdp      additionally shard the largest remaining big dim over 'data'
            (+ 'pod' folded into 'data' for multi-pod) — serving / the
            GSPMD-mean trainer for >=100B params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes top-level ``jax.shard_map(..., axis_names=...,
    check_vma=...)``; the pinned 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``.
    ``axis_names`` follows the new API (the MANUAL axes; None = all).
    """
    if hasattr(jax, "shard_map"):
        kw: Dict[str, Any] = dict(mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names or mesh.axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=auto)

# trailing-dim specs keyed by leaf name (without the 'model' axis resolved)
_TRAILING: Dict[str, Tuple[Optional[str], ...]] = {
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # MLA
    "w_dkv": (None, None), "w_kr": (None, None),
    "w_uk": (None, "model"), "w_uv": (None, "model"), "kv_norm": (None,),
    # embeddings
    "embed": ("model", None), "unembed": (None, "model"),
    # router / norms / scalars
    "router": (None, None), "scale": (None,), "bias": (None,),
    "gnorm": ("model",), "dt_bias": ("model",), "D": ("model",),
    # mamba
    "in_proj": (None, "model"), "out_proj": ("model", None),
    "conv_w": (None, "model"), "conv_b": ("model",),
    "x_proj": ("model", None), "dt_proj": (None, "model"),
    "A_log": ("model", None), "bc_proj": ("model", None),
    # projector (vlm) / encoder input
    "w1": (None, "model"), "w2": ("model", None), "enc_in_proj": (None, None),
}

# dense-MLP vs MoE expert tensors share names; disambiguate by rank below.
_MLP2 = {"w_gate": (None, "model"), "w_up": (None, "model"), "w_down": ("model", None)}
_MOE3 = {"w_gate": ("model", None, None), "w_up": ("model", None, None),
         "w_down": ("model", None, None)}

_FSDP_MIN_DIM = 1024  # only shard dims at least this large over 'data'


def _axis_size(mesh, axis) -> int:
    if axis is None or mesh is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= int(mesh.shape[a])
        return n
    return int(mesh.shape[axis])


def prune_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop spec entries whose mesh-axis size does not divide the dim.

    Keeps dry-runs honest across all (arch x shape) cells: global_batch=1
    cannot shard over data=16, kv_heads=4 cannot shard over model=16 (the
    KV cache is then replicated across TP shards, the standard GQA
    fallback).
    """
    if mesh is None:
        return spec
    out = []
    for i, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(ax if shape[i] % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


def _spec_for(name: str, shape: Tuple[int, ...], n_stack: int) -> Tuple[Optional[str], ...]:
    """n_stack = how many leading dims are layer/group stacking."""
    trailing_rank = len(shape) - n_stack
    if name in ("w_gate", "w_up", "w_down"):
        tr = _MOE3[name] if trailing_rank == 3 else _MLP2[name]
    elif name in _TRAILING:
        tr = _TRAILING[name]
        tr = tr[-trailing_rank:] if trailing_rank <= len(tr) else (None,) * (trailing_rank - len(tr)) + tr
    else:
        tr = (None,) * trailing_rank
    return (None,) * n_stack + tuple(tr)


def _count_stack_dims(name: str, shape: Tuple[int, ...],
                      cfg: Optional[ArchConfig] = None) -> int:
    """Infer leading stacked dims: total rank minus the natural rank."""
    if name in ("w_gate", "w_up", "w_down"):
        # dense (2) or expert (3): a rank-4 w_gate is stacked expert (1+3);
        # rank-3 is ambiguous (stacked dense (L,d,ff) vs unstacked expert
        # (E,d,ff)) — the config disambiguates: dense archs have no expert
        # tensors, and expert tensors lead with exactly n_experts.
        if len(shape) == 4:
            return 1
        if len(shape) == 3:
            if cfg is not None and cfg.n_experts and shape[0] == cfg.n_experts:
                return 0  # unstacked expert tensor
            return 1      # stacked dense MLP
        return 0
    base = {"scale": 1, "bias": 1, "bq": 1, "bk": 1, "bv": 1, "gnorm": 1,
            "dt_bias": 1, "D": 1, "conv_b": 1, "kv_norm": 1}.get(name, 2)
    return max(0, len(shape) - base)


def param_specs(cfg: ArchConfig, params_shape: Any, fsdp: bool = False,
                data_axes: Tuple[str, ...] = ("data",), mesh=None) -> Any:
    """Build a PartitionSpec pytree mirroring params."""
    data_axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        n_stack = _count_stack_dims(name, shape, cfg)
        spec = list(_spec_for(name, shape, n_stack))
        if fsdp:
            # put 'data' on the largest unsharded trailing dim
            best, best_size = -1, _FSDP_MIN_DIM - 1
            for i in range(n_stack, len(shape)):
                if spec[i] is None and shape[i] > best_size:
                    best, best_size = i, shape[i]
            if best >= 0:
                spec[best] = data_axis
        return prune_spec(P(*spec), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cfg: ArchConfig, cache_shape: Any, data_axes: Tuple[str, ...] = ("data",), mesh=None) -> Any:
    """Decode-cache specs: batch over data, heads/inner over model."""
    data_axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if name == "idx" or leaf.ndim == 0:
            return P()
        if name in ("k", "v"):        # (..., B, Hkv, cap, hd)
            lead = (None,) * (len(shape) - 4)
            mdl = "model" if cfg.n_kv_heads > 1 else None
            return P(*lead, data_axis, mdl, None, None)
        if name in ("ckv", "krope"):  # (..., B, cap, r)
            lead = (None,) * (len(shape) - 3)
            return P(*lead, data_axis, None, None)
        if name == "conv":            # (..., B, kw-1, di)
            lead = (None,) * (len(shape) - 3)
            return P(*lead, data_axis, None, "model")
        if name == "h":
            if cfg.ssm_variant == "mamba2":   # (..., B, Hm, p, n)
                lead = (None,) * (len(shape) - 4)
                return P(*lead, data_axis, "model", None, None)
            lead = (None,) * (len(shape) - 3)  # (..., B, di, n)
            return P(*lead, data_axis, "model", None)
        if name == "enc_out":         # (B, S_enc, d)
            return P(data_axis, None, None)
        return P(*(None,) * len(shape))

    def pruned(path, leaf):
        return prune_spec(one(path, leaf), tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(pruned, cache_shape)


def batch_specs(batch_shape: Any, data_axes: Tuple[str, ...] = ("data",), mesh=None) -> Any:
    data_axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        return prune_spec(P(data_axis, *(None,) * (leaf.ndim - 1)),
                          tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def activation_rules(mode: str, multi_pod: bool) -> Dict[str, Any]:
    """Logical-axis rules for repro.distributed.logical.use_sharding."""
    batch_axes = ("pod", "data") if multi_pod else "data"
    rules = {
        "heads": "model", "kv_heads": "model", "ff": "model",
        "vocab": "model", "expert": "model", "inner": "model",
        "embed": None, "seq": None,
    }
    if mode == "robust_dp":
        rules["batch"] = None          # batch axis is manual-local per node
    else:
        rules["batch"] = batch_axes
    return rules
