"""D-sharded WFAgg gossip rounds under ``shard_map`` (the communication
contract the SPMD linter rules enforce).

The one-launch round kernel derives its trust weights at an in-kernel
phase boundary from GLOBAL filter statistics, so it cannot survive
model-dim sharding as a single launch: a shard only sees its d/S
coordinate slice.  What DOES survive — exactly — is the two-launch
decomposition, because every statistic the scoring stage consumes is a
coordinate-additive accumulator (``RobustStats``: dist2 / dotmed / norm2
/ mednorm2 / prev_* / gram are all sums over coordinates, and the
coordinate-wise median is computed per coordinate, i.e. shard-locally):

  phase 0 (shard-local)  ``robust_stats_indexed`` on the (M, d/S) model
                         shard — one Pallas launch per shard, no comm;
  psum                   ONE all-reduce of the O(N·K) statistic partials
                         across the 'model' axis reconstructs the full-d
                         statistics bit-for-bit up to float summation
                         order — this is the ONLY cross-shard collective
                         the contract allows;
  scoring (replicated)   ``core.wfagg._indexed_scoring`` — the same
                         host-side trust stage the two-launch backend
                         uses, now computed redundantly on every shard
                         (O(N·K) work, no comm);
  phase 1 (shard-local)  ``weighted_agg_indexed`` combines each node's
                         d/S slice with its neighbors' — the WFAgg-E
                         combine never crosses shards.

Per-device wire traffic per round is therefore O(N·K) — independent of
d — versus the O(N·d) a naive GSPMD gather would pay.  Zero-padding d
to a multiple of the shard count is exact for every statistic (a zero
column has median 0 and contributes nothing to any accumulator; see
``kernels.common.pad_d``).

Everything here stays (N, d)-sharded end to end: inputs, the scan
carry, and outputs keep ``P(None, 'model')``, so GSPMD never gets a
replicated consumer to hang a full-d all-gather on.  The analysis entry
points (``repro.analysis.entry_points``) lint the compiled HLO of these
functions against :class:`repro.analysis.collectives.CommContract`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import wfagg as wf
from repro.core.trust import needs_gram
from repro.kernels.robust_stats.ops import robust_stats_indexed
from repro.kernels.robust_stats.ref import RobustStats
from repro.kernels.weighted_agg.ops import weighted_agg_indexed

Array = jax.Array

# mesh axis the model dimension shards over (launch/mesh.py convention)
SHARD_AXIS = "model"


def aggregation_mesh(n_shards: int) -> Mesh:
    """(1, n_shards) ('data', 'model') mesh over the first devices."""
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(data=1, model=n_shards)


def shard_padded_d(d: int, n_shards: int) -> int:
    """d zero-padded up to a multiple of the shard count (exact: zero
    columns contribute nothing to any WFAgg statistic or combine)."""
    return d + (-d) % max(1, n_shards)


def pad_to_shards(x: Array, n_shards: int) -> Array:
    """Zero-pad the trailing (d) axis to a shard multiple, promote f32."""
    pad = (-x.shape[-1]) % max(1, n_shards)
    return jnp.pad(x.astype(jnp.float32),
                   [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def psum_stats(stats: RobustStats, axis: str = SHARD_AXIS) -> RobustStats:
    """Reconstruct full-d ``RobustStats`` from per-shard partials.

    Every populated field is a sum over coordinates of shard-local
    quantities, so one psum across the model axis is exact (up to float
    summation order).  ``med``/``trim`` are d-sized centers the indexed
    filter bank never emits — they must be None here (a d-sized center
    cannot cross shards without violating the contract)."""
    if stats.med is not None or stats.trim is not None:
        raise ValueError(
            "psum_stats only reconstructs the O(N*K) accumulator fields; "
            "d-sized centers (med/trim) must stay shard-local")

    def ps(x):
        return None if x is None else jax.lax.psum(x, axis)

    return RobustStats(
        med=None, trim=None,
        dist2=ps(stats.dist2), dotmed=ps(stats.dotmed),
        norm2=ps(stats.norm2), mednorm2=ps(stats.mednorm2),
        prev_dist2=ps(stats.prev_dist2), prev_dot=ps(stats.prev_dot),
        prev_norm2=ps(stats.prev_norm2), gram=ps(stats.gram))


def _state_specs(state: Optional[wf.TemporalState]):
    """PartitionSpecs for a matrix-prev TemporalState: ``prev`` (N, d)
    shards over the model axis, the O(K) ring buffers replicate."""
    if state is None:
        return None
    return wf.TemporalState(prev=P(None, SHARD_AXIS), hist_s=P(), hist_b=P(),
                            count=P(), t=P())


def _check_state(state: Optional[wf.TemporalState]) -> None:
    if state is not None and state.prev.ndim != 2:
        raise NotImplementedError(
            "the sharded round shards the (N, d) matrix-form temporal "
            "state; per-edge (N, K, d) prev would re-materialize the "
            "gossip tensor it exists to avoid")


def _shard_round_body(cfg: wf.WFAggConfig, axis: str):
    """Per-shard round body: local stats -> psum -> replicated scoring ->
    local combine.  Runs under shard_map; ``local``/``models``/``prev``
    are (., d/S) shards, everything else is replicated."""

    def body(local, models, state, neighbor_idx, valid_b):
        temporal = cfg.use_temporal and state is not None
        stats = robust_stats_indexed(
            models, neighbor_idx, valid_b,
            prev=state.prev if temporal else None,
            need_gram=needs_gram(cfg))
        stats = psum_stats(stats, axis)
        mask_d, mask_c, mask_t, weights, new_state = wf._indexed_scoring(
            stats, valid_b, state, cfg, models, neighbor_idx)
        out = weighted_agg_indexed(local, models, neighbor_idx, weights,
                                   alpha=cfg.alpha)
        return out, new_state, (mask_d, mask_c, mask_t, weights)

    return body


def _round_specs(state):
    in_specs = (P(None, SHARD_AXIS), P(None, SHARD_AXIS),
                _state_specs(state), P(None, None), P(None, None))
    out_specs = (P(None, SHARD_AXIS), _state_specs(state),
                 (P(), P(), P(), P()))
    return in_specs, out_specs


def wfagg_batch_sharded(
    local: Array,
    models: Array,
    state: Optional[wf.TemporalState],
    cfg: wf.WFAggConfig,
    neighbor_idx: Array,
    valid: Optional[Array] = None,
    *,
    mesh: Mesh,
) -> Tuple[Array, Optional[wf.TemporalState], Dict[str, Array]]:
    """Drop-in for ``wfagg_batch(..., neighbor_idx=...)`` with the model
    dimension sharded over ``mesh``'s 'model' axis.

    Semantics match ``backend='fused_two_launch'`` (same scoring stage on
    the psum-reconstructed statistics, same combine) up to float
    summation order.  d is zero-padded to a shard multiple internally
    and the pad sliced back off, so callers with replicated inputs (the
    DFL engine) can use any d; the lint entry points pre-pad and keep
    everything sharded instead."""
    from repro.distributed.sharding import shard_map_compat

    _check_state(state)
    S = int(mesh.shape[SHARD_AXIS])
    N, K = neighbor_idx.shape
    d = models.shape[-1]
    valid_b = (jnp.ones((N, K), dtype=bool) if valid is None
               else valid.astype(bool))

    loc = pad_to_shards(local, S)
    mod = pad_to_shards(models, S)
    st = (state._replace(prev=pad_to_shards(state.prev, S))
          if state is not None else None)

    in_specs, out_specs = _round_specs(st)
    fn = shard_map_compat(_shard_round_body(cfg, SHARD_AXIS), mesh=mesh,
                          in_specs=in_specs, out_specs=out_specs)
    out, new_state, (mask_d, mask_c, mask_t, weights) = fn(
        loc, mod, st, neighbor_idx, valid_b)
    if out.shape[-1] != d:
        out = out[..., :d]
        if new_state is not None:
            new_state = new_state._replace(prev=new_state.prev[..., :d])
    info = {
        "mask_d": mask_d, "mask_c": mask_c, "mask_t": mask_t,
        "valid": valid_b, "weights": weights,
        "n_accepted": (weights > 0).sum(axis=-1),
    }
    return out, new_state, info


def wfagg_scan_sharded(
    models: Array,
    state: Optional[wf.TemporalState],
    cfg: wf.WFAggConfig,
    sched_idx: Array,        # (R, N, K)
    sched_valid: Array,      # (R, N, K)
    *,
    mesh: Mesh,
) -> Tuple[Array, Optional[wf.TemporalState]]:
    """A whole dynamic schedule of sharded gossip rounds in one
    ``lax.scan`` INSIDE the shard_map region: the (N, d/S) model shard is
    the scan carry, so the model matrix never crosses the shard_map
    boundary between rounds and GSPMD has no replicated consumer to
    all-gather for.  Per round: shard-local stats, the one O(N·K) psum,
    replicated scoring (with the slot-history realignment of
    ``realign_temporal_history`` when temporal state is carried), and
    the shard-local combine.  d must already be a shard multiple
    (``pad_to_shards``)."""
    from repro.distributed.sharding import shard_map_compat

    _check_state(state)
    S = int(mesh.shape[SHARD_AXIS])
    if models.shape[-1] % S:
        raise ValueError(
            f"d={models.shape[-1]} must be a multiple of the shard count "
            f"{S} — pre-pad with pad_to_shards()")
    round_body = _shard_round_body(cfg, SHARD_AXIS)
    temporal = cfg.use_temporal and state is not None

    def scan_body(m, st, sched_idx, sched_valid):
        def one_round(carry, xs):
            models_l, state_l, prev_idx, prev_val = carry
            idx, val = xs
            if temporal:
                state_l = wf.realign_temporal_history(
                    state_l, prev_idx, prev_val, idx, val)
            out, new_state, _ = round_body(models_l, models_l, state_l,
                                           idx, val)
            return (out, new_state, idx, val), None

        init = (m, st, sched_idx[0], jnp.ones_like(sched_valid[0]))
        (m, st, _, _), _ = jax.lax.scan(init=init, xs=(sched_idx, sched_valid),
                                        f=one_round)
        return m, st

    in_specs = (P(None, SHARD_AXIS), _state_specs(state),
                P(None, None, None), P(None, None, None))
    out_specs = (P(None, SHARD_AXIS), _state_specs(state))
    fn = shard_map_compat(scan_body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return fn(models, state, sched_idx,
              sched_valid.astype(bool))


def batched_matrix_state(n: int, k: int, d: int,
                         window: int) -> wf.TemporalState:
    """Batched matrix-prev temporal state (the engine's layout): the
    (N, d) previous model MATRIX instead of an (N, K, d) per-edge
    tensor, slot-keyed (N, W, K) ring buffers."""
    return wf.TemporalState(
        prev=jnp.zeros((n, d), jnp.float32),
        hist_s=jnp.zeros((n, window, k), jnp.float32),
        hist_b=jnp.zeros((n, window, k), jnp.float32),
        count=jnp.zeros((n,), jnp.int32),
        t=jnp.zeros((n,), jnp.int32),
    )


def sharded_round_jit(cfg: wf.WFAggConfig, mesh: Mesh, n: int, k: int,
                      d: int, temporal: bool = True,
                      replicate_out: bool = False):
    """(jitted fn, example args) for ONE sharded gossip round with the
    (N, d) state pinned to ``P(None, 'model')`` at the jit boundary —
    the artifact the SPMD lint entry compiles.  d must be a shard
    multiple.

    ``replicate_out=True`` is the doctored twin for the linter's fire
    tests: demanding a REPLICATED output hands GSPMD a replicated
    consumer for the sharded model matrix, so it inserts exactly the
    full-d all-gather the spmd-* rules exist to catch."""
    S = int(mesh.shape[SHARD_AXIS])
    if d % S:
        raise ValueError(f"d={d} not a multiple of the shard count {S}")
    sharded = NamedSharding(mesh, P(None, SHARD_AXIS))
    repl = NamedSharding(mesh, P())

    state = batched_matrix_state(n, k, d, cfg.window) if temporal else None

    def run(models, state, neighbor_idx, valid):
        out, new_state, info = wfagg_batch_sharded(
            models, models, state, cfg, neighbor_idx, valid, mesh=mesh)
        return out, new_state, info["weights"]

    state_sh = (wf.TemporalState(prev=sharded, hist_s=repl, hist_b=repl,
                                 count=repl, t=repl)
                if state is not None else None)
    out_sh = repl if replicate_out else sharded
    fn = jax.jit(run, in_shardings=(sharded, state_sh, repl, repl),
                 out_shardings=(out_sh, state_sh, repl))
    models = jnp.zeros((n, d), jnp.float32)
    idx = jnp.zeros((n, k), jnp.int32)
    valid = jnp.ones((n, k), jnp.bool_)
    return fn, (models, state, idx, valid)


def sharded_scan_jit(cfg: wf.WFAggConfig, mesh: Mesh, n: int, k: int,
                     d: int, rounds: int, temporal: bool = True):
    """(jitted fn, example args) for the sharded dynamic-schedule scan."""
    S = int(mesh.shape[SHARD_AXIS])
    if d % S:
        raise ValueError(f"d={d} not a multiple of the shard count {S}")
    sharded = NamedSharding(mesh, P(None, SHARD_AXIS))
    repl = NamedSharding(mesh, P())

    state = batched_matrix_state(n, k, d, cfg.window) if temporal else None

    def run(models, state, sched_idx, sched_valid):
        return wfagg_scan_sharded(models, state, cfg, sched_idx,
                                  sched_valid, mesh=mesh)

    state_sh = (wf.TemporalState(prev=sharded, hist_s=repl, hist_b=repl,
                                 count=repl, t=repl)
                if state is not None else None)
    fn = jax.jit(run, in_shardings=(sharded, state_sh, repl, repl),
                 out_shardings=(sharded, state_sh))
    models = jnp.zeros((n, d), jnp.float32)
    sched_idx = jnp.zeros((rounds, n, k), jnp.int32)
    sched_valid = jnp.ones((rounds, n, k), jnp.bool_)
    return fn, (models, state, sched_idx, sched_valid)
