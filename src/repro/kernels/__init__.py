"""Pallas TPU kernels for the WFAgg aggregation hot-spots.

The paper's complexity analysis (Sections IV-C/D) identifies the
coordinate-wise median over K candidates as the dominant O(dK log K)
cost of every filter.  At production scale (d = 1e9..1e11) the candidate
tensor must stream HBM->VMEM exactly once, so we fuse the order
statistics with every other per-candidate statistic the filters need:

  robust_stats   fused median + trimmed-mean + WFAgg-D/C statistics
  pairwise_dist  blocked K x K sq-distance Gram (Krum / Multi-Krum)
  weighted_agg   fused WFAgg-E trust-weighted combine (Eq. 3)

Each package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper) and ref.py (pure-jnp oracle); validated with interpret=True.
"""
from repro.kernels.common import default_interpret, resolve_interpret
from repro.kernels.robust_stats.ops import (
    robust_stats, robust_stats_batch, wfagg_round_indexed)
from repro.kernels.robust_stats.ref import RobustStats, robust_stats_ref
from repro.kernels.pairwise_dist.ops import pairwise_gram
from repro.kernels.pairwise_dist.ops import pairwise_sq_dists as pairwise_sq_dists_kernel
from repro.kernels.pairwise_dist.ref import pairwise_dist_ref
from repro.kernels.weighted_agg.ops import weighted_agg
from repro.kernels.weighted_agg.ref import weighted_agg_ref
