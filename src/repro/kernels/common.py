"""Shared kernel-launch policy.

Every Pallas wrapper used to hardcode ``interpret=True`` (correct on CPU,
but it silently ran the interpreter on real TPUs too).  The single policy
lives here: compile for real when the default backend is a TPU, interpret
everywhere else, and let callers still force either mode explicitly.

The D-axis padding and block-size policy is also single-sourced here:
every ops wrapper used to carry its own ``_pad_d`` / ``(-D) % block_d``
copy, which is exactly the kind of plumbing that drifts apart.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode (non-TPU)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` argument: None means 'pick per backend'."""
    return default_interpret() if interpret is None else bool(interpret)


def auto_block_d(D: int, interpret: bool, interpret_blocks: int = 2) -> int:
    """Pick a D block size: ~``interpret_blocks`` large blocks in interpret
    mode (the interpreter carries whole output buffers through its grid
    scan, so many small steps thrash — kernels whose grid revisits a
    d-sized output on EVERY step, like the single-launch round kernel,
    pass ``interpret_blocks=1``), 1024-lane tiles for compiled TPU."""
    if not interpret:
        return 1024
    part = -(-D // max(1, interpret_blocks))
    return max(128, -(-part // 128) * 128)


def pad_d(x: jax.Array, block_d: int) -> jax.Array:
    """Zero-pad the trailing (D) axis up to a multiple of ``block_d`` and
    promote to f32.  Zero padding is exact for every kernel in this
    package: a zero column has median 0 and contributes nothing to any
    accumulated statistic, distance, dot product, or weighted combine."""
    pad = (-x.shape[-1]) % block_d
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x.astype(jnp.float32), cfgpad)


def resolve_block_d(D: int, block_d: Optional[int],
                    interpret: Optional[bool],
                    interpret_blocks: int = 2) -> tuple[int, bool]:
    """Resolve the (block_d, interpret) pair most wrappers need: None
    means 'pick per backend' for both."""
    itp = resolve_interpret(interpret)
    if block_d is None:
        block_d = auto_block_d(D, itp, interpret_blocks)
    return block_d, itp
