"""Shared kernel-launch policy.

Every Pallas wrapper used to hardcode ``interpret=True`` (correct on CPU,
but it silently ran the interpreter on real TPUs too).  The single policy
lives here: compile for real when the default backend is a TPU, interpret
everywhere else, and let callers still force either mode explicitly.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode (non-TPU)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` argument: None means 'pick per backend'."""
    return default_interpret() if interpret is None else bool(interpret)


def auto_block_d(D: int, interpret: bool) -> int:
    """Pick a D block size: ~2 large blocks in interpret mode (the
    interpreter carries whole output buffers through its grid scan, so
    many small steps thrash), 1024-lane tiles for compiled TPU."""
    if not interpret:
        return 1024
    half = -(-D // 2)
    return max(128, -(-half // 128) * 128)
