"""Pallas TPU kernel: flash attention (online softmax over KV blocks).

Tiling: grid (BH, Sq/bq, Sk/bk) with the KV-block axis INNERMOST — on TPU
the last grid dimension executes sequentially per core, so the (bq, hd)
output block plus the (1, bq) running max / denominator are *revisited*
accumulators in VMEM: initialized at ik == 0, rescaled by the online-
softmax correction every step, and divided by the denominator at
ik == nk-1.  The (Sq, Sk) score matrix exists only as one (bq, bk) VMEM
tile at a time; HBM traffic is one read of Q/K/V plus one write of O —
the whole point versus the XLA path, whose fusion boundary materializes
every score chunk (EXPERIMENTS.md §Perf, chunked-attention entry).

bq/bk default to 128/128 (MXU-aligned); hd rides along unblocked.
Causal masking is computed from program ids (no mask tensor exists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref,       # (1,bq,hd), (1,bk,hd), (1,bk,hd)
                  o_ref, m_ref, l_ref,       # (1,bq,hd), (1,bq), (1,bq)
                  *, scale: float, causal: bool, sk_valid: int, off: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0].astype(jnp.float32)           # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < sk_valid                    # strip Sk padding
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + off
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]                          # (bq,)
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)

    acc = o_ref[0].astype(jnp.float32) * corr[:, None]
    acc = acc + jnp.dot(p, v_ref[0].astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    m_ref[...] = m_new[None]
    l_ref[...] = l_new[None]

    nk = pl.num_programs(2)

    @pl.when(ik < nk - 1)
    def _store():
        o_ref[...] = acc[None].astype(o_ref.dtype)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[...] = (acc / jnp.maximum(l_new, 1e-30)[:, None])[None].astype(o_ref.dtype)


def flash_attention_pallas(
    q: Array, k: Array, v: Array,
    *, scale: float, causal: bool = True, sk_valid: int | None = None,
    q_offset: int = 0, block_q: int = 128, block_k: int = 128,
    interpret: bool | None = None,
):
    """q (BH, Sq, hd), k/v (BH, Sk, hd); Sq % block_q == Sk % block_k == 0.

    ``sk_valid`` masks KV padding; ``q_offset`` shifts query positions for
    decode-style alignment (qpos = q_offset + row).
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grid = (BH, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        sk_valid=Sk if sk_valid is None else sk_valid, off=q_offset)
    out_shapes = (
        jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
    )
    in_specs = [
        pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
    ]
    out_specs = (
        pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=resolve_interpret(interpret),
    )(q, k, v)
