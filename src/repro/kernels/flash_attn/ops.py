"""Jitted public wrapper for the flash-attention kernel.

Pads Sq/Sk to block multiples (padding is masked inside the kernel via
``sk_valid`` / the causal test) and reshapes (B,H,S,hd) <-> (BH,S,hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "block_q", "block_k", "interpret", "use_kernel"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    scale: float, causal: bool = True,
    block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None, use_kernel: bool = True,
) -> jax.Array:
    """q (B,H,Sq,hd), k/v (B,H,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Sk, hd)
    vf = v.reshape(B * H, Sk, hd)
    if not use_kernel:
        return flash_attention_ref(qf, kf, vf, scale, causal).reshape(B, H, Sq, hd)

    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    out, _, _ = flash_attention_pallas(
        qf, kf, vf, scale=scale, causal=causal, sk_valid=Sk,
        q_offset=Sk - Sq,  # align ends: standard self/decode convention
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :Sq].reshape(B, H, Sq, hd)
