"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array, scale: float,
                        causal: bool = True) -> Array:
    """q (BH, Sq, hd), k/v (BH, Sk, hd) -> (BH, Sq, hd); dense softmax."""
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(Sq) + (Sk - Sq)   # align ends (decode-style)
        mask = jnp.arange(Sk)[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
