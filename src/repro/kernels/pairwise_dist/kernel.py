"""Pallas TPU kernel: blocked pairwise squared distances (Krum/Multi-Krum).

Uses the Gram expansion ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b> so the inner
loop is a (K, T) x (T, K) matmul per block — MXU work rather than VPU work.
The (K, K) Gram and the (1, K) squared norms accumulate in revisited VMEM
blocks across the 1-D grid over D; the final combine happens in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _pairwise_kernel(u_ref, gram_ref, norm2_ref):
    u = u_ref[...].astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        norm2_ref[...] = jnp.zeros_like(norm2_ref)

    gram_ref[...] += jnp.dot(u, u.T, preferred_element_type=jnp.float32)
    norm2_ref[...] += jnp.sum(u * u, axis=1)[None, :]


def pairwise_pallas(updates: jax.Array, *, block_d: int = 1024,
                    interpret: bool | None = None):
    K, D = updates.shape
    assert D % block_d == 0
    grid = (D // block_d,)
    out_shapes = (
        jax.ShapeDtypeStruct((K, K), jnp.float32),
        jax.ShapeDtypeStruct((1, K), jnp.float32),
    )
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((K, block_d), lambda i: (0, i))],
        out_specs=(
            pl.BlockSpec((K, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=resolve_interpret(interpret),
    )(updates)
