"""Jitted wrappers: pairwise Gram / squared-distance matrix via the
blocked Pallas kernel.  ``pairwise_gram`` exposes the kernel's raw
(Gram, squared-norms) pair for consumers that need inner products
(cosine distances, Krum's Gram expansion) — reconstructing the Gram
from the distance matrix would round-trip two cancellation-prone
conversions."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_d, resolve_block_d
from repro.kernels.pairwise_dist.kernel import pairwise_pallas
from repro.kernels.pairwise_dist.ref import pairwise_dist_ref


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "use_kernel"))
def pairwise_gram(
    updates: jax.Array,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
):
    """((K, K) Gram matrix, (K,) squared norms) in one blocked pass."""
    if not use_kernel:
        u = updates.astype(jnp.float32)
        gram = u @ u.T
        return gram, jnp.sum(u * u, axis=-1)
    K, D = updates.shape
    block_d, interpret = resolve_block_d(D, block_d, interpret)
    u = pad_d(updates, block_d)
    gram, norm2 = pairwise_pallas(u, block_d=block_d, interpret=interpret)
    return gram, norm2[0]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "use_kernel"))
def pairwise_sq_dists(
    updates: jax.Array,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return pairwise_dist_ref(updates)
    K = updates.shape[0]
    gram, n = pairwise_gram(updates, block_d=block_d, interpret=interpret)
    d2 = n[:, None] + n[None, :] - 2.0 * gram
    # The Gram expansion cancels catastrophically on the diagonal; the
    # self-distance is exactly zero, so pin it.
    d2 = d2 * (1.0 - jnp.eye(K, dtype=d2.dtype))
    return jnp.maximum(d2, 0.0)
