"""Jitted wrapper: pairwise squared-distance matrix via the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairwise_dist.kernel import pairwise_pallas
from repro.kernels.pairwise_dist.ref import pairwise_dist_ref


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "use_kernel"))
def pairwise_sq_dists(
    updates: jax.Array,
    block_d: int = 1024,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return pairwise_dist_ref(updates)
    K, D = updates.shape
    pad = (-D) % block_d
    u = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    gram, norm2 = pairwise_pallas(u, block_d=block_d, interpret=interpret)
    n = norm2[0]
    d2 = n[:, None] + n[None, :] - 2.0 * gram
    # The Gram expansion cancels catastrophically on the diagonal; the
    # self-distance is exactly zero, so pin it.
    d2 = d2 * (1.0 - jnp.eye(K, dtype=d2.dtype))
    return jnp.maximum(d2, 0.0)
