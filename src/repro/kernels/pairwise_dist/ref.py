"""Pure-jnp oracle: pairwise squared-distance Gram matrix for Krum."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_dist_ref(updates: jax.Array) -> jax.Array:
    """(K, D) -> (K, K) squared Euclidean distances."""
    diff = updates[:, None, :] - updates[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
