"""Pallas TPU kernel: fused robust statistics over K candidates.

Tiling: the candidate matrix (K, D) streams HBM->VMEM in (K, T) blocks
(T a multiple of 128 lanes; K <= 32 candidates sit on the sublane axis).
Inside a block we run an odd-even-transposition sorting network over the
K axis — K is static and small, so the network fully unrolls into ~K^2/2
vectorized min/max pairs on (T,)-shaped vregs, which the VPU executes at
full lane width.  The median/trimmed-mean reductions and all per-candidate
partial statistics (distance-to-median, dot-with-median, norms) come out
of the same VMEM-resident block, so the whole WFAgg filter bank costs ONE
HBM read of the candidates.

Temporal extension: when the previous-round candidates ``prev (K, D)``
are supplied, the same VMEM-resident block also accumulates the WFAgg-T
metrics — s_t = ||u - prev||^2 plus the dot/norm terms of b_t — so the
full WFAgg-D/C/T filter bank still costs one read of the candidates (plus
the unavoidable one read of ``prev``).

Grids:
  single  1-D over D/T blocks, candidates (K, D)
  batched 2-D over (node, D/T block), candidates (N, K, D) — all N
          per-node gossip aggregations in ONE kernel launch.  The D axis
          is the innermost grid dimension, so each node's revisited (K,)
          accumulator blocks are initialized at its first D block and
          complete before the grid moves to the next node.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

Array = jax.Array


def sort_rows(x: Array) -> Array:
    """Odd-even transposition sort along axis 0 (static K, fully unrolled)."""
    K = x.shape[0]
    for p in range(K):
        for i in range(p % 2, K - 1, 2):
            a, b = x[i], x[i + 1]
            x = x.at[i].set(jnp.minimum(a, b)).at[i + 1].set(jnp.maximum(a, b))
    return x


def _robust_stats_kernel(*refs, n_trim: int, has_prev: bool,
                         emit_center: bool, d_axis: int):
    """Shared kernel body for the single (d_axis=0) and batched (d_axis=1)
    launches.  Block shapes may carry a leading node axis of size 1; every
    read/write goes through a reshape so one body serves both layouts.
    ``emit_center=False`` drops the streaming (1, D) median/trimmed-mean
    outputs — the WFAgg filter bank only consumes the O(K) accumulators,
    so skipping those writes keeps the fused path at one read + no
    d-sized writes."""
    if has_prev:
        u_ref, prev_ref = refs[0], refs[1]
        outs = refs[2:]
    else:
        u_ref, prev_ref = refs[0], None
        outs = refs[1:]
    if emit_center:
        med_ref, trim_ref = outs[:2]
        acc_refs = outs[2:]
    else:
        med_ref = trim_ref = None
        acc_refs = outs
    dist2_ref, dotmed_ref, norm2_ref, mednorm2_ref = acc_refs[:4]

    u = u_ref[...].astype(jnp.float32)
    u = u.reshape(u.shape[-2], u.shape[-1])            # (K, T)
    K = u.shape[0]

    srt = sort_rows(u)
    if K % 2 == 1:
        med = srt[K // 2]
    else:
        med = 0.5 * (srt[K // 2 - 1] + srt[K // 2])
    if emit_center:
        if n_trim > 0:
            trim = jnp.mean(srt[n_trim : K - n_trim], axis=0)
        else:
            trim = jnp.mean(srt, axis=0)
        med_ref[...] = med.reshape(med_ref.shape).astype(med_ref.dtype)
        trim_ref[...] = trim.reshape(trim_ref.shape).astype(trim_ref.dtype)

    diff = u - med[None, :]
    p_dist2 = jnp.sum(diff * diff, axis=1)          # (K,)
    p_dot = jnp.sum(u * med[None, :], axis=1)       # (K,)
    p_norm2 = jnp.sum(u * u, axis=1)                # (K,)
    p_med2 = jnp.sum(med * med)                     # ()

    @pl.when(pl.program_id(d_axis) == 0)
    def _init():
        for ref in acc_refs:
            ref[...] = jnp.zeros_like(ref)

    dist2_ref[...] += p_dist2.reshape(dist2_ref.shape)
    dotmed_ref[...] += p_dot.reshape(dotmed_ref.shape)
    norm2_ref[...] += p_norm2.reshape(norm2_ref.shape)
    mednorm2_ref[...] += p_med2.reshape(mednorm2_ref.shape)

    if has_prev:
        pdist2_ref, pdot_ref, pnorm2_ref = acc_refs[4:]
        pv = prev_ref[...].astype(jnp.float32)
        pv = pv.reshape(pv.shape[-2], pv.shape[-1])
        dprev = u - pv
        pdist2_ref[...] += jnp.sum(dprev * dprev, axis=1).reshape(pdist2_ref.shape)
        pdot_ref[...] += jnp.sum(u * pv, axis=1).reshape(pdot_ref.shape)
        pnorm2_ref[...] += jnp.sum(pv * pv, axis=1).reshape(pnorm2_ref.shape)


def robust_stats_pallas(
    updates: Array,
    prev: Array | None = None,
    *,
    n_trim: int,
    block_d: int = 1024,
    interpret: bool | None = None,
    emit_center: bool = True,
):
    """Launch the fused robust-stats kernel.  D must be a multiple of block_d.

    Returns ([med, trim,] dist2, dotmed, norm2, mednorm2[, prev_dist2,
    prev_dot, prev_norm2]) — med/trim only with ``emit_center``, the
    temporal tail only when ``prev`` is given.
    """
    K, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    has_prev = prev is not None
    grid = (D // block_d,)
    kernel = functools.partial(
        _robust_stats_kernel, n_trim=n_trim, has_prev=has_prev,
        emit_center=emit_center, d_axis=0
    )
    d_spec = pl.BlockSpec((1, block_d), lambda i: (0, i))
    k_spec = pl.BlockSpec((1, K), lambda i: (0, 0))
    out_shapes, out_specs = [], []
    if emit_center:
        out_shapes += [jax.ShapeDtypeStruct((1, D), jnp.float32)] * 2  # med, trim
        out_specs += [d_spec, d_spec]
    out_shapes += [
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((1, 1), jnp.float32),   # mednorm2
    ]
    out_specs += [k_spec, k_spec, k_spec,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))]
    in_specs = [pl.BlockSpec((K, block_d), lambda i: (0, i))]
    args = [updates]
    if has_prev:
        assert prev.shape == updates.shape, (prev.shape, updates.shape)
        in_specs.append(pl.BlockSpec((K, block_d), lambda i: (0, i)))
        args.append(prev)
        out_shapes += [jax.ShapeDtypeStruct((1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(*args)


def robust_stats_batch_pallas(
    updates: Array,
    prev: Array | None = None,
    *,
    n_trim: int,
    block_d: int = 1024,
    interpret: bool | None = None,
    emit_center: bool = True,
):
    """Batched launch: one kernel over (N, K, D) computes every node's
    statistics.  2-D grid (node, D block); same outputs as the single
    launch with a leading N axis."""
    N, K, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    has_prev = prev is not None
    grid = (N, D // block_d)
    kernel = functools.partial(
        _robust_stats_kernel, n_trim=n_trim, has_prev=has_prev,
        emit_center=emit_center, d_axis=1
    )
    d_spec = pl.BlockSpec((1, 1, block_d), lambda n, i: (n, 0, i))
    k_spec = pl.BlockSpec((1, 1, K), lambda n, i: (n, 0, 0))
    out_shapes, out_specs = [], []
    if emit_center:
        out_shapes += [jax.ShapeDtypeStruct((N, 1, D), jnp.float32)] * 2
        out_specs += [d_spec, d_spec]
    out_shapes += [
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((N, 1, 1), jnp.float32),   # mednorm2
    ]
    out_specs += [k_spec, k_spec, k_spec,
                  pl.BlockSpec((1, 1, 1), lambda n, i: (n, 0, 0))]
    in_specs = [pl.BlockSpec((1, K, block_d), lambda n, i: (n, 0, i))]
    args = [updates]
    if has_prev:
        assert prev.shape == updates.shape, (prev.shape, updates.shape)
        in_specs.append(pl.BlockSpec((1, K, block_d), lambda n, i: (n, 0, i)))
        args.append(prev)
        out_shapes += [jax.ShapeDtypeStruct((N, 1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(*args)
