"""Pallas TPU kernel: fused robust statistics over K candidates.

Tiling: the candidate matrix (K, D) streams HBM->VMEM in (K, T) blocks
(T a multiple of 128 lanes; K <= 32 candidates sit on the sublane axis).
Inside a block we run an odd-even-transposition sorting network over the
K axis — K is static and small, so the network fully unrolls into ~K^2/2
vectorized min/max pairs on (T,)-shaped vregs, which the VPU executes at
full lane width.  The median/trimmed-mean reductions and all per-candidate
partial statistics (distance-to-median, dot-with-median, norms) come out
of the same VMEM-resident block, so the whole WFAgg filter bank costs ONE
HBM read of the candidates.

Temporal extension: when the previous-round candidates ``prev (K, D)``
are supplied, the same VMEM-resident block also accumulates the WFAgg-T
metrics — s_t = ||u - prev||^2 plus the dot/norm terms of b_t — so the
full WFAgg-D/C/T filter bank still costs one read of the candidates (plus
the unavoidable one read of ``prev``).

Grids:
  single  1-D over D/T blocks, candidates (K, D)
  batched 2-D over (node, D/T block), candidates (N, K, D) — all N
          per-node gossip aggregations in ONE kernel launch.  The D axis
          is the innermost grid dimension, so each node's revisited (K,)
          accumulator blocks are initialized at its first D block and
          complete before the grid moves to the next node.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret
from repro.kernels.robust_stats.ref import RobustStats

Array = jax.Array


def sort_rows(x: Array) -> Array:
    """Odd-even transposition sort along axis 0 (static K, fully unrolled)."""
    K = x.shape[0]
    for p in range(K):
        for i in range(p % 2, K - 1, 2):
            a, b = x[i], x[i + 1]
            x = x.at[i].set(jnp.minimum(a, b)).at[i + 1].set(jnp.maximum(a, b))
    return x


def _valid_median(u: Array, vcol: Array) -> Array:
    """Valid-masked median of a resident (K, T) tile: invalid rows sort
    to +inf, the two dynamic middles of the v valid rows are one-hot
    selected, and the degree-0 guard zeroes the empty median (an
    all-invalid row would otherwise pick +inf and 0 * inf would poison
    dotmed with NaNs).  Zero is the safe empty median: every accumulated
    statistic stays finite and the caller's valid mask rejects all slots,
    so the node keeps its local model."""
    K = u.shape[0]
    srt = sort_rows(jnp.where(vcol, u, jnp.inf))
    v = jnp.sum(vcol.astype(jnp.int32))
    lo, hi = (v - 1) // 2, v // 2                        # dynamic middles
    kar = jax.lax.broadcasted_iota(jnp.int32, (K, 1), 0)
    med = 0.5 * (jnp.sum(jnp.where(kar == lo, srt, 0.0), axis=0)
                 + jnp.sum(jnp.where(kar == hi, srt, 0.0), axis=0))
    return jnp.where(v > 0, med, jnp.zeros_like(med))


def _robust_stats_kernel(*refs, n_trim: int, has_prev: bool,
                         emit_center: bool, d_axis: int):
    """Shared kernel body for the single (d_axis=0) and batched (d_axis=1)
    launches.  Block shapes may carry a leading node axis of size 1; every
    read/write goes through a reshape so one body serves both layouts.
    ``emit_center=False`` drops the streaming (1, D) median/trimmed-mean
    outputs — the WFAgg filter bank only consumes the O(K) accumulators,
    so skipping those writes keeps the fused path at one read + no
    d-sized writes."""
    if has_prev:
        u_ref, prev_ref = refs[0], refs[1]
        outs = refs[2:]
    else:
        u_ref, prev_ref = refs[0], None
        outs = refs[1:]
    if emit_center:
        med_ref, trim_ref = outs[:2]
        acc_refs = outs[2:]
    else:
        med_ref = trim_ref = None
        acc_refs = outs
    dist2_ref, dotmed_ref, norm2_ref, mednorm2_ref = acc_refs[:4]

    u = u_ref[...].astype(jnp.float32)
    u = u.reshape(u.shape[-2], u.shape[-1])            # (K, T)
    K = u.shape[0]

    srt = sort_rows(u)
    if K % 2 == 1:
        med = srt[K // 2]
    else:
        med = 0.5 * (srt[K // 2 - 1] + srt[K // 2])
    if emit_center:
        if n_trim > 0:
            trim = jnp.mean(srt[n_trim : K - n_trim], axis=0)
        else:
            trim = jnp.mean(srt, axis=0)
        med_ref[...] = med.reshape(med_ref.shape).astype(med_ref.dtype)
        trim_ref[...] = trim.reshape(trim_ref.shape).astype(trim_ref.dtype)

    diff = u - med[None, :]
    p_dist2 = jnp.sum(diff * diff, axis=1)          # (K,)
    p_dot = jnp.sum(u * med[None, :], axis=1)       # (K,)
    p_norm2 = jnp.sum(u * u, axis=1)                # (K,)
    p_med2 = jnp.sum(med * med)                     # ()

    @pl.when(pl.program_id(d_axis) == 0)
    def _init():
        for ref in acc_refs:
            ref[...] = jnp.zeros_like(ref)

    dist2_ref[...] += p_dist2.reshape(dist2_ref.shape)
    dotmed_ref[...] += p_dot.reshape(dotmed_ref.shape)
    norm2_ref[...] += p_norm2.reshape(norm2_ref.shape)
    mednorm2_ref[...] += p_med2.reshape(mednorm2_ref.shape)

    if has_prev:
        pdist2_ref, pdot_ref, pnorm2_ref = acc_refs[4:]
        pv = prev_ref[...].astype(jnp.float32)
        pv = pv.reshape(pv.shape[-2], pv.shape[-1])
        dprev = u - pv
        pdist2_ref[...] += jnp.sum(dprev * dprev, axis=1).reshape(pdist2_ref.shape)
        pdot_ref[...] += jnp.sum(u * pv, axis=1).reshape(pdot_ref.shape)
        pnorm2_ref[...] += jnp.sum(pv * pv, axis=1).reshape(pnorm2_ref.shape)


def robust_stats_pallas(
    updates: Array,
    prev: Array | None = None,
    *,
    n_trim: int,
    block_d: int = 1024,
    interpret: bool | None = None,
    emit_center: bool = True,
):
    """Launch the fused robust-stats kernel.  D must be a multiple of block_d.

    Returns ([med, trim,] dist2, dotmed, norm2, mednorm2[, prev_dist2,
    prev_dot, prev_norm2]) — med/trim only with ``emit_center``, the
    temporal tail only when ``prev`` is given.
    """
    K, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    has_prev = prev is not None
    grid = (D // block_d,)
    kernel = functools.partial(
        _robust_stats_kernel, n_trim=n_trim, has_prev=has_prev,
        emit_center=emit_center, d_axis=0
    )
    d_spec = pl.BlockSpec((1, block_d), lambda i: (0, i))
    k_spec = pl.BlockSpec((1, K), lambda i: (0, 0))
    out_shapes, out_specs = [], []
    if emit_center:
        out_shapes += [jax.ShapeDtypeStruct((1, D), jnp.float32)] * 2  # med, trim
        out_specs += [d_spec, d_spec]
    out_shapes += [
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((1, 1), jnp.float32),   # mednorm2
    ]
    out_specs += [k_spec, k_spec, k_spec,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))]
    in_specs = [pl.BlockSpec((K, block_d), lambda i: (0, i))]
    args = [updates]
    if has_prev:
        assert prev.shape == updates.shape, (prev.shape, updates.shape)
        in_specs.append(pl.BlockSpec((K, block_d), lambda i: (0, i)))
        args.append(prev)
        out_shapes += [jax.ShapeDtypeStruct((1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(*args)


def _robust_stats_indexed_kernel(*refs, K: int, has_prev: bool,
                                 need_gram: bool):
    """Gather-free body: grid (node, D block, neighbor slot).  Each step
    DMAs ONE neighbor row block — models[neighbor_idx[n, k], d-block],
    resolved by the scalar-prefetch index map — into a VMEM scratch row;
    at the last slot the full (K, T) candidate tile is resident and the
    stats flush exactly like the gathered kernel, so the (N, K, d) gossip
    tensor never exists in HBM.

    The median honors the per-node valid mask: invalid (padded) rows sort
    to +inf and the median picks the dynamic middle of the v valid rows
    via one-hot row selection.  Per-candidate statistics are computed on
    the RAW rows (padded slots hold the node's own finite model), so they
    stay finite and the caller's mask logic drops them by ``valid``.
    """
    idx_ref = refs[0]  # scalar-prefetch neighbor table (unused in body)
    del idx_ref
    valid_ref = refs[1]
    if has_prev:
        u_ref, prev_ref = refs[2], refs[3]
        outs = refs[4:]
    else:
        u_ref, prev_ref = refs[2], None
        outs = refs[3:]
    n_scratch = 2 if has_prev else 1
    scratch_u = outs[-n_scratch]
    scratch_p = outs[-1] if has_prev else None
    acc_refs = outs[:-n_scratch]
    dist2_ref, dotmed_ref, norm2_ref, mednorm2_ref = acc_refs[:4]
    gram_ref = acc_refs[4] if need_gram else None

    i = pl.program_id(1)
    k = pl.program_id(2)
    # program_id must be read OUTSIDE pl.when bodies: the 0.4.x interpret
    # path cannot lower the primitive from inside the cond branch.
    is_last = k == K - 1
    is_first_d = i == 0

    scratch_u[k, :] = u_ref[...].reshape(scratch_u.shape[1:]).astype(jnp.float32)
    if has_prev:
        scratch_p[k, :] = prev_ref[...].reshape(scratch_p.shape[1:]).astype(jnp.float32)

    @pl.when(is_last)
    def _flush():
        u = scratch_u[...]                                   # (K, T)
        vcol = valid_ref[...].reshape(K, 1) > 0.0            # (K, 1)
        med = _valid_median(u, vcol)        # degree-0 guard: empty median = 0

        diff = u - med[None, :]
        p_dist2 = jnp.sum(diff * diff, axis=1)
        p_dot = jnp.sum(u * med[None, :], axis=1)
        p_norm2 = jnp.sum(u * u, axis=1)
        p_med2 = jnp.sum(med * med)

        @pl.when(is_first_d)
        def _init():
            for ref in acc_refs:
                ref[...] = jnp.zeros_like(ref)

        dist2_ref[...] += p_dist2.reshape(dist2_ref.shape)
        dotmed_ref[...] += p_dot.reshape(dotmed_ref.shape)
        norm2_ref[...] += p_norm2.reshape(norm2_ref.shape)
        mednorm2_ref[...] += p_med2.reshape(mednorm2_ref.shape)

        if need_gram:
            # the (K, K) candidate Gram comes free off the resident tile
            # (MXU matmul) — no extra pass for the Alt-WFAgg filters, and
            # nothing quadratic in the TOTAL node count M
            g = jnp.dot(u, u.T, preferred_element_type=jnp.float32)
            gram_ref[...] += g.reshape(gram_ref.shape)

        if has_prev:
            pdist2_ref, pdot_ref, pnorm2_ref = acc_refs[5 if need_gram else 4:]
            pv = scratch_p[...]
            dprev = u - pv
            pdist2_ref[...] += jnp.sum(dprev * dprev, axis=1).reshape(pdist2_ref.shape)
            pdot_ref[...] += jnp.sum(u * pv, axis=1).reshape(pdot_ref.shape)
            pnorm2_ref[...] += jnp.sum(pv * pv, axis=1).reshape(pnorm2_ref.shape)


def robust_stats_indexed_pallas(
    models: Array,        # (M, D) model matrix (row per node)
    neighbor_idx: Array,  # (N, K) int32 rows into ``models``
    valid: Array,         # (N, K) float32, 1.0 on real edges
    prev: Array | None = None,   # (N, K, D) per-edge, or (M, D) matrix
    prev_idx: Array | None = None,  # (N, K) rows into matrix ``prev``
    *,
    block_d: int = 1024,
    interpret: bool | None = None,
    need_gram: bool = False,
):
    """Gather-free robust-stats launch over a 3-D (node, D block, slot)
    grid via ``PrefetchScalarGridSpec``: the neighbor table rides in SMEM
    and the models input's index map reads it, so each grid step streams
    one neighbor row block straight from the (M, D) matrix.  ``prev`` may
    be per-edge (N, K, D) or a previous-round model matrix (M, D) read
    through the same index table — or, with ``prev_idx``, through its OWN
    (N, K) table (fault-injected transport: the payload an edge served
    last round need not be the row it reads this round).  The two tables
    then ride the same SMEM prefetch as one concatenated (N, 2K) block;
    without ``prev_idx`` the launch is byte-identical to before.
    ``need_gram`` also accumulates each node's (K, K) candidate Gram off
    the same resident tile (Alt-WFAgg).
    Returns (dist2, dotmed, norm2, mednorm2[, gram][, prev_dist2,
    prev_dot, prev_norm2]) shaped like the batched launch ((N, 1, K) /
    (N, 1, 1) / (N, K, K)).
    """
    M, D = models.shape
    N, K = neighbor_idx.shape
    assert D % block_d == 0, (D, block_d)
    has_prev = prev is not None
    prev_is_matrix = has_prev and prev.ndim == 2
    if prev_idx is not None and not prev_is_matrix:
        raise ValueError("prev_idx requires a matrix-form prev")
    grid = (N, D // block_d, K)
    kernel = functools.partial(
        _robust_stats_indexed_kernel, K=K, has_prev=has_prev,
        need_gram=need_gram,
    )
    k_spec = pl.BlockSpec((1, 1, K), lambda n, i, k, ir: (n, 0, 0))
    in_specs = [
        pl.BlockSpec((1, K), lambda n, i, k, ir: (n, 0)),          # valid
        pl.BlockSpec((1, block_d), lambda n, i, k, ir: (ir[n, k], i)),  # models
    ]
    args = [valid.astype(jnp.float32), models]
    table = neighbor_idx
    if has_prev:
        if prev_is_matrix:
            assert prev.shape[-1] == models.shape[-1], (prev.shape,
                                                        models.shape)
            if prev_idx is not None:
                assert prev_idx.shape == (N, K), (prev_idx.shape, (N, K))
                table = jnp.concatenate([neighbor_idx, prev_idx], axis=1)
                in_specs.append(pl.BlockSpec(
                    (1, block_d), lambda n, i, k, ir: (ir[n, K + k], i)))
            else:
                assert prev.shape == models.shape, (prev.shape, models.shape)
                in_specs.append(pl.BlockSpec(
                    (1, block_d), lambda n, i, k, ir: (ir[n, k], i)))
        else:
            assert prev.shape == (N, K, D), (prev.shape, (N, K, D))
            in_specs.append(
                pl.BlockSpec((1, 1, block_d), lambda n, i, k, ir: (n, k, i)))
        args.append(prev)
    out_shapes = [
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((N, 1, 1), jnp.float32),   # mednorm2
    ]
    out_specs = [k_spec, k_spec, k_spec,
                 pl.BlockSpec((1, 1, 1), lambda n, i, k, ir: (n, 0, 0))]
    if need_gram:
        out_shapes.append(jax.ShapeDtypeStruct((N, K, K), jnp.float32))
        out_specs.append(pl.BlockSpec((1, K, K), lambda n, i, k, ir: (n, 0, 0)))
    if has_prev:
        out_shapes += [jax.ShapeDtypeStruct((N, 1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    scratch_shapes = [pltpu.VMEM((K, block_d), jnp.float32)]
    if has_prev:
        scratch_shapes.append(pltpu.VMEM((K, block_d), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(table.astype(jnp.int32), *args)


def _wfagg_round_indexed_kernel(*refs, K: int, n_d: int, has_prev: bool,
                                has_tbands: bool, need_gram: bool,
                                cfg, alpha: float, mean_fallback: bool):
    """Single-launch WFAgg round body: grid (node, PHASE, D block, slot).

    Phase 0 is the indexed stats pass — each step DMAs one neighbor row
    block via the scalar-prefetch index map into the (K, T) VMEM scratch
    and flushes the D/C/T accumulators (and the Alt-WFAgg Gram) at the
    last slot, exactly like ``_robust_stats_indexed_kernel``.  At the
    phase boundary (last D block, last slot of phase 0) the WFAgg scoring
    stage runs IN-KERNEL on the VMEM-resident (1, K) accumulators
    (``core.trust.derive_trust_weights`` — the same code the two-launch
    host path vmaps), the masks/weights are written to their O(K)
    outputs, and the normalized combine coefficients land in a VMEM
    scratch.  Phase 1 re-DMAs the neighbor blocks through the same index
    map and accumulates the trust-weighted WFAgg-E combine into the
    (1, T) output block — no host round-trip, no second kernel launch,
    and the candidate re-read hits tiles that are still resident
    whenever a node's (K, D) slab fits VMEM.

    The WFAgg-T decision is four compares against the precomputed flat
    (4K,) EWMA band input (``core.trust.temporal_bands`` — the history
    lives outside the kernel); the ring-buffer push happens on the host
    off the emitted temporal statistics.
    """
    # deferred import: core.wfagg -> robust_stats.ops -> this module at
    # package-init time; by kernel-trace time repro.core is fully loaded
    from repro.core import trust

    idx_ref = refs[0]
    del idx_ref
    refs = list(refs[1:])
    valid_ref = refs.pop(0)
    tbands_ref = refs.pop(0) if has_tbands else None
    local_ref = refs.pop(0)
    u_ref = refs.pop(0)
    prev_ref = refs.pop(0) if has_prev else None
    out_ref, w_ref, md_ref, mc_ref, mt_ref = refs[:5]
    n_acc = 4 + (1 if need_gram else 0) + (3 if has_prev else 0)
    acc_refs = refs[5:5 + n_acc]
    scratch = refs[5 + n_acc:]
    dist2_ref, dotmed_ref, norm2_ref, mednorm2_ref = acc_refs[:4]
    gram_ref = acc_refs[4] if need_gram else None
    prev_acc = acc_refs[5 if need_gram else 4:] if has_prev else ()
    scratch_u = scratch[0]
    scratch_p = scratch[1] if has_prev else None
    wcomb_ref, lcoef_ref = scratch[-2], scratch[-1]

    # program ids read OUTSIDE pl.when bodies (0.4.x interpret rule)
    p = pl.program_id(1)
    i = pl.program_id(2)
    k = pl.program_id(3)
    is_phase0 = p == 0
    is_last_slot = k == K - 1
    is_first_d = i == 0
    is_boundary = is_phase0 & is_last_slot & (i == n_d - 1)

    u_now = u_ref[...].astype(jnp.float32).reshape(1, -1)   # (1, T)

    @pl.when(is_phase0)
    def _stage():
        scratch_u[k, :] = u_now[0]
        if has_prev:
            scratch_p[k, :] = prev_ref[...].reshape(
                scratch_p.shape[1:]).astype(jnp.float32)

    @pl.when(is_phase0 & is_last_slot)
    def _flush():
        u = scratch_u[...]                                   # (K, T)
        vcol = valid_ref[...].reshape(K, 1) > 0.0
        med = _valid_median(u, vcol)        # degree-0 guard: empty median = 0

        diff = u - med[None, :]
        p_dist2 = jnp.sum(diff * diff, axis=1)
        p_dot = jnp.sum(u * med[None, :], axis=1)
        p_norm2 = jnp.sum(u * u, axis=1)
        p_med2 = jnp.sum(med * med)

        @pl.when(is_first_d)
        def _init():
            for ref in acc_refs:
                ref[...] = jnp.zeros_like(ref)

        dist2_ref[...] += p_dist2.reshape(dist2_ref.shape)
        dotmed_ref[...] += p_dot.reshape(dotmed_ref.shape)
        norm2_ref[...] += p_norm2.reshape(norm2_ref.shape)
        mednorm2_ref[...] += p_med2.reshape(mednorm2_ref.shape)

        if need_gram:
            g = jnp.dot(u, u.T, preferred_element_type=jnp.float32)
            gram_ref[...] += g.reshape(gram_ref.shape)

        if has_prev:
            pdist2_ref, pdot_ref, pnorm2_ref = prev_acc
            pv = scratch_p[...]
            dprev = u - pv
            pdist2_ref[...] += jnp.sum(dprev * dprev, axis=1).reshape(pdist2_ref.shape)
            pdot_ref[...] += jnp.sum(u * pv, axis=1).reshape(pdot_ref.shape)
            pnorm2_ref[...] += jnp.sum(pv * pv, axis=1).reshape(pnorm2_ref.shape)

    @pl.when(is_boundary)
    def _derive():
        valid_f = valid_ref[...].reshape(K)
        tail = [r[...].reshape(K) for r in prev_acc] if has_prev \
            else [None, None, None]
        stats = RobustStats(
            med=None, trim=None,
            dist2=dist2_ref[...].reshape(K),
            dotmed=dotmed_ref[...].reshape(K),
            norm2=norm2_ref[...].reshape(K),
            mednorm2=jnp.reshape(mednorm2_ref[...], ()),
            prev_dist2=tail[0], prev_dot=tail[1], prev_norm2=tail[2],
        )
        gram = gram_ref[...].reshape(K, K) if need_gram else None
        tb = tbands_ref[...].reshape(4, K) if has_tbands else None
        mask_d, mask_c, mask_t, w = trust.derive_trust_weights(
            stats, gram, valid_f, tb, cfg)
        md_ref[...] = mask_d.astype(jnp.float32).reshape(md_ref.shape)
        mc_ref[...] = mask_c.astype(jnp.float32).reshape(mc_ref.shape)
        mt_ref[...] = mask_t.astype(jnp.float32).reshape(mt_ref.shape)
        w_ref[...] = w.reshape(w_ref.shape)
        wcomb, lcoef = trust.combine_coefficients(w, alpha, valid_f,
                                                  mean_fallback)
        wcomb_ref[...] = wcomb.reshape(1, K)
        lcoef_ref[...] = jnp.reshape(lcoef, (1, 1))

    # ---- phase 1: trust-weighted combine (same DMA pattern, weights in
    # VMEM from the boundary step; matches _weighted_agg_indexed_kernel) --
    is_phase1 = jnp.logical_not(is_phase0)
    kio = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    @pl.when(is_phase1 & (k == 0))
    def _seed():
        wk = jnp.sum(jnp.where(kio == k, wcomb_ref[...], 0.0))
        lc = lcoef_ref[0, 0]
        out_ref[...] = (lc * local_ref[...].astype(jnp.float32)
                        + wk * u_now).reshape(out_ref.shape)

    @pl.when(is_phase1 & (k != 0))
    def _accum():
        wk = jnp.sum(jnp.where(kio == k, wcomb_ref[...], 0.0))
        out_ref[...] += (wk * u_now).reshape(out_ref.shape)


def wfagg_round_indexed_pallas(
    local: Array,         # (N, D) local models (combine anchors)
    models: Array,        # (M, D) model matrix (row per node)
    neighbor_idx: Array,  # (N, K) int32 rows into ``models``
    valid: Array,         # (N, K) float32, 1.0 on real edges
    cfg,                  # duck-typed WFAggConfig (static)
    prev: Array | None = None,    # (N, K, D) per-edge, or (M, D) matrix
    tbands: Array | None = None,  # (N, 4K) flat WFAgg-T EWMA bands
    prev_idx: Array | None = None,  # (N, K) rows into matrix ``prev``
    *,
    alpha: float,
    mean_fallback: bool = False,
    need_gram: bool = False,
    block_d: int = 1024,
    interpret: bool | None = None,
):
    """Launch the single-launch WFAgg round kernel over a 4-D
    (node, phase, D block, slot) grid.  Phase 0 accumulates the indexed
    robust statistics, the phase boundary derives the trust weights
    in-kernel, and phase 1 writes the WFAgg-E combine — one launch for
    the entire gossip round.

    With ``prev_idx`` the matrix-form ``prev`` reads through its own
    (N, K) table (concatenated after ``neighbor_idx`` into one (N, 2K)
    SMEM prefetch block) instead of re-using the models table — the
    fault-injected transport's staleness pricing, still one launch.

    Returns (out (N, D), weights, mask_d, mask_c, mask_t (each (N, 1, K)),
    dist2, dotmed, norm2 ((N, 1, K)), mednorm2 ((N, 1, 1))
    [, gram (N, K, K)][, prev_dist2, prev_dot, prev_norm2 ((N, 1, K))]).
    """
    M, D = models.shape
    N, K = neighbor_idx.shape
    assert D % block_d == 0, (D, block_d)
    assert local.shape == (N, D), (local.shape, (N, D))
    n_d = D // block_d
    has_prev = prev is not None
    has_tbands = tbands is not None
    prev_is_matrix = has_prev and prev.ndim == 2
    if prev_idx is not None and not prev_is_matrix:
        raise ValueError("prev_idx requires a matrix-form prev")
    grid = (N, 2, n_d, K)
    kernel = functools.partial(
        _wfagg_round_indexed_kernel, K=K, n_d=n_d, has_prev=has_prev,
        has_tbands=has_tbands, need_gram=need_gram, cfg=cfg, alpha=alpha,
        mean_fallback=mean_fallback,
    )
    k_spec = pl.BlockSpec((1, 1, K), lambda n, p, i, k, ir: (n, 0, 0))
    in_specs = [
        pl.BlockSpec((1, K), lambda n, p, i, k, ir: (n, 0)),        # valid
    ]
    args = [valid.astype(jnp.float32)]
    if has_tbands:
        # bands ride as a flat (N, 4K) 2-D input (kernel reshapes to
        # (4, K)) — a 3-D (N, 4, K) buffer would false-positive the
        # (N, K, d)-free HLO assertions whenever K == 4
        assert tbands.shape == (N, 4 * K), (tbands.shape, (N, 4 * K))
        in_specs.append(
            pl.BlockSpec((1, 4 * K), lambda n, p, i, k, ir: (n, 0)))
        args.append(tbands.astype(jnp.float32))
    # local: pinned to block 0 during phase 0 (only phase 1 reads it) —
    # `i * p` keeps the fetched block constant until the combine phase
    in_specs.append(
        pl.BlockSpec((1, block_d), lambda n, p, i, k, ir: (n, i * p)))
    args.append(local)
    in_specs.append(
        pl.BlockSpec((1, block_d), lambda n, p, i, k, ir: (ir[n, k], i)))
    args.append(models)
    table = neighbor_idx
    if has_prev:
        # prev is only read in phase 0: pin the index map to one constant
        # block during phase 1 so the re-walk fetches nothing new
        if prev_is_matrix:
            if prev_idx is not None:
                assert prev_idx.shape == (N, K), (prev_idx.shape, (N, K))
                assert prev.shape[-1] == models.shape[-1], (prev.shape,
                                                           models.shape)
                table = jnp.concatenate([neighbor_idx, prev_idx], axis=1)
                in_specs.append(pl.BlockSpec(
                    (1, block_d),
                    lambda n, p, i, k, ir: (ir[n, K + k * (1 - p)],
                                            i * (1 - p))))
            else:
                assert prev.shape == models.shape, (prev.shape, models.shape)
                in_specs.append(pl.BlockSpec(
                    (1, block_d),
                    lambda n, p, i, k, ir: (ir[n, k * (1 - p)],
                                            i * (1 - p))))
        else:
            assert prev.shape == (N, K, D), (prev.shape, (N, K, D))
            in_specs.append(pl.BlockSpec(
                (1, 1, block_d),
                lambda n, p, i, k, ir: (n, k * (1 - p), i * (1 - p))))
        args.append(prev)

    out_shapes = [
        jax.ShapeDtypeStruct((N, D), jnp.float32),      # combined models
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # trust weights
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # mask_d
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # mask_c
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # mask_t
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((N, 1, 1), jnp.float32),   # mednorm2
    ]
    out_specs = [
        # the combine output is revisited at block 0 through phase 0 and
        # only written in phase 1 (`i * p` pins it, like `local`)
        pl.BlockSpec((1, block_d), lambda n, p, i, k, ir: (n, i * p)),
        k_spec, k_spec, k_spec, k_spec,                  # weights + masks
        k_spec, k_spec, k_spec,
        pl.BlockSpec((1, 1, 1), lambda n, p, i, k, ir: (n, 0, 0)),
    ]
    if need_gram:
        out_shapes.append(jax.ShapeDtypeStruct((N, K, K), jnp.float32))
        out_specs.append(
            pl.BlockSpec((1, K, K), lambda n, p, i, k, ir: (n, 0, 0)))
    if has_prev:
        out_shapes += [jax.ShapeDtypeStruct((N, 1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    scratch_shapes = [pltpu.VMEM((K, block_d), jnp.float32)]
    if has_prev:
        scratch_shapes.append(pltpu.VMEM((K, block_d), jnp.float32))
    scratch_shapes += [pltpu.VMEM((1, K), jnp.float32),   # combine weights
                       pltpu.VMEM((1, 1), jnp.float32)]   # local coefficient
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(table.astype(jnp.int32), *args)


def robust_stats_batch_pallas(
    updates: Array,
    prev: Array | None = None,
    *,
    n_trim: int,
    block_d: int = 1024,
    interpret: bool | None = None,
    emit_center: bool = True,
):
    """Batched launch: one kernel over (N, K, D) computes every node's
    statistics.  2-D grid (node, D block); same outputs as the single
    launch with a leading N axis."""
    N, K, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    has_prev = prev is not None
    grid = (N, D // block_d)
    kernel = functools.partial(
        _robust_stats_kernel, n_trim=n_trim, has_prev=has_prev,
        emit_center=emit_center, d_axis=1
    )
    d_spec = pl.BlockSpec((1, 1, block_d), lambda n, i: (n, 0, i))
    k_spec = pl.BlockSpec((1, 1, K), lambda n, i: (n, 0, 0))
    out_shapes, out_specs = [], []
    if emit_center:
        out_shapes += [jax.ShapeDtypeStruct((N, 1, D), jnp.float32)] * 2
        out_specs += [d_spec, d_spec]
    out_shapes += [
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((N, 1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((N, 1, 1), jnp.float32),   # mednorm2
    ]
    out_specs += [k_spec, k_spec, k_spec,
                  pl.BlockSpec((1, 1, 1), lambda n, i: (n, 0, 0))]
    in_specs = [pl.BlockSpec((1, K, block_d), lambda n, i: (n, 0, i))]
    args = [updates]
    if has_prev:
        assert prev.shape == updates.shape, (prev.shape, updates.shape)
        in_specs.append(pl.BlockSpec((1, K, block_d), lambda n, i: (n, 0, i)))
        args.append(prev)
        out_shapes += [jax.ShapeDtypeStruct((N, 1, K), jnp.float32)] * 3
        out_specs += [k_spec] * 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=resolve_interpret(interpret),
    )(*args)
