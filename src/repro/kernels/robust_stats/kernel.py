"""Pallas TPU kernel: fused robust statistics over K candidates.

Tiling: the candidate matrix (K, D) streams HBM->VMEM in (K, T) blocks
(T a multiple of 128 lanes; K <= 32 candidates sit on the sublane axis).
Inside a block we run an odd-even-transposition sorting network over the
K axis — K is static and small, so the network fully unrolls into ~K^2/2
vectorized min/max pairs on (T,)-shaped vregs, which the VPU executes at
full lane width.  The median/trimmed-mean reductions and all per-candidate
partial statistics (distance-to-median, dot-with-median, norms) come out
of the same VMEM-resident block, so the whole WFAgg filter bank costs ONE
HBM read of the candidates.

Grid: 1-D over D/T blocks.  Per-candidate statistics accumulate into a
revisited (1, K) output block (init at program_id 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def sort_rows(x: Array) -> Array:
    """Odd-even transposition sort along axis 0 (static K, fully unrolled)."""
    K = x.shape[0]
    for p in range(K):
        for i in range(p % 2, K - 1, 2):
            a, b = x[i], x[i + 1]
            x = x.at[i].set(jnp.minimum(a, b)).at[i + 1].set(jnp.maximum(a, b))
    return x


def _robust_stats_kernel(
    u_ref,          # (K, T) candidate block
    med_ref,        # (1, T) out
    trim_ref,       # (1, T) out
    dist2_ref,      # (1, K) out, accumulated
    dotmed_ref,     # (1, K) out, accumulated
    norm2_ref,      # (1, K) out, accumulated
    mednorm2_ref,   # (1, 1) out, accumulated
    *,
    n_trim: int,
):
    u = u_ref[...].astype(jnp.float32)
    K = u.shape[0]

    srt = sort_rows(u)
    if K % 2 == 1:
        med = srt[K // 2]
    else:
        med = 0.5 * (srt[K // 2 - 1] + srt[K // 2])
    if n_trim > 0:
        trim = jnp.mean(srt[n_trim : K - n_trim], axis=0)
    else:
        trim = jnp.mean(srt, axis=0)
    med_ref[...] = med[None, :].astype(med_ref.dtype)
    trim_ref[...] = trim[None, :].astype(trim_ref.dtype)

    diff = u - med[None, :]
    p_dist2 = jnp.sum(diff * diff, axis=1)          # (K,)
    p_dot = jnp.sum(u * med[None, :], axis=1)       # (K,)
    p_norm2 = jnp.sum(u * u, axis=1)                # (K,)
    p_med2 = jnp.sum(med * med)                     # ()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dist2_ref[...] = jnp.zeros_like(dist2_ref)
        dotmed_ref[...] = jnp.zeros_like(dotmed_ref)
        norm2_ref[...] = jnp.zeros_like(norm2_ref)
        mednorm2_ref[...] = jnp.zeros_like(mednorm2_ref)

    dist2_ref[...] += p_dist2[None, :]
    dotmed_ref[...] += p_dot[None, :]
    norm2_ref[...] += p_norm2[None, :]
    mednorm2_ref[...] += p_med2[None, None]


def robust_stats_pallas(
    updates: Array,
    *,
    n_trim: int,
    block_d: int = 1024,
    interpret: bool = True,
):
    """Launch the fused robust-stats kernel.  D must be a multiple of block_d."""
    K, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    kernel = functools.partial(_robust_stats_kernel, n_trim=n_trim)
    out_shapes = (
        jax.ShapeDtypeStruct((1, D), jnp.float32),   # med
        jax.ShapeDtypeStruct((1, D), jnp.float32),   # trim
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dist2
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # dotmed
        jax.ShapeDtypeStruct((1, K), jnp.float32),   # norm2
        jax.ShapeDtypeStruct((1, 1), jnp.float32),   # mednorm2
    )
    in_specs = [pl.BlockSpec((K, block_d), lambda i: (0, i))]
    out_specs = (
        pl.BlockSpec((1, block_d), lambda i: (0, i)),
        pl.BlockSpec((1, block_d), lambda i: (0, i)),
        pl.BlockSpec((1, K), lambda i: (0, 0)),
        pl.BlockSpec((1, K), lambda i: (0, 0)),
        pl.BlockSpec((1, K), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(updates)
