"""Jitted public wrappers for the fused robust-stats kernel.

Handles D padding to the block size (zero padding is exact: a zero column
has median 0, contributing nothing to any accumulated statistic — and
this extends to the temporal statistics, since ``prev`` is padded with
zeros too) and returns the same ``RobustStats`` namedtuple as the oracle
in ref.py.

``robust_stats`` operates on one (K, D) candidate matrix;
``robust_stats_batch`` runs all N nodes of a gossip round through ONE
kernel launch over the gathered (N, K, D) tensor (2-D grid), instead of
a vmap of single-node calls — vmapping a pallas_call serializes into a
per-node outer loop, while the batched grid streams every node's blocks
through the same kernel instance.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import auto_block_d, resolve_interpret
from repro.kernels.robust_stats.kernel import (
    robust_stats_batch_pallas,
    robust_stats_indexed_pallas,
    robust_stats_pallas,
)
from repro.kernels.robust_stats.ref import (
    RobustStats,
    robust_stats_indexed_ref,
    robust_stats_ref,
    trim_count,
)


def _pad_d(x: jax.Array, block_d: int) -> jax.Array:
    pad = (-x.shape[-1]) % block_d
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x.astype(jnp.float32), cfgpad)


@functools.partial(jax.jit, static_argnames=(
    "beta", "block_d", "interpret", "use_kernel", "need_center"))
def robust_stats(
    updates: jax.Array,
    prev: Optional[jax.Array] = None,
    beta: float = 0.1,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_center: bool = True,
) -> RobustStats:
    """Fused median / trimmed-mean / WFAgg filter statistics over (K, D).

    With ``prev`` (the previous-round candidates), the same single pass
    also emits the WFAgg-T temporal metrics (prev_dist2/prev_dot/
    prev_norm2); without it those fields are None.  ``block_d=None``
    picks a backend-appropriate tile (see auto_block_d).
    ``need_center=False`` skips the streaming (D,)-sized median/trim
    outputs (med/trim come back None) — the WFAgg filter bank consumes
    only the O(K) accumulators, so its fused path writes nothing d-sized.
    """
    if not use_kernel:
        return robust_stats_ref(updates, beta, prev=prev)
    K, D = updates.shape
    n_trim = trim_count(K, beta)
    itp = resolve_interpret(interpret)
    if block_d is None:
        block_d = auto_block_d(D, itp)
    u = _pad_d(updates, block_d)
    p = _pad_d(prev, block_d) if prev is not None else None
    outs = robust_stats_pallas(
        u, p, n_trim=n_trim, block_d=block_d, interpret=itp,
        emit_center=need_center,
    )
    if need_center:
        med, trim = outs[0][0, :D], outs[1][0, :D]
        outs = outs[2:]
    else:
        med = trim = None
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[0] for o in outs[4:])
    return RobustStats(
        med=med,
        trim=trim,
        dist2=dist2[0],
        dotmed=dotmed[0],
        norm2=norm2[0],
        mednorm2=mednorm2[0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
    )


@functools.partial(jax.jit, static_argnames=(
    "block_d", "interpret", "use_kernel", "need_gram"))
def robust_stats_indexed(
    models: jax.Array,
    neighbor_idx: jax.Array,
    valid: Optional[jax.Array] = None,
    prev: Optional[jax.Array] = None,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_gram: bool = False,
) -> RobustStats:
    """Gather-free batched statistics: ``models (M, d)`` + ``neighbor_idx
    (N, K)`` replace the gathered (N, K, d) tensor — the kernel DMAs each
    neighbor's d-block straight from the model matrix (scalar-prefetch
    index map), so the K-fold gossip tensor never exists in HBM.

    ``valid (N, K)`` marks real edges on irregular (padded) topologies:
    the in-kernel median spans only valid rows; per-candidate stats of
    padded slots are finite garbage the caller masks out.  ``prev`` may
    be per-edge (N, K, d) or a previous-round model matrix (M, d) read
    through the same index table.  Output layout matches
    ``robust_stats_batch`` (leading N axis; med/trim are None — the
    filter bank never reads a d-sized center).  ``need_gram`` also emits
    the per-node (K, K) candidate Gram, accumulated from the SAME
    resident tile — no extra pass, and nothing quadratic in the total
    node count M (the Alt-WFAgg filters consume it).
    """
    if not use_kernel:
        return robust_stats_indexed_ref(models, neighbor_idx, valid, prev,
                                        need_gram=need_gram)
    N, K = neighbor_idx.shape
    itp = resolve_interpret(interpret)
    if block_d is None:
        block_d = auto_block_d(models.shape[-1], itp)
    m = _pad_d(models, block_d)
    p = _pad_d(prev, block_d) if prev is not None else None
    v = (jnp.ones((N, K), jnp.float32) if valid is None
         else valid.astype(jnp.float32))
    outs = robust_stats_indexed_pallas(
        m, neighbor_idx, v, p, block_d=block_d, interpret=itp,
        need_gram=need_gram)
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    rest = outs[4:]
    gram = None
    if need_gram:
        gram, rest = rest[0], rest[1:]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[:, 0, :] for o in rest)
    return RobustStats(
        med=None,
        trim=None,
        dist2=dist2[:, 0, :],
        dotmed=dotmed[:, 0, :],
        norm2=norm2[:, 0, :],
        mednorm2=mednorm2[:, 0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
        gram=gram,
    )


@functools.partial(jax.jit, static_argnames=(
    "beta", "block_d", "interpret", "use_kernel", "need_center"))
def robust_stats_batch(
    updates: jax.Array,
    prev: Optional[jax.Array] = None,
    beta: float = 0.1,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_center: bool = True,
) -> RobustStats:
    """Batched fused statistics over (N, K, D): one kernel launch for all
    N per-node aggregations.  Every ``RobustStats`` field gains a leading
    N axis (``mednorm2`` becomes (N,))."""
    if not use_kernel:
        return jax.vmap(lambda u, p: robust_stats_ref(u, beta, prev=p))(
            updates, prev
        ) if prev is not None else jax.vmap(
            lambda u: robust_stats_ref(u, beta))(updates)
    N, K, D = updates.shape
    n_trim = trim_count(K, beta)
    itp = resolve_interpret(interpret)
    if block_d is None:
        block_d = auto_block_d(D, itp)
    u = _pad_d(updates, block_d)
    p = _pad_d(prev, block_d) if prev is not None else None
    outs = robust_stats_batch_pallas(
        u, p, n_trim=n_trim, block_d=block_d, interpret=itp,
        emit_center=need_center,
    )
    if need_center:
        med, trim = outs[0][:, 0, :D], outs[1][:, 0, :D]
        outs = outs[2:]
    else:
        med = trim = None
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[:, 0, :] for o in outs[4:])
    return RobustStats(
        med=med,
        trim=trim,
        dist2=dist2[:, 0, :],
        dotmed=dotmed[:, 0, :],
        norm2=norm2[:, 0, :],
        mednorm2=mednorm2[:, 0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
    )
