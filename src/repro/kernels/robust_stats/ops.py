"""Jitted public wrappers for the fused robust-stats kernel.

Handles D padding to the block size (zero padding is exact: a zero column
has median 0, contributing nothing to any accumulated statistic — and
this extends to the temporal statistics, since ``prev`` is padded with
zeros too) and returns the same ``RobustStats`` namedtuple as the oracle
in ref.py.

``robust_stats`` operates on one (K, D) candidate matrix;
``robust_stats_batch`` runs all N nodes of a gossip round through ONE
kernel launch over the gathered (N, K, D) tensor (2-D grid), instead of
a vmap of single-node calls — vmapping a pallas_call serializes into a
per-node outer loop, while the batched grid streams every node's blocks
through the same kernel instance.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_d, resolve_block_d
from repro.kernels.robust_stats.kernel import (
    robust_stats_batch_pallas,
    robust_stats_indexed_pallas,
    robust_stats_pallas,
    wfagg_round_indexed_pallas,
)
from repro.kernels.robust_stats.ref import (
    RobustStats,
    robust_stats_indexed_ref,
    robust_stats_ref,
    trim_count,
)


@functools.partial(jax.jit, static_argnames=(
    "beta", "block_d", "interpret", "use_kernel", "need_center"))
def robust_stats(
    updates: jax.Array,
    prev: Optional[jax.Array] = None,
    beta: float = 0.1,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_center: bool = True,
) -> RobustStats:
    """Fused median / trimmed-mean / WFAgg filter statistics over (K, D).

    With ``prev`` (the previous-round candidates), the same single pass
    also emits the WFAgg-T temporal metrics (prev_dist2/prev_dot/
    prev_norm2); without it those fields are None.  ``block_d=None``
    picks a backend-appropriate tile (see auto_block_d).
    ``need_center=False`` skips the streaming (D,)-sized median/trim
    outputs (med/trim come back None) — the WFAgg filter bank consumes
    only the O(K) accumulators, so its fused path writes nothing d-sized.
    """
    if not use_kernel:
        return robust_stats_ref(updates, beta, prev=prev)
    K, D = updates.shape
    n_trim = trim_count(K, beta)
    block_d, itp = resolve_block_d(D, block_d, interpret)
    u = pad_d(updates, block_d)
    p = pad_d(prev, block_d) if prev is not None else None
    outs = robust_stats_pallas(
        u, p, n_trim=n_trim, block_d=block_d, interpret=itp,
        emit_center=need_center,
    )
    if need_center:
        med, trim = outs[0][0, :D], outs[1][0, :D]
        outs = outs[2:]
    else:
        med = trim = None
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[0] for o in outs[4:])
    return RobustStats(
        med=med,
        trim=trim,
        dist2=dist2[0],
        dotmed=dotmed[0],
        norm2=norm2[0],
        mednorm2=mednorm2[0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
    )


@functools.partial(jax.jit, static_argnames=(
    "block_d", "interpret", "use_kernel", "need_gram"))
def robust_stats_indexed(
    models: jax.Array,
    neighbor_idx: jax.Array,
    valid: Optional[jax.Array] = None,
    prev: Optional[jax.Array] = None,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_gram: bool = False,
    prev_idx: Optional[jax.Array] = None,
) -> RobustStats:
    """Gather-free batched statistics: ``models (M, d)`` + ``neighbor_idx
    (N, K)`` replace the gathered (N, K, d) tensor — the kernel DMAs each
    neighbor's d-block straight from the model matrix (scalar-prefetch
    index map), so the K-fold gossip tensor never exists in HBM.

    ``valid (N, K)`` marks real edges on irregular (padded) topologies:
    the in-kernel median spans only valid rows; per-candidate stats of
    padded slots are finite garbage the caller masks out.  ``prev`` may
    be per-edge (N, K, d) or a previous-round model matrix (M, d) read
    through the same index table.  Output layout matches
    ``robust_stats_batch`` (leading N axis; med/trim are None — the
    filter bank never reads a d-sized center).  ``need_gram`` also emits
    the per-node (K, K) candidate Gram, accumulated from the SAME
    resident tile — no extra pass, and nothing quadratic in the total
    node count M (the Alt-WFAgg filters consume it).  ``prev_idx (N, K)``
    points matrix-form ``prev`` reads at rows OTHER than the live
    neighbor table — the chaos transport's staleness pricing (see
    dfl/faults.py).
    """
    if not use_kernel:
        return robust_stats_indexed_ref(models, neighbor_idx, valid, prev,
                                        need_gram=need_gram,
                                        prev_idx=prev_idx)
    N, K = neighbor_idx.shape
    block_d, itp = resolve_block_d(models.shape[-1], block_d, interpret)
    m = pad_d(models, block_d)
    p = pad_d(prev, block_d) if prev is not None else None
    v = (jnp.ones((N, K), jnp.float32) if valid is None
         else valid.astype(jnp.float32))
    outs = robust_stats_indexed_pallas(
        m, neighbor_idx, v, p, block_d=block_d, interpret=itp,
        need_gram=need_gram, prev_idx=prev_idx)
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    rest = outs[4:]
    gram = None
    if need_gram:
        gram, rest = rest[0], rest[1:]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[:, 0, :] for o in rest)
    return RobustStats(
        med=None,
        trim=None,
        dist2=dist2[:, 0, :],
        dotmed=dotmed[:, 0, :],
        norm2=norm2[:, 0, :],
        mednorm2=mednorm2[:, 0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
        gram=gram,
    )


@functools.partial(jax.jit, static_argnames=(
    "beta", "block_d", "interpret", "use_kernel", "need_center"))
def robust_stats_batch(
    updates: jax.Array,
    prev: Optional[jax.Array] = None,
    beta: float = 0.1,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    need_center: bool = True,
) -> RobustStats:
    """Batched fused statistics over (N, K, D): one kernel launch for all
    N per-node aggregations.  Every ``RobustStats`` field gains a leading
    N axis (``mednorm2`` becomes (N,))."""
    if not use_kernel:
        return jax.vmap(lambda u, p: robust_stats_ref(u, beta, prev=p))(
            updates, prev
        ) if prev is not None else jax.vmap(
            lambda u: robust_stats_ref(u, beta))(updates)
    N, K, D = updates.shape
    n_trim = trim_count(K, beta)
    block_d, itp = resolve_block_d(D, block_d, interpret)
    u = pad_d(updates, block_d)
    p = pad_d(prev, block_d) if prev is not None else None
    outs = robust_stats_batch_pallas(
        u, p, n_trim=n_trim, block_d=block_d, interpret=itp,
        emit_center=need_center,
    )
    if need_center:
        med, trim = outs[0][:, 0, :D], outs[1][:, 0, :D]
        outs = outs[2:]
    else:
        med = trim = None
    dist2, dotmed, norm2, mednorm2 = outs[:4]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[:, 0, :] for o in outs[4:])
    return RobustStats(
        med=med,
        trim=trim,
        dist2=dist2[:, 0, :],
        dotmed=dotmed[:, 0, :],
        norm2=norm2[:, 0, :],
        mednorm2=mednorm2[:, 0, 0],
        prev_dist2=tail[0],
        prev_dot=tail[1],
        prev_norm2=tail[2],
    )


@functools.partial(jax.jit, static_argnames=(
    "cfg", "alpha", "mean_fallback", "block_d", "interpret"))
def wfagg_round_indexed(
    local: jax.Array,          # (N, d) combine anchors (local models)
    models: jax.Array,         # (M, d) model matrix
    neighbor_idx: jax.Array,   # (N, K) rows into models
    valid: Optional[jax.Array],    # (N, K); None = all valid
    cfg,                       # WFAggConfig (static; sets the filters)
    prev: Optional[jax.Array] = None,    # (N, K, d) or (M, d) matrix
    tbands: Optional[jax.Array] = None,  # (N, 4, K) WFAgg-T EWMA bands
    prev_idx: Optional[jax.Array] = None,  # (N, K) rows into matrix prev
    alpha: Optional[float] = None,
    mean_fallback: bool = False,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """One-launch gossip round: the fused WFAgg-E combine folded into the
    indexed robust_stats kernel (ROADMAP's "2 passes -> ~1").

    A single 4-D (node, phase, D block, slot) Pallas launch streams the
    neighbor blocks, accumulates every filter statistic, derives the
    trust weights at the in-kernel phase boundary
    (``core.trust.derive_trust_weights`` on the VMEM-resident (1, K)
    accumulators — the Alt-WFAgg Gram included via the resident-tile
    matmul), and writes the trust-weighted combine in phase 1.  The
    WFAgg-T EWMA bands are precomputed from history by the caller
    (``core.trust.temporal_bands``) and ride in as an O(K) input; the
    in-kernel temporal decision is a compare against the kernel's own
    prev_dist2 / cosine statistics.

    Returns ``(out (N, d), weights (N, K), mask_d, mask_c, mask_t
    ((N, K) bool), stats)`` where ``stats`` is a ``RobustStats`` with
    (N, K)-shaped accumulators (the caller pushes the WFAgg-T ring
    buffers from its temporal tail).  ``mean_fallback`` selects the
    all-rejected behavior: local model (DFL, Eq. 3) vs uniform valid
    mean (robust all-reduce).

    Interpret-mode block policy: ONE D block (``interpret_blocks=1``) —
    the interpreter carries the (N, d) combine output through every grid
    step, so fewer/bigger steps beat smaller tiles; compiled TPU keeps
    1024-lane tiles.
    """
    from repro.core import trust  # deferred: see kernel.py

    N, K = neighbor_idx.shape
    d = models.shape[-1]
    if tbands is not None and prev is None:
        raise ValueError(
            "tbands requires prev: the in-kernel WFAgg-T band compare "
            "reads the kernel's own prev_dist2/cosine temporal statistics")
    if alpha is None:
        alpha = cfg.alpha
    block_d, itp = resolve_block_d(d, block_d, interpret, interpret_blocks=1)
    m = pad_d(models, block_d)
    loc = pad_d(local, block_d)
    p = pad_d(prev, block_d) if prev is not None else None
    v = (jnp.ones((N, K), jnp.float32) if valid is None
         else valid.astype(jnp.float32))
    # (N, 4, K) bands flatten to 2-D for the launch (no 3-D O(K) buffer
    # may exist — the (N, K, d)-free HLO assertions grep by rank)
    tb = tbands.reshape(N, 4 * K) if tbands is not None else None
    outs = wfagg_round_indexed_pallas(
        loc, m, neighbor_idx, v, cfg, p, tb, prev_idx,
        alpha=float(alpha), mean_fallback=mean_fallback,
        need_gram=trust.needs_gram(cfg), block_d=block_d, interpret=itp)
    out = outs[0][:, :d]
    weights = outs[1][:, 0, :]
    mask_d, mask_c, mask_t = (o[:, 0, :] > 0.0 for o in outs[2:5])
    dist2, dotmed, norm2, mednorm2 = outs[5:9]
    rest = outs[9:]
    gram = None
    if trust.needs_gram(cfg):
        gram, rest = rest[0], rest[1:]
    tail = (None, None, None)
    if prev is not None:
        tail = tuple(o[:, 0, :] for o in rest)
    stats = RobustStats(
        med=None, trim=None,
        dist2=dist2[:, 0, :], dotmed=dotmed[:, 0, :], norm2=norm2[:, 0, :],
        mednorm2=mednorm2[:, 0, 0],
        prev_dist2=tail[0], prev_dot=tail[1], prev_norm2=tail[2],
        gram=gram,
    )
    return out, weights, mask_d, mask_c, mask_t, stats
