"""Jitted public wrapper for the fused robust-stats kernel.

Handles D padding to the block size (zero padding is exact: a zero column
has median 0, contributing nothing to any accumulated statistic) and
returns the same ``RobustStats`` namedtuple as the oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.robust_stats.kernel import robust_stats_pallas
from repro.kernels.robust_stats.ref import RobustStats, robust_stats_ref, trim_count


@functools.partial(jax.jit, static_argnames=("beta", "block_d", "interpret", "use_kernel"))
def robust_stats(
    updates: jax.Array,
    beta: float = 0.1,
    block_d: int = 1024,
    interpret: bool = True,
    use_kernel: bool = True,
) -> RobustStats:
    """Fused median / trimmed-mean / WFAgg filter statistics over (K, D)."""
    if not use_kernel:
        return robust_stats_ref(updates, beta)
    K, D = updates.shape
    n_trim = trim_count(K, beta)
    pad = (-D) % block_d
    u = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    med, trim, dist2, dotmed, norm2, mednorm2 = robust_stats_pallas(
        u, n_trim=n_trim, block_d=block_d, interpret=interpret
    )
    return RobustStats(
        med=med[0, :D],
        trim=trim[0, :D],
        dist2=dist2[0],
        dotmed=dotmed[0],
        norm2=norm2[0],
        mednorm2=mednorm2[0, 0],
    )
