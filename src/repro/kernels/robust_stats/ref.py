"""Pure-jnp oracle for the fused robust-statistics kernel.

Given a candidate matrix ``updates (K, D)`` computes, in one logical pass:
  med       (D,)  coordinate-wise median (mean of the two middles, K even)
  trim      (D,)  beta-trimmed coordinate-wise mean
  dist2     (K,)  squared L2 distance of each candidate to the median model
  dotmed    (K,)  inner product of each candidate with the median model
  norm2     (K,)  squared L2 norm of each candidate
  mednorm2  ()    squared L2 norm of the median model

and, when the previous-round candidates ``prev (K, D)`` are supplied:
  prev_dist2 (K,) squared L2 distance to the previous update  (WFAgg-T s_t)
  prev_dot   (K,) inner product with the previous update
  prev_norm2 (K,) squared L2 norm of the previous update

These are exactly the sufficient statistics of WFAgg-D (Alg. 2), WFAgg-C
(Alg. 3) and WFAgg-T (Alg. 4) plus the Median / Trimmed-Mean baselines —
one HBM read of the candidate block serves all of them.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class RobustStats(NamedTuple):
    med: Array
    trim: Array
    dist2: Array
    dotmed: Array
    norm2: Array
    mednorm2: Array
    # temporal tail — populated only when the kernel was given ``prev``
    prev_dist2: Optional[Array] = None
    prev_dot: Optional[Array] = None
    prev_norm2: Optional[Array] = None

    def cosine_to_median(self) -> Array:
        """1 - cos(theta_j, theta_med): the WFAgg-C metric (clip-invariant)."""
        denom = jnp.sqrt(jnp.maximum(self.norm2 * self.mednorm2, 1e-24))
        return 1.0 - self.dotmed / denom

    def cosine_to_prev(self) -> Array:
        """1 - cos(theta_j^t, theta_j^{t-1}): the WFAgg-T b_t metric."""
        denom = jnp.sqrt(jnp.maximum(self.norm2 * self.prev_norm2, 1e-24))
        return 1.0 - self.prev_dot / denom


def trim_count(K: int, beta: float) -> int:
    return int(beta * K)


def robust_stats_ref(updates: Array, beta: float = 0.1,
                     prev: Optional[Array] = None) -> RobustStats:
    K = updates.shape[0]
    srt = jnp.sort(updates, axis=0)
    if K % 2 == 1:
        med = srt[K // 2]
    else:
        med = 0.5 * (srt[K // 2 - 1] + srt[K // 2])
    t = trim_count(K, beta)
    trim = jnp.mean(srt[t : K - t] if t > 0 else srt, axis=0)
    diff = updates - med[None, :]
    dist2 = jnp.sum(diff * diff, axis=-1)
    dotmed = updates @ med
    norm2 = jnp.sum(updates * updates, axis=-1)
    mednorm2 = jnp.sum(med * med)
    prev_dist2 = prev_dot = prev_norm2 = None
    if prev is not None:
        dp = updates - prev
        prev_dist2 = jnp.sum(dp * dp, axis=-1)
        prev_dot = jnp.sum(updates * prev, axis=-1)
        prev_norm2 = jnp.sum(prev * prev, axis=-1)
    return RobustStats(med, trim, dist2, dotmed, norm2, mednorm2,
                       prev_dist2, prev_dot, prev_norm2)
