"""Pure-jnp oracle for the fused robust-statistics kernel.

Given a candidate matrix ``updates (K, D)`` computes, in one logical pass:
  med       (D,)  coordinate-wise median (mean of the two middles, K even)
  trim      (D,)  beta-trimmed coordinate-wise mean
  dist2     (K,)  squared L2 distance of each candidate to the median model
  dotmed    (K,)  inner product of each candidate with the median model
  norm2     (K,)  squared L2 norm of each candidate
  mednorm2  ()    squared L2 norm of the median model

and, when the previous-round candidates ``prev (K, D)`` are supplied:
  prev_dist2 (K,) squared L2 distance to the previous update  (WFAgg-T s_t)
  prev_dot   (K,) inner product with the previous update
  prev_norm2 (K,) squared L2 norm of the previous update

These are exactly the sufficient statistics of WFAgg-D (Alg. 2), WFAgg-C
(Alg. 3) and WFAgg-T (Alg. 4) plus the Median / Trimmed-Mean baselines —
one HBM read of the candidate block serves all of them.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class RobustStats(NamedTuple):
    med: Array
    trim: Array
    dist2: Array
    dotmed: Array
    norm2: Array
    mednorm2: Array
    # temporal tail — populated only when the kernel was given ``prev``
    prev_dist2: Optional[Array] = None
    prev_dot: Optional[Array] = None
    prev_norm2: Optional[Array] = None
    # (K, K) candidate Gram — populated only on the indexed path with
    # ``need_gram`` (accumulated from the same VMEM-resident tile, so the
    # Alt-WFAgg filters cost no extra candidate pass)
    gram: Optional[Array] = None

    def cosine_to_median(self) -> Array:
        """1 - cos(theta_j, theta_med): the WFAgg-C metric (clip-invariant)."""
        denom = jnp.sqrt(jnp.maximum(self.norm2 * self.mednorm2, 1e-24))
        return 1.0 - self.dotmed / denom

    def cosine_to_prev(self) -> Array:
        """1 - cos(theta_j^t, theta_j^{t-1}): the WFAgg-T b_t metric."""
        denom = jnp.sqrt(jnp.maximum(self.norm2 * self.prev_norm2, 1e-24))
        return 1.0 - self.prev_dot / denom


def trim_count(K: int, beta: float) -> int:
    return int(beta * K)


def robust_stats_indexed_ref(
    models: Array,            # (M, D) model matrix
    neighbor_idx: Array,      # (N, K) rows into models (padded w/ self)
    valid: Optional[Array] = None,   # (N, K) bool; None = all valid
    prev: Optional[Array] = None,    # (N, K, D) per-edge or (M, D) matrix
    need_gram: bool = False,
    prev_idx: Optional[Array] = None,  # (N, K) rows into matrix ``prev``
) -> RobustStats:
    """Oracle for the gather-free kernel (the oracle MAY gather).

    The median is taken over the valid rows only (invalid rows sort to
    +inf; the middle element indices come from the per-node valid count).
    Per-candidate statistics are computed on the raw padded rows — the
    caller masks them with ``valid`` — so every output stays finite.
    ``med``/``trim`` are None: the indexed entry serves the WFAgg filter
    bank, which never reads a d-sized center.
    """
    u = models[neighbor_idx].astype(jnp.float32)     # (N, K, D)
    N, K, _ = u.shape
    if valid is None:
        valid = jnp.ones((N, K), dtype=bool)
    vmask = valid.astype(bool)
    srt = jnp.sort(jnp.where(vmask[..., None], u, jnp.inf), axis=1)
    v = vmask.sum(axis=1)
    lo, hi = (v - 1) // 2, v // 2
    take = lambda j: jnp.take_along_axis(srt, j[:, None, None], axis=1)[:, 0, :]
    med = 0.5 * (take(lo) + take(hi))                # (N, D)
    # degree-0 rows have no valid middle (the take lands on +inf): the
    # empty median is 0, matching the kernel's guard — all stats finite,
    # and the caller's valid mask makes the node keep its local model
    med = jnp.where((v > 0)[:, None], med, 0.0)
    diff = u - med[:, None, :]
    dist2 = jnp.sum(diff * diff, axis=-1)
    dotmed = jnp.einsum("nkd,nd->nk", u, med)
    norm2 = jnp.sum(u * u, axis=-1)
    mednorm2 = jnp.sum(med * med, axis=-1)
    prev_dist2 = prev_dot = prev_norm2 = None
    if prev is not None:
        if prev_idx is not None and prev.ndim != 2:
            raise ValueError("prev_idx requires a matrix-form prev")
        pidx = neighbor_idx if prev_idx is None else prev_idx
        pe = (prev[pidx] if prev.ndim == 2 else prev).astype(jnp.float32)
        dp = u - pe
        prev_dist2 = jnp.sum(dp * dp, axis=-1)
        prev_dot = jnp.sum(u * pe, axis=-1)
        prev_norm2 = jnp.sum(pe * pe, axis=-1)
    gram = jnp.einsum("nkd,njd->nkj", u, u) if need_gram else None
    return RobustStats(None, None, dist2, dotmed, norm2, mednorm2,
                       prev_dist2, prev_dot, prev_norm2, gram)


def robust_stats_ref(updates: Array, beta: float = 0.1,
                     prev: Optional[Array] = None) -> RobustStats:
    K = updates.shape[0]
    srt = jnp.sort(updates, axis=0)
    if K % 2 == 1:
        med = srt[K // 2]
    else:
        med = 0.5 * (srt[K // 2 - 1] + srt[K // 2])
    t = trim_count(K, beta)
    trim = jnp.mean(srt[t : K - t] if t > 0 else srt, axis=0)
    diff = updates - med[None, :]
    dist2 = jnp.sum(diff * diff, axis=-1)
    dotmed = updates @ med
    norm2 = jnp.sum(updates * updates, axis=-1)
    mednorm2 = jnp.sum(med * med)
    prev_dist2 = prev_dot = prev_norm2 = None
    if prev is not None:
        dp = updates - prev
        prev_dist2 = jnp.sum(dp * dp, axis=-1)
        prev_dot = jnp.sum(updates * prev, axis=-1)
        prev_norm2 = jnp.sum(prev * prev, axis=-1)
    return RobustStats(med, trim, dist2, dotmed, norm2, mednorm2,
                       prev_dist2, prev_dot, prev_norm2)
