"""Pallas TPU kernel: fused WFAgg-E consensus combine.

out = lcoef * local + wvec @ updates, blocked over D.  ``wvec`` carries the
already-normalized trust weights scaled by the smoothing factor alpha, and
``lcoef`` = 1 - alpha_eff; both are computed once in ops.py (they are (K,)
and scalar — negligible), so the kernel makes exactly one HBM pass over
the (K, D) candidates fused with the (D,) local model read and (D,) write.
The K-way reduce is a (1, K) x (K, T) matmul -> MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _weighted_agg_kernel(w_ref, lcoef_ref, local_ref, u_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)            # (K, T)
    w = w_ref[...].astype(jnp.float32)            # (1, K)
    lc = lcoef_ref[0, 0]
    acc = jnp.dot(w, u, preferred_element_type=jnp.float32)  # (1, T)
    out_ref[...] = lc * local_ref[...].astype(jnp.float32) + acc


def weighted_agg_pallas(
    wvec: jax.Array,      # (1, K) normalized weights * alpha_eff
    lcoef: jax.Array,     # (1, 1) local coefficient 1 - alpha_eff
    local: jax.Array,     # (1, D)
    updates: jax.Array,   # (K, D)
    *,
    block_d: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    K, D = updates.shape
    assert D % block_d == 0
    grid = (D // block_d,)
    return pl.pallas_call(
        _weighted_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((K, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(wvec, lcoef, local, updates)
