"""Pallas TPU kernel: fused WFAgg-E consensus combine.

out = lcoef * local + wvec @ updates, blocked over D.  ``wvec`` carries the
already-normalized trust weights scaled by the smoothing factor alpha, and
``lcoef`` = 1 - alpha_eff; both are computed once in ops.py (they are (K,)
and scalar — negligible), so the kernel makes exactly one HBM pass over
the (K, D) candidates fused with the (D,) local model read and (D,) write.
The K-way reduce is a (1, K) x (K, T) matmul -> MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret


def _weighted_agg_kernel(w_ref, lcoef_ref, local_ref, u_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)            # (K, T)
    w = w_ref[...].astype(jnp.float32)            # (1, K)
    lc = lcoef_ref[0, 0]
    acc = jnp.dot(w, u, preferred_element_type=jnp.float32)  # (1, T)
    out_ref[...] = lc * local_ref[...].astype(jnp.float32) + acc


def _weighted_agg_indexed_kernel(idx_ref, w_ref, lcoef_ref, local_ref, u_ref,
                                 out_ref, *, K: int):
    """Gather-free batched combine: grid (node, D block, neighbor slot).
    Each step DMAs one neighbor row block (scalar-prefetch index map) and
    accumulates w[n, k] * models[idx[n, k]] into the revisited output
    block, seeding it with lcoef * local at the first slot — the (N, K, d)
    gossip tensor never exists."""
    del idx_ref
    k = pl.program_id(2)
    is_first = k == 0
    u = u_ref[...].astype(jnp.float32).reshape(1, -1)     # (1, T)
    w = w_ref[...].astype(jnp.float32)                    # (1, K)
    kio = jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    wk = jnp.sum(jnp.where(kio == k, w, 0.0))             # scalar w[n, k]

    @pl.when(is_first)
    def _seed():
        lc = lcoef_ref[0, 0]
        out_ref[...] = (lc * local_ref[...].astype(jnp.float32)
                        + wk * u).reshape(out_ref.shape)

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        out_ref[...] += (wk * u).reshape(out_ref.shape)


def weighted_agg_indexed_pallas(
    wvec: jax.Array,          # (N, K) normalized weights * alpha_eff
    lcoef: jax.Array,         # (N, 1) local coefficient 1 - alpha_eff
    local: jax.Array,         # (N, D)
    models: jax.Array,        # (M, D) model matrix
    neighbor_idx: jax.Array,  # (N, K) rows into models
    *,
    block_d: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    N, K = wvec.shape
    M, D = models.shape
    assert D % block_d == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N, D // block_d, K),
        in_specs=[
            pl.BlockSpec((1, K), lambda n, i, k, ir: (n, 0)),
            pl.BlockSpec((1, 1), lambda n, i, k, ir: (n, 0)),
            pl.BlockSpec((1, block_d), lambda n, i, k, ir: (n, i)),
            pl.BlockSpec((1, block_d), lambda n, i, k, ir: (ir[n, k], i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda n, i, k, ir: (n, i)),
    )
    return pl.pallas_call(
        functools.partial(_weighted_agg_indexed_kernel, K=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(neighbor_idx.astype(jnp.int32), wvec, lcoef, local, models)


def weighted_agg_pallas(
    wvec: jax.Array,      # (1, K) normalized weights * alpha_eff
    lcoef: jax.Array,     # (1, 1) local coefficient 1 - alpha_eff
    local: jax.Array,     # (1, D)
    updates: jax.Array,   # (K, D)
    *,
    block_d: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    K, D = updates.shape
    assert D % block_d == 0
    grid = (D // block_d,)
    return pl.pallas_call(
        _weighted_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((K, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(wvec, lcoef, local, updates)
