"""Jitted wrapper for the fused WFAgg-E combine kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import auto_block_d, resolve_interpret
from repro.kernels.weighted_agg.kernel import weighted_agg_pallas
from repro.kernels.weighted_agg.ref import weighted_agg_ref


@functools.partial(jax.jit, static_argnames=("alpha", "block_d", "interpret", "use_kernel"))
def weighted_agg(
    local: jax.Array,
    updates: jax.Array,
    weights: jax.Array,
    alpha: float = 0.8,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return weighted_agg_ref(local, updates, weights, alpha)
    K, D = updates.shape
    interpret = resolve_interpret(interpret)
    if block_d is None:
        block_d = auto_block_d(D, interpret)
    wsum = weights.sum()
    w_norm = weights / jnp.maximum(wsum, 1e-12)
    eff_alpha = jnp.where(wsum > 0, alpha, 0.0)
    pad = (-D) % block_d
    u = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    loc = jnp.pad(local.astype(jnp.float32), (0, pad))[None, :]
    out = weighted_agg_pallas(
        (eff_alpha * w_norm)[None, :].astype(jnp.float32),
        jnp.reshape(1.0 - eff_alpha, (1, 1)).astype(jnp.float32),
        loc,
        u,
        block_d=block_d,
        interpret=interpret,
    )
    return out[0, :D]
