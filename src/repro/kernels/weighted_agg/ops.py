"""Jitted wrappers for the fused WFAgg-E combine kernel (single-node and
the gather-free batched/indexed variant)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_d, resolve_block_d
from repro.kernels.weighted_agg.kernel import (
    weighted_agg_indexed_pallas,
    weighted_agg_pallas,
)
from repro.kernels.weighted_agg.ref import weighted_agg_ref


@functools.partial(jax.jit, static_argnames=("alpha", "block_d", "interpret", "use_kernel"))
def weighted_agg(
    local: jax.Array,
    updates: jax.Array,
    weights: jax.Array,
    alpha: float = 0.8,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return weighted_agg_ref(local, updates, weights, alpha)
    K, D = updates.shape
    block_d, interpret = resolve_block_d(D, block_d, interpret)
    wsum = weights.sum()
    w_norm = weights / jnp.maximum(wsum, 1e-12)
    eff_alpha = jnp.where(wsum > 0, alpha, 0.0)
    u = pad_d(updates, block_d)
    loc = pad_d(local, block_d)[None, :]
    out = weighted_agg_pallas(
        (eff_alpha * w_norm)[None, :].astype(jnp.float32),
        jnp.reshape(1.0 - eff_alpha, (1, 1)).astype(jnp.float32),
        loc,
        u,
        block_d=block_d,
        interpret=interpret,
    )
    return out[0, :D]


@functools.partial(jax.jit, static_argnames=("alpha", "block_d", "interpret", "use_kernel"))
def weighted_agg_indexed(
    local: jax.Array,          # (N, d)
    models: jax.Array,         # (M, d) model matrix
    neighbor_idx: jax.Array,   # (N, K) rows into models
    weights: jax.Array,        # (N, K) trust weights (0 on invalid slots)
    alpha: float = 0.8,
    block_d: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Gather-free batched WFAgg-E combine: out_n = (1 - a_n) local_n +
    a_n * sum_k w'_nk models[idx[n, k]], one kernel launch for all N
    nodes, with the neighbor rows DMA'd straight from the (M, d) model
    matrix.  Nodes whose weights sum to zero keep their local model."""
    wsum = weights.sum(axis=-1)
    w_norm = weights / jnp.maximum(wsum, 1e-12)[:, None]
    eff_alpha = jnp.where(wsum > 0, alpha, 0.0)
    if not use_kernel:
        gathered = models[neighbor_idx].astype(jnp.float32)
        neighbor = jnp.einsum("nk,nkd->nd", w_norm, gathered)
        return (1.0 - eff_alpha)[:, None] * local + eff_alpha[:, None] * neighbor
    N, d = local.shape
    block_d, interpret = resolve_block_d(d, block_d, interpret)
    m = pad_d(models, block_d)
    loc = pad_d(local, block_d)
    out = weighted_agg_indexed_pallas(
        (eff_alpha[:, None] * w_norm).astype(jnp.float32),
        (1.0 - eff_alpha)[:, None].astype(jnp.float32),
        loc,
        m,
        neighbor_idx,
        block_d=block_d,
        interpret=interpret,
    )
    return out[:, :d]
