"""Pure-jnp oracle for the WFAgg-E weighted-aggregation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg_ref(
    local: jax.Array, updates: jax.Array, weights: jax.Array, alpha: float
) -> jax.Array:
    """Eq. 3: (1-a)*local + a * sum_j w'_j theta_j with w' normalized.

    If all weights are zero the neighbor term vanishes and the local model
    is returned unchanged.
    """
    wsum = weights.sum()
    w_norm = weights / jnp.maximum(wsum, 1e-12)
    eff_alpha = jnp.where(wsum > 0, alpha, 0.0)
    return (1.0 - eff_alpha) * local + eff_alpha * (w_norm @ updates)
