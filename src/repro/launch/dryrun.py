import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count on first init.
# This is the ONLY entry point that forces 512 host devices; smoke tests
# and benchmarks see the single real CPU device.
"""Multi-pod dry-run launcher.

For every (architecture x input shape) pair, lower + compile the real
train/prefill/decode step against the production mesh (16x16 single-pod,
2x16x16 multi-pod) with ShapeDtypeStruct inputs (zero allocation), then
extract:

  * memory_analysis()  — per-device argument/temp/output bytes (fits HBM?)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * the collective schedule — parsed from the optimized HLO text, with
    per-op wire-byte estimates (ring-schedule factors per collective kind)

and derive the three roofline terms (DESIGN.md Section 8).  One JSON
artifact per pair lands in ``benchmarks/artifacts/`` for roofline.py.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # sweep, subprocess per pair
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, List, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

# TPU v5e
HBM_PER_CHIP = 16e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# '%name = TYPE[dims]{layout} kind(' — also matches tuple outputs '(T1, T2) kind('
_INSTR_RE = re.compile(
    r"=\s*(?P<out>\(?[a-z0-9\[\],{}<>= ]+?\)?)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [G,S]<=[...] : G groups of size S
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [s for s in m.group(1).split(",") if s.strip()]
        return max(1, len(ids))
    if _SRC_TGT_RE.search(line):
        return 2  # permute: one send+recv per device
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Collective schedule from optimized HLO.

    Per instruction we know the per-device OUTPUT bytes and the replica
    group size S.  Ring-schedule wire bytes per device:
      all-gather      out*(S-1)/S      (out = full gathered buffer)
      all-reduce      2*out*(S-1)/S    (reduce-scatter + all-gather)
      reduce-scatter  out*(S-1)        (out = one shard)
      all-to-all      out*(S-1)/S
      collective-permute  out          (dedicated link)
    """
    per_kind: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "out_bytes": 0.0, "wire_bytes": 0.0} for k in _COLL_KINDS}
    ops: List[Dict[str, Any]] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue  # count start (or sync form) once; skip the -done half
        kind = m.group("kind")
        out_b = _shape_bytes(m.group("out"))
        S = _group_size(line, n_devices)
        if kind == "all-gather":
            wire = out_b * (S - 1) / max(S, 1)
        elif kind == "all-reduce":
            wire = 2 * out_b * (S - 1) / max(S, 1)
        elif kind == "reduce-scatter":
            wire = out_b * (S - 1)
        elif kind == "all-to-all":
            wire = out_b * (S - 1) / max(S, 1)
        else:  # collective-permute
            wire = out_b
        pk = per_kind[kind]
        pk["count"] += 1
        pk["out_bytes"] += out_b
        pk["wire_bytes"] += wire
        ops.append({"kind": kind, "out_bytes": out_b, "group_size": S,
                    "wire_bytes": wire})
    total_wire = sum(k["wire_bytes"] for k in per_kind.values())
    return {"per_kind": per_kind, "total_wire_bytes": total_wire,
            "n_ops": len(ops), "largest": sorted(
                ops, key=lambda o: -o["wire_bytes"])[:8]}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            layout: str = "flat", param_dtype: str = "") -> Dict[str, Any]:
    import jax

    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch import specs as sp
    from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                   make_production_mesh)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    # Gossip-round VMEM headroom at this model size: the fused one-launch
    # round kernel traced abstractly at d = param_count under the
    # compiled-TPU block policy (repro.analysis.vmem).  Residency is
    # d-independent once the model dim is blocked — recording it per
    # config turns that scaling claim into data the roofline artifacts
    # carry (see docs/STATIC_ANALYSIS.md, vmem-budget rule).
    try:
        from repro.analysis.vmem import config_vmem_report
        rec["gossip_vmem"] = config_vmem_report(arch=arch)[0]
    except Exception as e:  # advisory record; never fails the dry-run
        rec["gossip_vmem"] = {"error": repr(e)}

    variant = sp.arch_variant(cfg, shape)
    if param_dtype and variant is not None:
        import dataclasses
        variant = dataclasses.replace(variant, param_dtype=param_dtype)
    if variant is None:
        rec.update(status="skipped",
                   reason="enc-dec 500k-token decode outside operating regime "
                          "(DESIGN.md Section 6)")
        return rec
    if shape.kind in ("decode",) and not variant.supports_long_context \
            and shape.name == "long_500k":
        rec.update(status="skipped", reason="full-attention arch at 500k")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    fn, args, info = sp.build_dryrun(variant, shape, mesh, multi_pod,
                                     layout=layout)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text, n_dev)

    # trip-count-aware totals (raw cost_analysis counts scan bodies once;
    # every layer stack here is a lax.scan) — see hlo_analysis.py
    from repro.launch import hlo_analysis as ha
    tca = ha.analyze(hlo_text, n_dev)

    flops_dev = float(tca.flops)
    bytes_dev = float(tca.bytes)
    wire_dev = float(tca.wire_bytes)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]

    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_global = flops_dev * n_dev

    arg_b = mem.argument_size_in_bytes if mem else 0
    temp_b = mem.temp_size_in_bytes if mem else 0
    out_b = mem.output_size_in_bytes if mem else 0
    alias_b = mem.alias_size_in_bytes if mem else 0
    peak_b = arg_b + temp_b + out_b - alias_b

    rec.update(
        status="ok", mode=info, n_devices=n_dev,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory={"argument_bytes": arg_b, "temp_bytes": temp_b,
                "output_bytes": out_b, "alias_bytes": alias_b,
                "peak_bytes": peak_b, "hbm_bytes": HBM_PER_CHIP,
                "fits": bool(peak_b <= HBM_PER_CHIP)},
        cost={"flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
              "raw_flops": float(cost.get("flops", 0.0)),
              "raw_bytes": float(cost.get("bytes accessed", 0.0)),
              "transcendentals": float(cost.get("transcendentals", 0.0)),
              "n_while": tca.n_while,
              "unknown_trip_whiles": tca.unknown_trip_whiles,
              "trip_counts": tca.trip_counts[:32]},
        collectives={"per_kind_wire_bytes": tca.coll_by_kind,
                     "schedule_once": coll},
        roofline={
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "step_s_lower_bound": max(compute_s, memory_s, collective_s),
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": (model_flops / hlo_flops_global
                                   if hlo_flops_global else 0.0),
        },
    )
    return rec


def artifact_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "multi" if multi_pod else "single"
    suffix = f".{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}.{shape}.{mesh}{suffix}.json")


def _sweep(multi_pod_too: bool, tag: str) -> int:
    """Run every pair in a subprocess (compile-state isolation)."""
    from repro.configs.registry import assigned_archs
    from repro.configs.shapes import SHAPES

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    failures = 0
    meshes = [False, True] if multi_pod_too else [False]
    for arch in assigned_archs():
        for shape in SHAPES:
            for mp in meshes:
                out = artifact_path(arch, shape, mp, tag)
                if os.path.exists(out):
                    print(f"[skip-cached] {arch} x {shape} mp={mp}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--json", out]
                if mp:
                    cmd.append("--multi-pod")
                if tag:
                    cmd += ["--tag", tag]
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                dt = time.time() - t0
                if r.returncode != 0:
                    failures += 1
                    print(f"[FAIL {dt:6.1f}s] {arch} x {shape} mp={mp}\n"
                          f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
                else:
                    print(f"[ok   {dt:6.1f}s] {arch} x {shape} mp={mp} "
                          f"{r.stdout.strip().splitlines()[-1] if r.stdout.strip() else ''}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--with-multi-pod", action="store_true",
                    help="with --all: also run every pair on the 2x16x16 mesh")
    ap.add_argument("--json", help="artifact output path")
    ap.add_argument("--tag", default="", help="artifact tag (perf experiments)")
    ap.add_argument("--layout", default="stacked", choices=("flat", "stacked"),
                    help="robust-agg gradient layout (train shapes)")
    ap.add_argument("--param-dtype", default="",
                    help="override cfg.param_dtype (perf experiments)")
    args = ap.parse_args()

    if args.all:
        sys.exit(1 if _sweep(args.with_multi_pod, args.tag) else 0)

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_one(args.arch, args.shape, args.multi_pod, layout=args.layout,
                      param_dtype=args.param_dtype)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "traceback": traceback.format_exc()}
    out = args.json or artifact_path(args.arch, args.shape, args.multi_pod,
                                     args.tag)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"{rec['arch']} x {rec['shape']} [{rec['mesh']}] "
              f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
              f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
              f"fits={rec['memory']['fits']}")
    elif rec["status"] == "skipped":
        print(f"{rec['arch']} x {rec['shape']} SKIPPED: {rec['reason']}")
    else:
        print(rec.get("traceback", "error"), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
