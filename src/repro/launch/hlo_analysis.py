"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE — but every
layer stack in this codebase is a ``lax.scan`` (L iterations) and the
robust all-reduce streams gradient chunks through a scan (n_chunks
iterations).  Raw cost_analysis therefore under-reports FLOPs, HBM bytes
and collective traffic by 1-3 orders of magnitude on exactly the programs
we care about.

This module re-derives the three roofline terms from the optimized HLO
text itself:

  1. split the module into computations;
  2. build a global  %name -> (dtype, shape)  table from instruction defs
     (operands are printed without types on the CPU backend);
  3. per computation, accumulate
       - dot/convolution FLOPs (from output shape x contracting dims),
       - fusion-granularity HBM bytes (each top-level op materializes its
         output once and reads its operands once),
       - collective wire bytes (ring-schedule factors per kind);
  4. walk the call graph (body=/condition=/calls=) multiplying every
     computation's cost by the product of enclosing while-loop
     ``known_trip_count``s;
  5. totals = sum over computations of multiplier x local cost.

Validated against unrolled references in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.+?\)?)\s+([\w\-]+)\(")
_SHAPE_TOK_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"?n"?[":\\]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CALLED_COMPS_RE = re.compile(r"called_computations=\{([^}]*)\}")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
# the FULL brace-list form: replica_groups={{0,1},{2,3},...} — the older
# _GROUPS_LIST_RE only captures the first group, which is all _group_size
# needs but not enough for cover-the-mesh / singleton-group checks
_GROUPS_FULL_RE = re.compile(r"replica_groups=\{(\{[0-9, ]*\}(?:,\s*\{[0-9, ]*\})*)\}")
_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")
_KERNEL_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")

# opcodes that move no HBM bytes at fusion granularity
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "iota", "after-all", "partition-id", "replica-id", "custom-call",
    "opt-barrier", "domain", "add-dependency",
}


def _parse_shape(text: str) -> Tuple[int, int]:
    """(total elements across shape tokens, total bytes)."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_TOK_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Instr:
    name: str
    out_text: str
    opcode: str
    line: str

    @property
    def out_elems(self) -> int:
        return _parse_shape(self.out_text)[0]

    @property
    def out_bytes(self) -> int:
        return _parse_shape(self.out_text)[1]


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_by_kind: Optional[Dict[str, float]] = None
    unknown_trip: int = 0

    def __post_init__(self):
        if self.coll_by_kind is None:
            self.coll_by_kind = {k: 0.0 for k in _COLL_KINDS}


@dataclasses.dataclass
class Collective:
    """One collective instruction in the module, with its replica-group
    structure resolved (the per-collective record the SPMD contract
    rules consume — see repro.analysis.collectives)."""
    name: str
    kind: str                 # base kind (async -start folded in)
    out_bytes: int            # payload bytes (halved for non-AR -start)
    group_size: int           # devices per replica group
    n_groups: int
    groups: Optional[List[List[int]]]  # explicit brace-list groups, if any
    group_form: str           # "iota" | "list" | "pairs" | "default"
    wire_bytes: float         # per-device wire bytes, ONE execution
    mult: float               # call-graph trip-count multiplier
    line: str

    def participants(self) -> Optional[set]:
        if self.groups is not None:
            return {d for g in self.groups for d in g}
        if self.group_form == "iota":
            return set(range(self.group_size * self.n_groups))
        return None

    def covers_mesh(self, n_devices: int) -> Optional[bool]:
        """Whether every device participates (None if undecidable)."""
        p = self.participants()
        return None if p is None else p == set(range(n_devices))

    def to_dict(self) -> Dict:
        return {
            "name": self.name, "kind": self.kind,
            "out_bytes": self.out_bytes, "group_size": self.group_size,
            "n_groups": self.n_groups, "group_form": self.group_form,
            "wire_bytes": self.wire_bytes, "mult": self.mult,
        }


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    wire_bytes: float
    coll_by_kind: Dict[str, float]
    n_while: int
    unknown_trip_whiles: int
    trip_counts: List[int]
    top_bytes: Optional[List[Tuple[float, str]]] = None  # (bytes x mult, instr)
    top_wire: Optional[List[Tuple[float, str]]] = None
    # computations unreachable from the entry via the parsed call graph:
    # dead code the compiler kept, or a call-graph edge this analyzer
    # missed — either way its cost is NOT in the totals, so surface it
    dead_computations: Optional[List[str]] = None
    # every collective instruction with resolved replica groups, sorted
    # by mult x wire_bytes descending (-done halves are skipped)
    collectives: Optional[List[Collective]] = None
    num_partitions: int = 1


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    current: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HEADER_RE.match(line)
        if m and not line.lstrip().startswith("%param"):
            current = m.group(1)
            comps[current] = []
            if raw.startswith("ENTRY"):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None and "=" in line:
            comps[current].append(line)
    return comps, entry


def parse_replica_groups(line: str, n_devices: int):
    """(group_form, groups, group_size, n_groups) for one collective.

    ``groups`` is the explicit list-of-lists when the HLO prints the
    brace form; iota form (``[G,S]<=[...]``) resolves sizes but not
    membership (participants are still 0..G*S-1); collective-permute's
    source_target_pairs count as size-2 "pairs"; no annotation means one
    group over all devices.
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, size = int(m.group(1)), int(m.group(2))
        return "iota", None, size, n_groups
    m = _GROUPS_FULL_RE.search(line)
    if m:
        groups = [[int(d) for d in g.split(",") if d.strip()]
                  for g in re.findall(r"\{([0-9, ]*)\}", m.group(1))]
        size = max((len(g) for g in groups), default=1)
        return "list", groups, max(1, size), len(groups)
    if "source_target_pairs=" in line:
        return "pairs", None, 2, 1
    return "default", None, n_devices, 1


def _group_size(line: str, n_devices: int) -> int:
    return parse_replica_groups(line, n_devices)[2]


def _wire_bytes(kind: str, out_b: float, S: int) -> float:
    if kind == "all-gather":
        return out_b * (S - 1) / max(S, 1)
    if kind == "all-reduce":
        return 2 * out_b * (S - 1) / max(S, 1)
    if kind == "reduce-scatter":
        return out_b * (S - 1)
    if kind == "all-to-all":
        return out_b * (S - 1) / max(S, 1)
    return float(out_b)  # collective-permute


def analyze(hlo: str, n_devices: int) -> HloCost:
    comps, entry = _split_computations(hlo)
    mnp = _NUM_PARTITIONS_RE.search(hlo[:2000])
    num_partitions = int(mnp.group(1)) if mnp else 1

    # global name -> output type text (names are module-unique in printed HLO)
    shapes: Dict[str, str] = {}
    parsed: Dict[str, List[Instr]] = {}
    for cname, lines in comps.items():
        instrs = []
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, out_text, opcode = m.groups()
            shapes[name] = out_text
            instrs.append(Instr(name, out_text, opcode, line))
        parsed[cname] = instrs

    def operand_names(line: str) -> List[str]:
        # operands live between the opcode '(' and its matching ')'
        start = line.find("(", line.find("=") + 1)
        depth, end = 0, len(line)
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPERAND_RE.findall(line[start:end])

    def operand_bytes(line: str) -> int:
        total = 0
        for name in operand_names(line):
            if name in shapes:
                total += _parse_shape(shapes[name])[1]
        return total

    # computation roots (last instruction with ROOT marker) + fused set
    roots: Dict[str, Instr] = {}
    for cname, instrs in parsed.items():
        for ins in instrs:
            if "ROOT" in ins.line:
                roots[cname] = ins
    fused: set = set()
    for cname, lines in comps.items():
        for line in lines:
            m = _DEF_RE.match(line)
            if m and m.group(3) == "fusion":
                mc = _CALLS_RE.search(line)
                if mc:
                    fused.add(mc.group(1))

    # per-computation local cost + call edges
    costs: Dict[str, CompCost] = {}
    edges: Dict[str, List[Tuple[str, float, bool]]] = {}  # caller -> (callee, mult, is_while)
    trip_counts: List[int] = []
    n_while = 0
    instr_recs: Dict[str, list] = {}
    coll_recs: Dict[str, List[Collective]] = {}
    for cname, instrs in parsed.items():
        cc = CompCost()
        edges[cname] = []
        recs = instr_recs.setdefault(cname, [])

        def process(ins, cc=None, edges_c=None):
            # returns (flops, bytes, wire, kind) for this instruction and
            # appends call edges; kind is the collective kind or None.
            op = ins.opcode
            line = ins.line
            if op == "while":
                mt = _TRIP_RE.search(line)
                trip = int(mt.group(1)) if mt else 1
                trip_counts.append(trip)
                if not mt:
                    cc.unknown_trip += 1
                mb = _BODY_RE.search(line)
                mc = _COND_RE.search(line)
                if mb:
                    edges_c.append((mb.group(1), float(trip), True))
                if mc:
                    edges_c.append((mc.group(1), float(trip + 1), True))
                return (0.0, 0.0, 0.0, "while")
            if op in ("conditional",):
                for mm in _BRANCHES_RE.finditer(line):
                    for b in mm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b:
                            edges_c.append((b, 1.0, False))
                for mm in _TRUE_FALSE_RE.finditer(line):
                    edges_c.append((mm.group(1), 1.0, False))
                return (0.0, 0.0, 0.0, None)
            if op in ("fusion", "call", "async-start"):
                mcalls = _CALLS_RE.search(line)
                callee = mcalls.group(1) if mcalls else None
                if callee:
                    edges_c.append((callee, 1.0, False))
                if op == "fusion":
                    root = roots.get(callee)
                    if root is not None and root.opcode == "dynamic-update-slice":
                        rops = operand_names(root.line)
                        upd = (_parse_shape(shapes[rops[1]])[1]
                               if len(rops) >= 2 and rops[1] in shapes
                               else root.out_bytes)
                        return (0.0, 2 * upd, 0.0, None)
                    return (0.0, ins.out_bytes + operand_bytes(line), 0.0, None)
                return (0.0, 0.0, 0.0, None)

            if op == "copy-start":
                # async copy pair: the START moves the buffer (one read +
                # one write of the operand); its tuple output aliases the
                # same bytes and copy-done just retires the handle, so
                # counting out_bytes here would triple-count the transfer
                return (0.0, 2.0 * operand_bytes(line), 0.0, None)
            base_kind = op[:-6] if op.endswith("-start") else op
            if base_kind in _COLL_KINDS:
                out_b = ins.out_bytes
                if op.endswith("-start"):
                    out_b = out_b // 2 if base_kind != "all-reduce" else out_b
                S = _group_size(line, n_devices)
                w = _wire_bytes(base_kind, out_b, S)
                return (0.0, 2 * out_b, w, base_kind)
            if op.endswith("-done"):
                return (0.0, 0.0, 0.0, None)
            if op in _FREE_OPS:
                if op == "custom-call":
                    return (0.0, ins.out_bytes + operand_bytes(line), 0.0, None)
                return (0.0, 0.0, 0.0, None)
            if op == "dot":
                mc_ = _CONTRACT_RE.search(line)
                contract = 1
                ops_ = operand_names(line)
                if mc_ and ops_ and ops_[0] in shapes:
                    dims = [int(x) for x in mc_.group(1).split(",") if x.strip()]
                    toks = _SHAPE_TOK_RE.findall(shapes[ops_[0]])
                    if toks:
                        lhs_dims = [int(d) for d in toks[0][1].split(",") if d.strip()]
                        for d in dims:
                            if d < len(lhs_dims):
                                contract *= lhs_dims[d]
                return (2.0 * ins.out_elems * contract,
                        ins.out_bytes + operand_bytes(line), 0.0, None)
            if op == "convolution":
                ops_ = operand_names(line)
                per_out = 1.0
                if len(ops_) >= 2 and ops_[1] in shapes:
                    toks = _SHAPE_TOK_RE.findall(shapes[ops_[1]])
                    if toks:
                        kprod = 1
                        for d in toks[0][1].split(","):
                            if d.strip():
                                kprod *= int(d)
                        per_out = kprod / max(1, _last_feature_dim(ins.out_text))
                return (2.0 * ins.out_elems * per_out,
                        ins.out_bytes + operand_bytes(line), 0.0, None)
            if op == "dynamic-update-slice":
                ops_ = operand_names(line)
                upd = (_parse_shape(shapes[ops_[1]])[1]
                       if len(ops_) >= 2 and ops_[1] in shapes else ins.out_bytes)
                return (0.0, 2 * upd, 0.0, None)
            if op == "dynamic-slice":
                return (0.0, 2 * ins.out_bytes, 0.0, None)
            if op == "sort":
                return (ins.out_elems * 8,
                        2 * operand_bytes(line) + 2 * ins.out_bytes, 0.0, None)
            # generic compute op (reduce, elementwise, copy, ...)
            return (float(ins.out_elems),
                    ins.out_bytes + operand_bytes(line), 0.0, None)

        for ins in instrs:
            if ins.opcode == "while":
                n_while += 1
            # reducer/comparator computations (reduce, sort, scatter,
            # select-and-scatter, all-reduce) hang off to_apply= — follow
            # them so they are reachable, not misreported as dead code
            mta = _TO_APPLY_RE.search(ins.line)
            if mta:
                edges[cname].append((mta.group(1), 1.0, False))
            # custom-calls (TopK, ...) carry their comparator/helper
            # computations in called_computations={...}
            mcc = _CALLED_COMPS_RE.search(ins.line)
            if mcc:
                for callee in mcc.group(1).split(","):
                    callee = callee.strip().lstrip("%")
                    if callee:
                        edges[cname].append((callee, 1.0, False))
            fl, by, wi, kind = process(ins, cc=cc, edges_c=edges[cname])
            cc.flops += fl
            cc.bytes += by
            cc.wire_bytes += wi
            if kind in _COLL_KINDS:
                cc.coll_by_kind[kind] += wi
                out_b = ins.out_bytes
                if ins.opcode.endswith("-start") and kind != "all-reduce":
                    out_b //= 2
                form, groups, size, n_groups = parse_replica_groups(
                    ins.line, n_devices)
                coll_recs.setdefault(cname, []).append(Collective(
                    name=ins.name, kind=kind, out_bytes=out_b,
                    group_size=size, n_groups=n_groups, groups=groups,
                    group_form=form, wire_bytes=wi, mult=1.0,
                    line=ins.line.strip()[:200]))
            if by > 1e6 or wi > 1e6:
                recs.append((by, wi, ins.line.strip()[:160]))
        costs[cname] = cc

    # propagate multipliers from entry
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry:
        mult[entry] = 1.0
    order = _topo_order(edges, entry)
    for cname in order:
        for callee, m, _ in edges.get(cname, []):
            if callee in mult:
                mult[callee] += mult[cname] * m

    total = HloCost(0.0, 0.0, 0.0, {k: 0.0 for k in _COLL_KINDS},
                    n_while, 0, trip_counts, num_partitions=num_partitions)
    for cname, cc in costs.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 and cname != entry:
            m = 0.0  # unreachable (dead computation)
        total.flops += m * cc.flops
        # fused computations: the fusion wrapper accounts boundary bytes;
        # inner instructions contribute flops only.
        if cname not in fused:
            total.bytes += m * cc.bytes
        total.wire_bytes += m * cc.wire_bytes
        total.unknown_trip_whiles += cc.unknown_trip
        for k in _COLL_KINDS:
            total.coll_by_kind[k] += m * cc.coll_by_kind[k]

    top_b, top_w = [], []
    for cname, recs in instr_recs.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        skip_bytes = cname in fused
        for by, wi, line in recs:
            if by and not skip_bytes:
                top_b.append((m * by, f"x{m:g} {line}"))
            if wi:
                top_w.append((m * wi, f"x{m:g} {line}"))
    total.top_bytes = sorted(top_b, reverse=True)[:20]
    total.top_wire = sorted(top_w, reverse=True)[:20]
    total.dead_computations = sorted(
        c for c in comps if mult.get(c, 0.0) == 0.0 and c != entry)
    colls: List[Collective] = []
    for cname, crs in coll_recs.items():
        m = mult.get(cname, 0.0)
        for rec in crs:
            colls.append(dataclasses.replace(rec, mult=m))
    total.collectives = sorted(colls, key=lambda r: r.mult * r.wire_bytes,
                               reverse=True)
    return total


def _last_feature_dim(out_text: str) -> int:
    toks = _SHAPE_TOK_RE.findall(out_text)
    if not toks:
        return 1
    dims = [int(d) for d in toks[0][1].split(",") if d.strip()]
    return dims[-1] if dims else 1


def _topo_order(edges: Dict[str, List[Tuple[str, float, bool]]],
                entry: str) -> List[str]:
    """DFS topological order from entry (call graphs are acyclic)."""
    seen: Dict[str, int] = {}
    order: List[str] = []

    def visit(c: str):
        if seen.get(c):
            return
        seen[c] = 1
        for callee, _, _ in edges.get(c, []):
            visit(callee)
        order.append(c)

    if entry:
        visit(c=entry)
    for c in edges:
        visit(c)
    order.reverse()
    return order


def main(argv: Optional[List[str]] = None) -> None:
    """CLI: trip-count-aware cost summary of a saved HLO module.

        python -m repro.launch.hlo_analysis module.hlo [--n-devices N]
                                            [--top K] [--json]

    Prints the roofline totals, the while-loop census (unknown trip
    counts under-report cost — the `unknown-trip-count` lint rule), the
    top byte- and wire-heaviest instruction lines, any computations
    unreachable from the entry, and — for sharded modules — the
    per-collective wire-byte table (kind, payload, replica groups,
    trip-count multiplier) plus the mesh/replica-group summary the SPMD
    contract rules reason over (``top_wire`` alone only surfaces
    megabyte-scale movers, which tiny per-round psums never are).
    """
    import argparse
    import json as _json

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("hlo", help="path to an HLO module text dump, or - for stdin")
    ap.add_argument("--n-devices", type=int, default=1)
    ap.add_argument("--top", type=int, default=5,
                    help="how many top_bytes/top_wire lines to print")
    ap.add_argument("--json", action="store_true",
                    help="emit the full record as JSON instead of text")
    args = ap.parse_args(argv)

    if args.hlo == "-":
        import sys
        text = sys.stdin.read()
    else:
        with open(args.hlo) as f:
            text = f.read()
    n_dev = args.n_devices
    c = analyze(text, n_devices=n_dev)
    if n_dev == 1 and c.num_partitions > 1:
        # sharded module: price collectives over its own partition count
        n_dev = c.num_partitions
        c = analyze(text, n_devices=n_dev)

    if args.json:
        print(_json.dumps({
            "flops": c.flops, "bytes": c.bytes, "wire_bytes": c.wire_bytes,
            "coll_by_kind": c.coll_by_kind, "n_while": c.n_while,
            "unknown_trip_whiles": c.unknown_trip_whiles,
            "trip_counts": c.trip_counts,
            "top_bytes": c.top_bytes[:args.top] if c.top_bytes else [],
            "top_wire": c.top_wire[:args.top] if c.top_wire else [],
            "dead_computations": c.dead_computations or [],
            "num_partitions": c.num_partitions,
            "collectives": [r.to_dict() for r in c.collectives or []],
        }, indent=1))
        return

    print(f"flops      {c.flops:.4g}")
    print(f"bytes      {c.bytes:.4g}")
    print(f"wire_bytes {c.wire_bytes:.4g}")
    print(f"while loops: {c.n_while} "
          f"(unknown trip count: {c.unknown_trip_whiles}; "
          f"trip_counts={c.trip_counts[:16]})")
    if c.unknown_trip_whiles:
        print("  WARNING: unknown-trip bodies are multiplied by 1 — "
              "totals under-report cost")
    for label, rows in (("top_bytes", c.top_bytes), ("top_wire", c.top_wire)):
        print(f"{label}:")
        for val, line in (rows or [])[:args.top]:
            print(f"  {val:.4g}  {line[:140]}")
    if c.dead_computations:
        print(f"dead computations ({len(c.dead_computations)}): "
              f"{c.dead_computations[:8]}")

    colls = c.collectives or []
    if colls:
        print(f"collectives ({len(colls)} instrs, "
              f"num_partitions={c.num_partitions}):")
        print(f"  {'kind':<19}{'payload_B':>10}{'groups':>12}"
              f"{'wire_B/dev':>12}{'mult':>7}  name")
        for r in colls[:max(args.top, 8)]:
            g = f"{r.n_groups}x{r.group_size}"
            print(f"  {r.kind:<19}{r.out_bytes:>10}{g:>12}"
                  f"{r.wire_bytes:>12.4g}{r.mult:>7g}  {r.name}")
        n_single = sum(1 for r in colls if r.group_size <= 1)
        cover = [r.covers_mesh(n_dev) for r in colls]
        n_partial = sum(1 for x in cover if x is False)
        n_unknown = sum(1 for x in cover if x is None)
        wire = sum(r.mult * r.wire_bytes for r in colls)
        print(f"  replica-group summary: "
              f"{n_single} singleton-group, {n_partial} partial-mesh, "
              f"{n_unknown} undecidable; "
              f"collective wire (xmult) = {wire:.4g} B/device")


if __name__ == "__main__":
    main()
