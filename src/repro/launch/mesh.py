"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module does not touch jax device state.  The dry-run launcher forces 512
host-platform devices before any jax import; smoke tests and benchmarks
see the 1 real CPU device and use make_test_mesh.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(devices)} — "
            "launch via repro.launch.dryrun (XLA_FLAGS host device count)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             devices=jax.devices()[: pod * data * model])
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
