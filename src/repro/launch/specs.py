"""Dry-run spec assembly: per (arch x shape) abstract inputs + shardings.

All state is jax.ShapeDtypeStruct (via eval_shape) — nothing allocates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig, InputShape
from repro.data import specs as dsp
from repro.distributed.robust_allreduce import RobustAggConfig
from repro.core.wfagg import WFAggConfig
from repro.models import model as M
from repro.train import serve as sv
from repro.train import trainer as tr

SLIDING_WINDOW_LONG = 8192


def arch_variant(cfg: ArchConfig, shape: InputShape) -> Optional[ArchConfig]:
    """Per-shape config adjustments.  Returns None when the (arch, shape)
    cell is skipped (documented in DESIGN.md Section 6)."""
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return None  # seamless: enc-dec 500k-token target side — skipped
        if cfg.family in ("ssm", "hybrid"):
            return cfg  # natively sub-quadratic
        # dense/moe/vlm: explicitly-flagged sliding-window variant
        return dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


def train_config(cfg: ArchConfig, multi_pod: bool,
                 layout: str = "stacked") -> tr.TrainConfig:
    """Mode selection (DESIGN.md Section 3): robust-dp WFAgg everywhere
    except arctic-480b, whose K full gradient candidates cannot coexist in
    pod HBM -> gspmd mean (single pod) documented as the technique's
    materialization wall.

    layout="flat" is the paper-shaped baseline (ravel + streamed chunks);
    layout="stacked" is the sharded-gradient fast path (EXPERIMENTS.md
    Section Perf) — gradients stay TP-sharded through aggregation and the
    temporal filter is exact."""
    if cfg.param_count() > 100e9:
        return tr.TrainConfig(mode="gspmd", agg=RobustAggConfig(method="mean"),
                              multi_pod=multi_pod, donate=False)
    use_temporal = cfg.param_count() < 40e9 or layout == "stacked"
    return tr.TrainConfig(
        mode="robust_dp",
        agg=RobustAggConfig(method="wfagg", layout=layout,
                            wfagg=WFAggConfig(f=2, use_temporal=use_temporal)),
        multi_pod=multi_pod,
        donate=False,
        # FSDP the train state for multi-billion-param archs (stacked only)
        fsdp_params=(layout == "stacked" and cfg.param_count() > 2e9),
        # microbatching measured NO temp-memory reduction in the dry-run
        # accounting (EXPERIMENTS.md Section Perf, pair C iteration 3 —
        # refuted); available via TrainConfig.microbatches but not
        # auto-enabled.
        microbatches=1,
    )


def build_dryrun(cfg: ArchConfig, shape: InputShape, mesh: Mesh, multi_pod: bool,
                 layout: str = "flat"):
    """Returns (jitted_fn, example_args (abstract, sharded)) for lowering."""
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        tc = train_config(cfg, multi_pod, layout=layout)
        state_shape = tr.init_train_state(cfg, tc, key, mesh, abstract=True)
        state_sh = tr.state_shardings(cfg, tc, mesh, state_shape)
        batch_shape = dsp.train_specs(cfg, shape)
        batch_sh = tr.batch_shardings(tc, mesh, batch_shape)
        step = tr.build_train_step(cfg, tc, mesh)
        args = (
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                         state_shape, state_sh),
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                         batch_shape, batch_sh),
        )
        return step, args, {"mode": tc.mode, "agg": tc.agg.method}

    sc = sv.ServeConfig(multi_pod=multi_pod)
    params_shape = jax.eval_shape(lambda: M.init_params(cfg, key))
    if shape.kind == "prefill":
        pspecs, _ = sv.serve_shardings(cfg, sc, mesh, params_shape, {})
        batch_shape = dsp.train_specs(cfg, shape)
        batch_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, jax.sharding.PartitionSpec()), batch_shape)
        from repro.distributed import sharding as shd
        bsp = shd.batch_specs(batch_shape, data_axes=sc.data_axes(), mesh=mesh)
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), bsp)
        fn = sv.build_prefill(cfg, sc, mesh)
        args = (
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                         params_shape, pspecs),
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                         batch_shape, batch_sh),
        )
        return fn, args, {"mode": "prefill"}

    # decode
    cache_shape = sv.cache_shapes(cfg, shape)
    pspecs, cspecs = sv.serve_shardings(cfg, sc, mesh, params_shape, cache_shape)
    tok_shape = dsp.decode_token_specs(cfg, shape)
    from repro.distributed import sharding as shd
    tok_sp = shd.batch_specs(tok_shape, data_axes=sc.data_axes(), mesh=mesh)
    tok_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), tok_sp)
    fn = sv.build_decode_step(cfg, sc, mesh)
    args = (
        jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                     params_shape, pspecs),
        jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                     cache_shape, cspecs),
        jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                     tok_shape, tok_sh),
    )
    return fn, args, {"mode": "decode"}
