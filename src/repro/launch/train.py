"""Production training launcher.

Selects an architecture config (--arch), builds the mesh, the sharded
train state and the robust-DP (or gspmd) train step, feeds the synthetic
token pipeline, and runs with periodic logging + checkpointing.

On real hardware this is the per-host entry point (jax.distributed
initialization is the runner's job); on CPU it runs end-to-end with
however many devices exist — force more with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a real
candidate axis (the robust aggregation needs K > 1 to be meaningful).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.wfagg import WFAggConfig
from repro.data.synthetic import TokenStream
from repro.distributed.robust_allreduce import RobustAggConfig
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.train import checkpoint as ckpt
from repro.train import trainer as tr


def build_everything(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, head_dim=args.d_model // cfg.n_heads,
            d_ff=args.d_ff or 4 * args.d_model)
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)

    n_dev = jax.device_count()
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        model = max(1, min(args.model_parallel, n_dev))
        mesh = make_test_mesh(data=n_dev // model, model=model)

    tc = tr.TrainConfig(
        mode=args.mode,
        agg=RobustAggConfig(
            method=args.agg,
            layout=args.layout,
            wfagg=WFAggConfig(f=args.f, use_temporal=not args.no_temporal,
                              transient=args.transient, window=args.window),
            chunk_size=args.chunk_size,
            sketch_dim=args.sketch_dim,
        ),
        lr=args.lr, warmup=args.warmup, total_steps=args.steps,
        attack=args.attack, n_malicious=args.n_malicious,
        multi_pod=args.multi_pod, donate=False,
    )
    return cfg, mesh, tc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the same family")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--mode", default="robust_dp", choices=("robust_dp", "gspmd"))
    ap.add_argument("--agg", default="wfagg",
                    choices=("mean", "median", "trimmed_mean", "krum",
                             "multi_krum", "clustering", "wfagg", "alt_wfagg"))
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--no-temporal", action="store_true")
    ap.add_argument("--transient", type=int, default=3)
    ap.add_argument("--window", type=int, default=3)
    ap.add_argument("--layout", default="stacked", choices=("flat", "stacked"),
                    help="robust-agg gradient layout (stacked = sharded fast path)")
    ap.add_argument("--chunk-size", type=int, default=1 << 22)
    ap.add_argument("--sketch-dim", type=int, default=4096)
    ap.add_argument("--attack", default="none",
                    choices=("none", "noise", "sign_flip", "label_flip",
                             "ipm_0.5", "ipm_100", "alie"))
    ap.add_argument("--n-malicious", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg, mesh, tc = build_everything(args)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"devices={jax.device_count()} mesh={dict(mesh.shape)} "
          f"mode={tc.mode} agg={tc.agg.method} attack={tc.attack} "
          f"malicious={tc.n_malicious}/{mesh.shape['data']}")

    state = tr.init_train_state(cfg, tc, jax.random.PRNGKey(0), mesh)
    step_fn = tr.build_train_step(cfg, tc, mesh)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                         batch_size=args.global_batch)

    with mesh:
        t0 = time.time()
        for i in range(args.steps):
            state, m = step_fn(state, stream.batch(i))
            if (i + 1) % args.log_every == 0 or i == 0:
                loss = float(m["loss"])
                acc = int(m["n_accepted"])
                dt = time.time() - t0
                print(f"step {i + 1:5d}  loss {loss:8.4f}  "
                      f"grad_norm {float(m['grad_norm']):9.3e}  "
                      f"accepted {acc}  {dt / (i + 1):6.2f}s/step")
            if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                ckpt.save_checkpoint(args.ckpt_dir, f"step_{i + 1}",
                                     jax.device_get(state.params),
                                     {"step": i + 1, "loss": float(m["loss"])})
    print(f"done: {args.steps} steps, final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
