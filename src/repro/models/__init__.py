"""Model zoo: unified config-driven architectures + the paper's CNN."""
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)
from repro.models.lenet import (
    init_lenet,
    init_mlp_classifier,
    lenet_fwd,
    mlp_classifier_fwd,
)
