"""Shared neural-net layers: norms, RoPE, GQA/MLA attention, SwiGLU, MoE.

Functional style: ``init_*`` builds a param dict, ``*_fwd`` applies it.
All forward functions accept an optional KV-cache for decode and annotate
activations with logical sharding axes (no-ops outside a mesh context).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.logical import shard

Array = jax.Array
Params = Dict[str, Any]


def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    return (xf * inv).astype(x.dtype) * scale.astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    return _rmsnorm(x, scale, eps), (x, scale)


def _rmsnorm_bwd(eps, res, ct):
    """Exact gradient, computed in f32, RETURNED in the activation dtype.

    Letting autodiff differentiate the f32-upcast statistic makes the
    f32 cotangent leak into the residual-gradient stream (every backward
    TP all-reduce and elementwise chain doubles — EXPERIMENTS.md Section
    Perf); casting d_x back to x.dtype keeps the stream bf16 while the
    norm math itself stays f32-exact.
    """
    x, scale = res
    xf = x.astype(jnp.float32)
    ctf = ct.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    D = x.shape[-1]
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    xhat = xf * inv
    d_scale = jnp.sum(ctf * xhat, axis=tuple(range(ct.ndim - 1)))
    g = ctf * sf
    d_x = inv * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
    return d_x.astype(x.dtype), d_scale.astype(scale.dtype)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def norm_fwd(cfg: ArchConfig, p: Params, x: Array) -> Array:
    """Reduction statistics in f32, application + cotangents in the
    activation dtype (see _rmsnorm_bwd)."""
    dt = x.dtype
    if cfg.norm == "layernorm":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + cfg.norm_eps)
        return ((xf - mu) * inv).astype(dt) * p["scale"].astype(dt) \
            + p["bias"].astype(dt)
    return _rmsnorm(x, p["scale"], float(cfg.norm_eps))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding on the last dim.  x: (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + optional ring-buffer sliding-window cache)
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key: Array, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    hd, H, Hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
        p = {
            "wq": _dense_init(ks[0], (d, H * (hd + rd))),
            "w_dkv": _dense_init(ks[1], (d, r)),
            "w_kr": _dense_init(ks[2], (d, rd)),
            "w_uk": _dense_init(ks[3], (r, H * hd)),
            "w_uv": _dense_init(ks[4], (r, H * hd)),
            "wo": _dense_init(ks[5], (H * hd, d)),
            "kv_norm": jnp.ones((r,), jnp.float32),
        }
        return p
    p = {
        "wq": _dense_init(ks[0], (d, H * hd)),
        "wk": _dense_init(ks[1], (d, Hkv * hd)),
        "wv": _dense_init(ks[2], (d, Hkv * hd)),
        "wo": _dense_init(ks[3], (H * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * hd,), jnp.float32)
    return p


def init_kv_cache(cfg: ArchConfig, batch: int, capacity: int, dtype) -> Params:
    hd, Hkv = cfg.head_dim_, cfg.n_kv_heads
    if cfg.use_mla:
        return {
            "ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, Hkv, capacity, hd), dtype),
        "v": jnp.zeros((batch, Hkv, capacity, hd), dtype),
    }


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array], scale: float) -> Array:
    """q (B,H,Sq,hd), k/v (B,H,Sk,hd) -> (B,H,Sq,hd)."""
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


# KV lengths > this use the chunked online-softmax path (never materializes
# the (Sq, Sk) score matrix — EXPERIMENTS.md Section Perf iteration).  At
# 4k the dense path + remat is cheaper (chunk recompute adds ~12% HBM
# traffic for no capacity win); at 32k the dense scores cannot fit.
SDPA_CHUNK_THRESHOLD = 8192
SDPA_CHUNK = 1024


def _use_flash_kernel() -> bool:
    """Opt-in switch for the Pallas flash-attention kernel (kernels/
    flash_attn).  Default off: on this CPU container interpret-mode
    execution of real sizes is impractical, and the chunked-scan XLA path
    is the measured fallback; on a TPU pod set REPRO_FLASH_KERNEL=1."""
    import os
    return os.environ.get("REPRO_FLASH_KERNEL", "0") == "1"


def _sdpa_chunked(q: Array, k: Array, v: Array, scale: float,
                  mask_chunk_fn, chunk: int = SDPA_CHUNK) -> Array:
    """Flash-style attention: lax.scan over KV chunks with a running
    (max, denominator, accumulator).  ``mask_chunk_fn(offset, C)`` returns
    the boolean mask block (broadcastable to (B, 1|H, Sq, C)) for KV slots
    [offset, offset+C) — masks are built per chunk from positions, so the
    dense (Sq, Sk) mask never exists either.  The scan body is
    jax.checkpoint'ed: backward recomputes each chunk's scores instead of
    storing softmax weights (peak memory O(Sq x chunk), not O(Sq x Sk)).
    """
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    nc = -(-Sk // chunk)
    pad = nc * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ks = jnp.moveaxis(k.reshape(B, H, nc, chunk, hd), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, H, nc, chunk, hd), 2, 0)

    def body(carry, xs):
        m, l, acc = carry
        ci, kc, vc = xs
        off = ci * chunk
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kc).astype(jnp.float32) * scale
        valid = (off + jnp.arange(chunk)) < Sk            # strip padding
        msk = valid[None, None, None, :]
        if mask_chunk_fn is not None:
            msk = msk & mask_chunk_fn(off, chunk)
        s = jnp.where(msk, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, H, Sq), -1e30, jnp.float32),
        jnp.zeros((B, H, Sq), jnp.float32),
        jnp.zeros((B, H, Sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), init, (jnp.arange(nc), ks, vs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _repeat_kv(x: Array, n_rep: int) -> Array:
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=1)


def attention_fwd(
    cfg: ArchConfig,
    p: Params,
    x: Array,
    positions: Array,
    *,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_index: Optional[Array] = None,
    kv_source: Optional[Array] = None,
    use_rope: bool = True,
) -> Tuple[Array, Optional[Params]]:
    """GQA attention.

    Modes:
      train/prefill: cache=None -> full (causal) self-attention.
      decode:        cache given -> append x's K/V at ``cache_index`` (ring
                     buffer modulo capacity, i.e. sliding window when the
                     capacity < total positions) and attend to the cache.
      cross:         kv_source given -> K/V from kv_source, no cache write.
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    hd, H, Hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    src = kv_source if kv_source is not None else x

    q = x @ p["wq"].astype(dt)
    k = src @ p["wk"].astype(dt)
    v = src @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, src.shape[1], Hkv, hd)
    v = v.reshape(B, src.shape[1], Hkv, hd)
    q = shard(q, "batch", "seq", "heads")
    k = shard(k, "batch", "seq", "kv_heads")
    v = shard(v, "batch", "seq", "kv_heads")

    if use_rope and kv_source is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q = q.transpose(0, 2, 1, 3)  # (B,H,S,hd)
    k = k.transpose(0, 2, 1, 3)  # (B,Hkv,Sk,hd)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    mask = None
    mask_chunk_fn = None
    if cache is not None:
        cap = cache["k"].shape[2]
        slot = jnp.mod(cache_index, cap)
        # dynamic_update_slice needs S contiguous writes; decode has S==1.
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(dt), cv.astype(dt)
        n_valid = jnp.minimum(cache_index + S, cap)
        # Before the ring buffer wraps, slot j holds absolute position j, so
        # prefill-with-cache (S > 1) still needs the causal constraint.  Once
        # wrapped, every valid slot is in the query's past by construction.
        qpos = cache_index + jnp.arange(S)
        no_wrap = (cache_index + S) <= cap

        def _cache_mask(off, C):
            slots_c = off + jnp.arange(C)
            valid = slots_c[None, None, None, :] < n_valid
            causal_c = jnp.where(no_wrap, slots_c[None, :] <= qpos[:, None], True)
            return valid & causal_c[None, None, :, :]

        mask_chunk_fn = _cache_mask
        mask = _cache_mask(0, cap)
    elif causal:
        def _causal_mask(off, C):
            kpos_c = jax.lax.dynamic_slice_in_dim(
                jnp.pad(positions, ((0, 0), (0, (-positions.shape[1]) % C))),
                off, C, axis=1)
            return (kpos_c[:, None, None, :] <= positions[:, None, :, None])

        mask_chunk_fn = _causal_mask
        mask = (positions[:, None, :] <= positions[:, :, None])[:, None, :, :]

    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    Hp = cfg.pad_heads_to
    if Hp and H < Hp:
        # head-padding (EXPERIMENTS.md Section Perf, arctic/llava): 56 query
        # heads do not divide a 16-way model axis, so every attention
        # activation would replicate across TP shards (involuntary
        # rematerialization).  Zero-pad the head axis AFTER GQA expansion —
        # padded heads produce zero outputs (v rows are zero) and are
        # sliced off before wo, so the math is exact at +Hp/H-1 compute.
        padw = ((0, 0), (0, Hp - H), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        q = shard(q, "batch", "heads", None, None)
        k = shard(k, "batch", "heads", None, None)
        v = shard(v, "batch", "heads", None, None)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # chunked path only when BOTH dims are large: for single-token decode
    # the dense (B,H,1,Sk) scores are tiny and the 512-iteration chunk scan
    # just adds loop overhead (zamba2 x long_500k regression, EXPERIMENTS).
    if k.shape[2] >= SDPA_CHUNK_THRESHOLD and q.shape[2] >= 128:
        if _use_flash_kernel() and cache is None and causal and kv_source is None:
            # Pallas flash kernel (kernels/flash_attn): TPU fast path for the
            # plain-causal train/prefill case; ring-buffer cache masks stay
            # on the chunked-scan path.
            from repro.kernels.flash_attn.ops import flash_attention
            out = flash_attention(q, k, v, float(1.0 / hd ** 0.5), causal=True,
                                  interpret=jax.default_backend() != "tpu")
        else:
            out = _sdpa_chunked(q, k, v, scale, mask_chunk_fn)
    else:
        out = _sdpa(q, k, v, mask, scale)
    if Hp and H < Hp:
        out = out[:, :H]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = out @ p["wo"].astype(dt)
    return shard(out, "batch", "seq", "embed"), new_cache


def mla_attention_fwd(
    cfg: ArchConfig,
    p: Params,
    x: Array,
    positions: Array,
    *,
    cache: Optional[Params] = None,
    cache_index: Optional[Array] = None,
) -> Tuple[Array, Optional[Params]]:
    """Multi-head Latent Attention (DeepSeek-V2).

    Train/prefill: materialize K/V from the compressed latent.
    Decode: cache only (c_kv, k_rope) — the paper's KV-compression win —
    and run the *absorbed* form: q is projected into the latent space so
    attention scores are inner products in r + rope_dim dims.
    """
    B, S, d = x.shape
    hd, H = cfg.head_dim_, cfg.n_heads
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    dt = x.dtype
    scale = 1.0 / jnp.sqrt(hd + rd).astype(jnp.float32)

    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ p["w_dkv"].astype(dt)  # (B,S,r)
    # RMS-normalize the latent (deepseek uses a norm on the compressed kv)
    ckv = ckv * jax.lax.rsqrt(jnp.mean(ckv.astype(jnp.float32) ** 2, -1, keepdims=True) + 1e-6).astype(dt)
    ckv = ckv * p["kv_norm"].astype(dt)
    krope = (x @ p["w_kr"].astype(dt)).reshape(B, S, 1, rd)
    krope = rope(krope, positions, cfg.rope_theta).reshape(B, S, rd)

    if cache is None:
        # materialized path
        k_nope = (ckv @ p["w_uk"].astype(dt)).reshape(B, S, H, hd)
        v = (ckv @ p["w_uv"].astype(dt)).reshape(B, S, H, hd)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, rd))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        qpos, kpos = positions[:, :, None], positions[:, None, :]
        mask = (kpos <= qpos)[:, None, :, :]
        out = _sdpa(
            qq.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), mask, scale
        )
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
        return (out @ p["wo"].astype(dt)), None

    # absorbed decode path
    cap = cache["ckv"].shape[1]
    slot = jnp.mod(cache_index, cap)
    cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, slot, 0))
    ckr = jax.lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype), (0, slot, 0))
    new_cache = {"ckv": cckv, "krope": ckr}
    n_valid = jnp.minimum(cache_index + S, cap)
    slots = jnp.arange(cap)
    qpos = cache_index + jnp.arange(S)
    no_wrap = (cache_index + S) <= cap
    causal_c = jnp.where(no_wrap, slots[None, :] <= qpos[:, None], True)  # (S,C)
    valid = (slots[None, None, None, :] < n_valid) & causal_c[None, None, :, :]

    w_uk = p["w_uk"].astype(dt).reshape(r, H, hd)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (B,S,H,r)
    scores = jnp.einsum("bshr,bcr->bhsc", q_eff, cckv.astype(dt)) + jnp.einsum(
        "bshr,bcr->bhsc", q_rope, ckr.astype(dt)
    )
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhsc,bcr->bshr", w, cckv.astype(dt))  # (B,S,H,r)
    w_uv = p["w_uv"].astype(dt).reshape(r, H, hd)
    out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv).reshape(B, S, H * hd)
    return (out @ p["wo"].astype(dt)), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key: Array, d: Optional[int] = None, ff: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, ff)),
        "w_up": _dense_init(k2, (d, ff)),
        "w_down": _dense_init(k3, (ff, d)),
    }


def mlp_fwd(p: Params, x: Array) -> Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    h = shard(h, "batch", "seq", "ff")
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MoE (scatter-based capacity dispatch, GShard-style but without the
# (T, E, C) one-hot dispatch tensor)
# ---------------------------------------------------------------------------

def init_moe(cfg: ArchConfig, key: Array) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), scale=0.02),
        "w_gate": _dense_init(ks[1], (E, d, ff)),
        "w_up": _dense_init(ks[2], (E, d, ff)),
        "w_down": _dense_init(ks[3], (E, ff, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], ff=cfg.n_shared_experts * ff)
    if cfg.moe_dense_residual:
        p["dense_residual"] = init_mlp(cfg, ks[4], ff=cfg.dense_residual_ff or ff)
    return p


def moe_fwd(cfg: ArchConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    """x (B, S, d) -> (out, aux_loss).

    GROUPED top-k capacity dispatch (GShard-style groups = batch rows):
    each batch row dispatches its S tokens into its own (E, C_row) buffer
    with C_row = ceil(S/E * k * capacity_factor).  The scatter/gather is
    LOCAL to the row, so the dispatch buffer shards as (batch->data,
    expert->model) with no cross-shard scatter — the global-buffer
    formulation made GSPMD replicate the (E, C, d) buffer per data group
    and all-reduce it (6.6 TB all-gather + 12.7 TB all-reduce per arctic
    step; EXPERIMENTS.md Section Perf).  Per-row capacity drops tokens on
    per-row imbalance, the standard GShard trade-off.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)      # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                            # (B,S,k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(dt)

    # load-balance auxiliary loss (Switch/GShard form)
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    capacity = max(1, int(cfg.capacity_factor * k * S / E))

    def dispatch_row(xr, er):
        # xr (S,d), er (S,k) -> per-row expert buffer (E,C,d) + addressing
        e_flat = er.T.reshape(-1)                                  # (k*S,) top-1 first
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_flat = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
        within = pos_flat < capacity
        pos_safe = jnp.where(within, pos_flat, capacity)           # OOB -> dropped
        x_rep = jnp.tile(xr, (k, 1))                               # (k*S, d)
        buf = jnp.zeros((E, capacity, d), dt)
        buf = buf.at[e_flat, pos_safe].add(
            x_rep * within[:, None].astype(dt), mode="drop")
        return buf, e_flat, pos_safe, within

    buf, e_flat, pos_safe, within = jax.vmap(dispatch_row)(x, idx)  # (B,E,C,d)
    buf = shard(buf, "batch", "expert", None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    h = shard(h, "batch", "expert", None, None)
    yb = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    yb = shard(yb, "batch", "expert", None, None)

    def combine_row(ybr, e_flat_r, pos_r, within_r, gate_r):
        y_rep = ybr.at[e_flat_r, pos_r].get(mode="fill", fill_value=0)  # (k*S,d)
        y_rep = y_rep * within_r[:, None].astype(dt)
        return (y_rep.reshape(k, S, d) * gate_r.T[:, :, None]).sum(axis=0)

    y = jax.vmap(combine_row)(yb, e_flat, pos_safe, within, gate)  # (B,S,d)

    out = y
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], x.reshape(B * S, d)).reshape(B, S, d)
    if cfg.moe_dense_residual:
        out = out + mlp_fwd(p["dense_residual"], x.reshape(B * S, d)).reshape(B, S, d)
    return out, aux


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(cfg: ArchConfig, key: Array) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"embed": 0.02 * jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size))
    return p


def embed_fwd(cfg: ArchConfig, p: Params, tokens: Array, dtype) -> Array:
    out = jnp.take(p["embed"].astype(dtype), tokens, axis=0)
    return shard(out, "batch", "seq", "embed")


def unembed_fwd(cfg: ArchConfig, p: Params, h: Array) -> Array:
    dt = h.dtype
    if cfg.tie_embeddings:
        logits = h @ p["embed"].astype(dt).T
    else:
        logits = h @ p["unembed"].astype(dt)
    return shard(logits, "batch", "seq", "vocab")
