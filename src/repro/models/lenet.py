"""The paper's local model: a LeNet-5-style CNN for 28x28 image
classification (paper Section V-A: '7 layers, including convolutional,
pooling, and fully connected').

Pure-JAX functional implementation used by the DFL engine (mode A):
small enough that 20 node replicas train concurrently on CPU.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]


def init_lenet(key: Array, num_classes: int = 10) -> Params:
    ks = jax.random.split(key, 5)
    def conv_init(k, shape):  # (kh, kw, cin, cout)
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)
    def dense_init(k, shape):
        return jax.random.normal(k, shape) * jnp.sqrt(2.0 / shape[0])
    return {
        "conv1": {"w": conv_init(ks[0], (5, 5, 1, 6)), "b": jnp.zeros((6,))},
        "conv2": {"w": conv_init(ks[1], (5, 5, 6, 16)), "b": jnp.zeros((16,))},
        "fc1": {"w": dense_init(ks[2], (16 * 4 * 4, 120)), "b": jnp.zeros((120,))},
        "fc2": {"w": dense_init(ks[3], (120, 84)), "b": jnp.zeros((84,))},
        "fc3": {"w": dense_init(ks[4], (84, num_classes)), "b": jnp.zeros((num_classes,))},
    }


def _conv(x: Array, w: Array, b: Array) -> Array:
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _maxpool2(x: Array) -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def lenet_fwd(params: Params, images: Array) -> Array:
    """images (B, 28, 28, 1) -> logits (B, C)."""
    h = jax.nn.relu(_conv(images, params["conv1"]["w"], params["conv1"]["b"]))  # 24x24x6
    h = _maxpool2(h)                                                            # 12x12x6
    h = jax.nn.relu(_conv(h, params["conv2"]["w"], params["conv2"]["b"]))       # 8x8x16
    h = _maxpool2(h)                                                            # 4x4x16
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["fc3"]["w"] + params["fc3"]["b"]


def init_mlp_classifier(key: Array, d_in: int = 784, width: int = 64, num_classes: int = 10) -> Params:
    """Smaller alternative local model for fast CPU experiments."""
    k1, k2 = jax.random.split(key)
    return {
        "fc1": {"w": jax.random.normal(k1, (d_in, width)) * jnp.sqrt(2.0 / d_in),
                "b": jnp.zeros((width,))},
        "fc2": {"w": jax.random.normal(k2, (width, num_classes)) * jnp.sqrt(2.0 / width),
                "b": jnp.zeros((num_classes,))},
    }


def mlp_classifier_fwd(params: Params, images: Array) -> Array:
    """images (B, 28, 28, 1) or (B, 784) -> logits."""
    h = images.reshape(images.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]
