"""Unified config-driven model: every assigned architecture family.

Families:
  dense / moe / vlm       decoder-only LM (GQA or MLA attention, dense or
                          MoE FFN, optional modal-embedding prefix)
  ssm                     Mamba-1 stack (attention-free)
  hybrid                  Mamba-2 stack with a shared transformer block
                          invoked every `shared_attn_every` layers (Zamba2)
  encdec / audio          encoder-decoder backbone (Seamless) consuming
                          stub frame embeddings on the encoder side

Layer stacks are scanned (params stacked on a leading L axis via
vmap(init)) so compile time stays bounded for 27-64 layer configs, with
optional remat around the scanned body.

Public API:
  init_params(cfg, key)                  -> params pytree
  forward(cfg, params, batch)            -> (logits, aux_loss)
  loss_fn(cfg, params, batch)            -> (loss, metrics)
  init_cache(cfg, batch, capacity, dtype)-> decode cache pytree
  decode_step(cfg, params, cache, batch) -> (logits, new_cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.logical import shard
from repro.models import layers as L
from repro.models import ssm as S

Array = jax.Array
Params = Dict[str, Any]

MODAL_EMBED_DIM = 1024  # stubbed ViT/conv frontend output width


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_block(cfg: ArchConfig, key: Array, kind: str, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p = {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, ks[0]),
        "ln2": L.init_norm(cfg, cfg.d_model),
    }
    if kind == "moe":
        p["ffn"] = L.init_moe(cfg, ks[1])
    else:
        p["ffn"] = L.init_mlp(cfg, ks[1])
    if cross:
        p["ln_x"] = L.init_norm(cfg, cfg.d_model)
        p["xattn"] = L.init_attention(cfg, ks[2])
    return p


def block_fwd(
    cfg: ArchConfig,
    p: Params,
    h: Array,
    positions: Array,
    *,
    kind: str,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_index=None,
    enc_out: Optional[Array] = None,
) -> Tuple[Array, Optional[Params], Array]:
    a_in = L.norm_fwd(cfg, p["ln1"], h)
    if cfg.use_mla:
        attn_out, new_cache = L.mla_attention_fwd(
            cfg, p["attn"], a_in, positions, cache=cache, cache_index=cache_index
        )
    else:
        attn_out, new_cache = L.attention_fwd(
            cfg, p["attn"], a_in, positions, causal=causal, cache=cache, cache_index=cache_index
        )
    h = h + attn_out
    if enc_out is not None:
        x_in = L.norm_fwd(cfg, p["ln_x"], h)
        x_out, _ = L.attention_fwd(
            cfg, p["xattn"], x_in, positions, causal=False, kv_source=enc_out, use_rope=False
        )
        h = h + x_out
    f_in = L.norm_fwd(cfg, p["ln2"], h)
    if kind == "moe":
        f_out, aux = L.moe_fwd(cfg, p["ffn"], f_in)
    else:
        f_out, aux = L.mlp_fwd(p["ffn"], f_in), jnp.zeros((), jnp.float32)
    return h + f_out, new_cache, aux


def init_mamba_block(cfg: ArchConfig, key: Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln": L.init_norm(cfg, cfg.d_model), "mixer": S.init_mamba(cfg, k1)}


def mamba_block_fwd(cfg: ArchConfig, p: Params, h: Array, state=None):
    m_in = L.norm_fwd(cfg, p["ln"], h)
    out, new_state = S.mamba_fwd(cfg, p["mixer"], m_in, state)
    return h + out, new_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(fn, key: Array, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key: Array) -> Params:
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"embedding": L.init_embedding(cfg, ks[0]), "final_norm": L.init_norm(cfg, cfg.d_model)}

    if cfg.family == "ssm":
        p["layers"] = _stack_init(lambda k: init_mamba_block(cfg, k), ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack_init(lambda k: init_mamba_block(cfg, k), ks[1], cfg.n_layers)
        k1, k2, k3 = jax.random.split(ks[2], 3)
        p["shared_attn"] = {
            "in_proj": L._dense_init(k1, (2 * cfg.d_model, cfg.d_model)),
            "block": init_block(cfg, k2, "dense"),
        }
    elif cfg.is_encoder_decoder:
        p["enc_in_proj"] = L._dense_init(ks[3], (cfg.d_model, cfg.d_model))
        p["enc_layers"] = _stack_init(
            lambda k: init_block(cfg, k, "dense"), ks[4], cfg.n_enc_layers
        )
        p["enc_norm"] = L.init_norm(cfg, cfg.d_model)
        p["layers"] = _stack_init(
            lambda k: init_block(cfg, k, "dense", cross=True), ks[1], cfg.n_layers
        )
    else:
        kind = "moe" if cfg.n_experts else "dense"
        n_prefix = cfg.first_dense_layers if cfg.n_experts else 0
        if n_prefix:
            p["prefix_layers"] = [
                init_block(cfg, k, "dense") for k in jax.random.split(ks[5], n_prefix)
            ]
        p["layers"] = _stack_init(
            lambda k: init_block(cfg, k, kind), ks[1], cfg.n_layers - n_prefix
        )
        if cfg.family == "vlm" or cfg.modality == "vision":
            p["projector"] = {
                "w1": L._dense_init(ks[6], (MODAL_EMBED_DIM, cfg.d_model)),
                "w2": L._dense_init(ks[7], (cfg.d_model, cfg.d_model)),
            }
    return jax.tree.map(lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, p)


# ---------------------------------------------------------------------------
# trunk helpers
# ---------------------------------------------------------------------------

def _scan_blocks(cfg, stacked, h, positions, kind, caches=None, cache_index=None, enc_out=None,
                 causal=True):
    """Scan h through stacked transformer blocks; threads optional caches."""

    def body(carry, xs):
        h = carry
        lp, cache = xs
        h2, new_cache, aux = block_fwd(
            cfg, lp, h, positions, kind=kind, causal=causal, cache=cache,
            cache_index=cache_index, enc_out=enc_out,
        )
        return h2, (new_cache, aux)

    fn = jax.checkpoint(body) if (cfg.remat and caches is None) else body
    xs = (stacked, caches)
    h, (new_caches, auxs) = jax.lax.scan(fn, h, xs)
    return h, new_caches, auxs.sum()


def _scan_mamba(cfg, stacked, h, states=None):
    def body(carry, xs):
        lp, st = xs
        h2, new_st = mamba_block_fwd(cfg, lp, carry, st)
        return h2, new_st

    fn = jax.checkpoint(body) if (cfg.remat and states is None) else body
    h, new_states = jax.lax.scan(fn, h, (stacked, states))
    return h, new_states


def _shared_attn_apply(cfg, p_sh, h, h0, positions, cache=None, cache_index=None):
    """Zamba-style shared block: concat(h, h0) -> proj -> transformer block."""
    x = jnp.concatenate([h, h0], axis=-1) @ p_sh["in_proj"].astype(h.dtype)
    out, new_cache, _ = block_fwd(
        cfg, p_sh["block"], x, positions, kind="dense", cache=cache, cache_index=cache_index
    )
    return h + out, new_cache


def _hybrid_trunk(cfg, params, h, positions, caches=None, cache_index=None):
    """Scan over G groups: shared attention + `every` mamba layers."""
    Lc, every = cfg.n_layers, cfg.shared_attn_every
    assert Lc % every == 0, (Lc, every)
    G = Lc // every
    grouped = jax.tree.map(lambda x: x.reshape((G, every) + x.shape[1:]), params["layers"])
    h0 = h
    p_sh = params["shared_attn"]

    def body(carry, xs):
        h = carry
        gp, g_caches = xs
        attn_cache = g_caches["attn"] if g_caches is not None else None
        m_states = g_caches["mamba"] if g_caches is not None else None
        h, new_attn = _shared_attn_apply(cfg, p_sh, h, h0, positions, attn_cache, cache_index)
        h, new_m = _scan_mamba(cfg, gp, h, m_states)
        return h, {"attn": new_attn, "mamba": new_m}

    fn = jax.checkpoint(body) if (cfg.remat and caches is None) else body
    h, new_caches = jax.lax.scan(fn, h, (grouped, caches))
    return h, new_caches


# ---------------------------------------------------------------------------
# forward (train / single-shot)
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    dt = _dtype(cfg)
    aux = jnp.zeros((), jnp.float32)

    if cfg.is_encoder_decoder:
        frames = batch["frames"].astype(dt)
        enc_h = frames @ params["enc_in_proj"].astype(dt)
        enc_pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
        enc_h, _, _ = _scan_blocks(cfg, params["enc_layers"], enc_h, enc_pos, "dense", causal=False)
        enc_out = L.norm_fwd(cfg, params["enc_norm"], enc_h)

        tokens = batch["tokens"]
        h = L.embed_fwd(cfg, params["embedding"], tokens, dt)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        h, _, _ = _scan_blocks(cfg, params["layers"], h, pos, "dense", enc_out=enc_out)
    else:
        tokens = batch["tokens"]
        h = L.embed_fwd(cfg, params["embedding"], tokens, dt)
        if "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dt)
            proj = params["projector"]
            pe = jax.nn.gelu(pe @ proj["w1"].astype(dt)) @ proj["w2"].astype(dt)
            h = jnp.concatenate([pe, h], axis=1)
        Bb, Ss = h.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(Ss), (Bb, Ss))
        h = shard(h, "batch", "seq", "embed")

        if cfg.family == "ssm":
            h, _ = _scan_mamba(cfg, params["layers"], h)
        elif cfg.family == "hybrid":
            h, _ = _hybrid_trunk(cfg, params, h, pos)
        else:
            for lp in params.get("prefix_layers", []):
                h, _, a = block_fwd(cfg, lp, h, pos, kind="dense")
                aux = aux + a
            kind = "moe" if cfg.n_experts else "dense"
            h, _, a = _scan_blocks(cfg, params["layers"], h, pos, kind)
            aux = aux + a

    h = L.norm_fwd(cfg, params["final_norm"], h)
    logits = L.unembed_fwd(cfg, params["embedding"], h)
    return logits, aux


def _chunked_ce(cfg: ArchConfig, params: Params, h: Array, labels: Array, mask: Array) -> Array:
    """Cross-entropy without materializing (B, S, V) logits: lax.map over
    sequence chunks (vocab up to 256k makes full logits the peak tensor)."""
    B, Ss, d = h.shape
    C = cfg.loss_chunk
    nC = Ss // C
    hc = h[:, : nC * C].reshape(B, nC, C, d).transpose(1, 0, 2, 3)
    lc = labels[:, : nC * C].reshape(B, nC, C).transpose(1, 0, 2)
    mc = mask[:, : nC * C].reshape(B, nC, C).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx, mx = args
        logits = L.unembed_fwd(cfg, params["embedding"], hx).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mx), jnp.sum(mx)

    losses, counts = jax.lax.map(chunk_loss, (hc, lc, mc))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    """Next-token cross-entropy (+ MoE aux).  VLM: loss on text positions only."""
    dt = _dtype(cfg)
    n_modal = batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0

    if cfg.loss_chunk and not cfg.is_encoder_decoder:
        # recompute trunk output h, then chunked CE over the sequence
        logits = None
        # forward trunk without unembedding
        tokens = batch["tokens"]
        h = L.embed_fwd(cfg, params["embedding"], tokens, dt)
        if n_modal:
            pe = batch["patch_embeds"].astype(dt)
            proj = params["projector"]
            pe = jax.nn.gelu(pe @ proj["w1"].astype(dt)) @ proj["w2"].astype(dt)
            h = jnp.concatenate([pe, h], axis=1)
        Bb, Ss = h.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(Ss), (Bb, Ss))
        h = shard(h, "batch", "seq", "embed")
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            h, _ = _scan_mamba(cfg, params["layers"], h)
        elif cfg.family == "hybrid":
            h, _ = _hybrid_trunk(cfg, params, h, pos)
        else:
            for lp in params.get("prefix_layers", []):
                h, _, a = block_fwd(cfg, lp, h, pos, kind="dense")
                aux = aux + a
            kind = "moe" if cfg.n_experts else "dense"
            h, _, a = _scan_blocks(cfg, params["layers"], h, pos, kind)
            aux = aux + a
        h = L.norm_fwd(cfg, params["final_norm"], h)
        # shift: predict token t+1 from position t
        labels_full = jnp.concatenate(
            [jnp.zeros((Bb, n_modal), tokens.dtype), batch["tokens"]], axis=1
        ) if n_modal else batch["tokens"]
        h_in = h[:, :-1]
        lab = labels_full[:, 1:]
        mask = jnp.ones_like(lab, jnp.float32)
        if n_modal:
            posn = jnp.arange(lab.shape[1])
            mask = mask * (posn[None, :] >= n_modal - 1)
        ce = _chunked_ce(cfg, params, h_in, lab, mask)
        return ce + aux, {"ce": ce, "aux": aux}

    logits, aux = forward(cfg, params, batch)
    tokens = batch["tokens"]
    if n_modal:
        logits_text = logits[:, n_modal:]
    else:
        logits_text = logits
    lg = logits_text[:, :-1].astype(jnp.float32)
    lab = tokens[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _cache_capacity(cfg: ArchConfig, total_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, total_len)
    return total_len


def init_cache(cfg: ArchConfig, batch: int, total_len: int, dtype=None,
               enc_len: int = 0) -> Params:
    """Decode cache for a context of ``total_len`` positions."""
    dt = dtype or _dtype(cfg)
    cap = _cache_capacity(cfg, total_len)
    cache: Params = {"idx": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        cache["layers"] = jax.vmap(lambda _: S.init_ssm_state(cfg, batch, dt))(
            jnp.arange(cfg.n_layers)
        )
    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.shared_attn_every
        cache["layers"] = {
            "attn": jax.vmap(lambda _: L.init_kv_cache(cfg, batch, cap, dt))(jnp.arange(G)),
            "mamba": jax.vmap(
                lambda _: jax.vmap(lambda __: S.init_ssm_state(cfg, batch, dt))(
                    jnp.arange(cfg.shared_attn_every)
                )
            )(jnp.arange(G)),
        }
    elif cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros((batch, enc_len, cfg.d_model), dt)
        cache["layers"] = jax.vmap(lambda _: L.init_kv_cache(cfg, batch, cap, dt))(
            jnp.arange(cfg.n_layers)
        )
    else:
        n_prefix = cfg.first_dense_layers if cfg.n_experts else 0
        if n_prefix:
            cache["prefix"] = [L.init_kv_cache(cfg, batch, cap, dt) for _ in range(n_prefix)]
        cache["layers"] = jax.vmap(lambda _: L.init_kv_cache(cfg, batch, cap, dt))(
            jnp.arange(cfg.n_layers - n_prefix)
        )
    return cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens: Array
                ) -> Tuple[Array, Params]:
    """One-token decode: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    dt = _dtype(cfg)
    idx = cache["idx"]
    B = tokens.shape[0]
    pos = jnp.broadcast_to(idx[None, None], (B, 1))
    h = L.embed_fwd(cfg, params["embedding"], tokens, dt)
    new_cache: Params = {"idx": idx + 1}
    kind = "moe" if cfg.n_experts else "dense"

    if cfg.family == "ssm":
        def body(carry, xs):
            lp, st = xs
            h2, new_st = mamba_block_fwd(cfg, lp, carry, st)
            return h2, new_st
        h, new_states = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        new_cache["layers"] = new_states
    elif cfg.family == "hybrid":
        h, new_c = _hybrid_trunk(cfg, params, h, pos, caches=cache["layers"], cache_index=idx)
        new_cache["layers"] = new_c
    elif cfg.is_encoder_decoder:
        enc_out = cache["enc_out"].astype(dt)
        h, new_c, _ = _scan_blocks(
            cfg, params["layers"], h, pos, "dense",
            caches=cache["layers"], cache_index=idx, enc_out=enc_out,
        )
        new_cache["enc_out"] = cache["enc_out"]
        new_cache["layers"] = new_c
    else:
        if "prefix" in cache:
            new_prefix = []
            for lp, c in zip(params["prefix_layers"], cache["prefix"]):
                h, nc, _ = block_fwd(cfg, lp, h, pos, kind="dense", cache=c, cache_index=idx)
                new_prefix.append(nc)
            new_cache["prefix"] = new_prefix
        h, new_c, _ = _scan_blocks(
            cfg, params["layers"], h, pos, kind, caches=cache["layers"], cache_index=idx
        )
        new_cache["layers"] = new_c

    h = L.norm_fwd(cfg, params["final_norm"], h)
    logits = L.unembed_fwd(cfg, params["embedding"], h)
    return logits, new_cache
