"""State-space model blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Training/prefill uses a parallel associative scan over the sequence
(TPU-friendly: log2(S) sweeps of elementwise FMAs, no sequential HBM
dependency).  Decode is a single O(1) state update — this is what makes
the SSM/hybrid architectures run the long_500k shape natively.

State conventions:
  mamba1: h (B, d_inner, n)          A (d_inner, n) full matrix diag-init
  mamba2: h (B, H, p, n)             A (H,) scalar per head (SSD)
Both carry a causal-conv ring state (B, d_inner, conv-1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.logical import shard
from repro.models.layers import _dense_init

Array = jax.Array
Params = Dict[str, Any]


def init_mamba(cfg: ArchConfig, key: Array) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    ks = jax.random.split(key, 8)
    p = {
        "in_proj": _dense_init(ks[0], (d, 2 * di)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, di)),
        "conv_b": jnp.zeros((di,)),
        "out_proj": _dense_init(ks[2], (di, d)),
        "D": jnp.ones((di,)) if cfg.ssm_variant == "mamba1" else jnp.ones((cfg.n_ssm_heads,)),
    }
    if cfg.ssm_variant == "mamba1":
        dtr = cfg.dt_rank_
        p.update(
            x_proj=_dense_init(ks[3], (di, dtr + 2 * n)),
            dt_proj=_dense_init(ks[4], (dtr, di), scale=dtr**-0.5),
            dt_bias=jnp.log(jnp.expm1(jnp.exp(
                jax.random.uniform(ks[5], (di,)) * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
            ))),
            A_log=jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        )
    else:  # mamba2 (SSD): scalar A per head, head-wise dt
        Hm = cfg.n_ssm_heads
        p.update(
            bc_proj=_dense_init(ks[3], (di, 2 * n)),
            dt_proj=_dense_init(ks[4], (d, Hm), scale=0.02),
            dt_bias=jnp.zeros((Hm,)),
            A_log=jnp.log(jnp.linspace(1.0, 16.0, Hm)),
            gnorm=jnp.ones((di,)),
        )
    return p


def init_ssm_state(cfg: ArchConfig, batch: int, dtype) -> Params:
    di, n = cfg.d_inner_, cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)
    if cfg.ssm_variant == "mamba1":
        h = jnp.zeros((batch, di, n), jnp.float32)
    else:
        h = jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, n), jnp.float32)
    return {"conv": conv, "h": h}


def _causal_conv(cfg: ArchConfig, p: Params, x: Array, conv_state: Optional[Array]):
    """Depthwise causal conv along S.  x (B,S,di).  Returns (y, new_state)."""
    B, S, di = x.shape
    kw = cfg.ssm_conv
    if conv_state is None:
        ctx = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
        new_state = None
    else:
        ctx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        new_state = ctx[:, -(kw - 1):, :]
    w = p["conv_w"].astype(x.dtype)  # (kw, di)
    y = sum(ctx[:, i : i + S, :] * w[i] for i in range(kw))
    return y + p["conv_b"].astype(x.dtype), new_state


def _assoc_scan(decay: Array, inp: Array) -> Array:
    """First-order linear recurrence h_t = decay_t * h_{t-1} + inp_t along
    axis 1 via an associative scan."""

    def combine(a, b):
        da, xa = a
        db, xb = b
        return da * db, xa * db + xb

    _, h = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    return h


def mamba_fwd(
    cfg: ArchConfig,
    p: Params,
    x: Array,
    state: Optional[Params] = None,
) -> Tuple[Array, Optional[Params]]:
    """Mamba block forward.  x (B,S,d).  state given -> stateful decode."""
    B, S, d = x.shape
    di, n = cfg.d_inner_, cfg.ssm_state
    dt = x.dtype

    xz = x @ p["in_proj"].astype(dt)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", "seq", "inner")
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(cfg, p, xin, conv_state)
    xc = jax.nn.silu(xc)

    if cfg.ssm_variant == "mamba1":
        dtr = cfg.dt_rank_
        proj = xc @ p["x_proj"].astype(dt)  # (B,S,dtr+2n)
        dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + n], axis=-1)
        delta = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt))
        A = -jnp.exp(p["A_log"]).astype(jnp.float32)  # (di,n)
        deltaf = delta.astype(jnp.float32)
        decay = jnp.exp(deltaf[..., None] * A[None, None])          # (B,S,di,n)
        inp = (deltaf * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
        if state is None:
            h = _assoc_scan(decay, inp)                             # (B,S,di,n)
            new_h = None
        else:
            h0 = state["h"][:, None]                                # (B,1,di,n)
            if S == 1:
                h = decay * h0 + inp
            else:
                h = _assoc_scan(decay, inp)
                h = h + decay.cumprod(axis=1) * h0  # fold initial state in
            new_h = h[:, -1]
        y = jnp.einsum("bsdn,bsn->bsd", h, Cc.astype(jnp.float32)).astype(dt)
        y = y + xc * p["D"].astype(dt)
    else:  # mamba2 / SSD
        Hm, hp = cfg.n_ssm_heads, cfg.ssm_head_dim
        bc = xc @ p["bc_proj"].astype(dt)
        Bc, Cc = jnp.split(bc, 2, axis=-1)                          # (B,S,n) each
        delta = jax.nn.softplus(x @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt))  # (B,S,Hm)
        A = -jnp.exp(p["A_log"]).astype(jnp.float32)                # (Hm,)
        xh = xc.reshape(B, S, Hm, hp)
        deltaf = delta.astype(jnp.float32)
        decay = jnp.exp(deltaf * A[None, None])                     # (B,S,Hm)
        inp = (deltaf[..., None] * xh.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, :, None, None, :]
        dec = decay[..., None, None]                                # (B,S,Hm,1,1)
        if state is None:
            h = _assoc_scan(dec, inp)                               # (B,S,Hm,hp,n)
            new_h = None
        else:
            h0 = state["h"][:, None]
            if S == 1:
                h = dec * h0 + inp
            else:
                h = _assoc_scan(dec, inp)
                h = h + dec.cumprod(axis=1) * h0
            new_h = h[:, -1]
        y = jnp.einsum("bshpn,bsn->bshp", h, Cc.astype(jnp.float32)).astype(dt)
        y = y.reshape(B, S, di) + xc * jnp.repeat(p["D"].astype(dt), hp)
        # grouped RMS norm (mamba2 normalizes before gating)
        y = y * jax.lax.rsqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1, keepdims=True) + 1e-6).astype(dt)
        y = y * p["gnorm"].astype(dt)

    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "h": new_h}
    return shard(out, "batch", "seq", "embed"), new_state
