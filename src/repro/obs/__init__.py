"""Flight recorder: in-scan decision telemetry, round profiling and
trace export for the DFL engine (docs/OBSERVABILITY.md).

Three planes, three modules:

- :mod:`repro.obs.decision` — the packed per-edge verdict bitmask and
  per-node summaries, emitted as pure traced outputs of the round/scan
  (import-light: engine and mode-B depend on it, so this package
  ``__init__`` must NOT pull in the heavier planes below).
- :mod:`repro.obs.profile` — compile-vs-steady wall clock, named scopes
  / TraceAnnotations, achieved-bytes/s via the ``memory_passes`` table.
- :mod:`repro.obs.recorder` / :mod:`repro.obs.trace` /
  ``python -m repro.obs.report`` — JSONL event log, Chrome/Perfetto
  ``trace_event`` export, and the per-filter audit tables.
"""
from repro.obs.decision import (  # noqa: F401
    BIT_ACCEPTED,
    BIT_C,
    BIT_CORRUPT,
    BIT_D,
    BIT_DROPPED,
    BIT_STALE,
    BIT_T,
    BIT_VALID,
    BITS,
    DecisionRecord,
    FAULT_BITS,
    pack_verdict,
    record_from_info,
    record_from_masks,
    record_uniform,
    unpack_verdict,
    with_fault_bits,
)
