"""Decision plane: the packed per-edge filter-verdict record.

The WFAgg 2-of-3 vote already computes everything a flight recorder
needs — the three filter masks, the valid mask and the trust weights —
the round path just used to throw the ``info`` dict away.  This module
packs those signals into a fixed-width per-edge **verdict bitmask** plus
a handful of per-node summaries, all as pure ``jnp`` ops on values the
round already holds, so the record can ride through ``lax.scan`` as a
traced output: no host callbacks, no extra kernel launches, and the
``no-host-transfer-in-scan`` lint stays green (pinned by the
``dynamic_scan_telemetry`` entry in ``repro.analysis``).

Bit layout of the (…, K) uint8 ``verdict`` (bit SET = the edge passed
that test; a filter *rejection* is ``valid & ~bit``):

    bit 0  BIT_D         accepted by the distance filter (mask_d)
    bit 1  BIT_C         accepted by the similarity filter (mask_c)
    bit 2  BIT_T         accepted by the temporal filter (mask_t)
    bit 3  BIT_VALID     the edge exists this round (padded slates)
    bit 4  BIT_ACCEPTED  final verdict: positive trust weight
    bit 5  BIT_DROPPED   transport: delivery dropped / over budget
    bit 6  BIT_STALE     transport: a stale (lag > 0) payload was served
    bit 7  BIT_CORRUPT   transport: corruption hit the edge's payload

Bits 5-7 are the chaos-transport attribution bits (``repro.dfl.faults``)
— OR'd in by :func:`with_fault_bits` on fault-injected rounds, always 0
on clean ones.  The packing is bool -> uint8 (never through floats), so
the ``f32-trust-invariant`` lint rule — no sub-f32 downcasts of
trust-sized buffers — is untouched by construction.  See
docs/OBSERVABILITY.md and docs/FAULTS.md.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

BIT_D = 1 << 0
BIT_C = 1 << 1
BIT_T = 1 << 2
BIT_VALID = 1 << 3
BIT_ACCEPTED = 1 << 4
BIT_DROPPED = 1 << 5
BIT_STALE = 1 << 6
BIT_CORRUPT = 1 << 7

#: name -> bit position for the five masks :func:`pack_verdict` packs
BITS = {"mask_d": 0, "mask_c": 1, "mask_t": 2, "valid": 3, "accepted": 4}
#: transport-attribution bits, OR'd in by :func:`with_fault_bits` only
FAULT_BITS = {"dropped": 5, "stale": 6, "corrupt": 7}

_EPS = 1e-12


class DecisionRecord(NamedTuple):
    """One round's filter decisions, shaped to scan/stack cleanly.

    Leading axes are whatever the call site carries — ``(N,)`` per-node
    for a mode-A gossip round, ``()`` for a mode-B all-reduce, ``(R, N)``
    after a schedule scan stacks R rounds.
    """
    verdict: Array        # (..., K) uint8 packed per-edge bitmask
    accepted: Array       # (...,)   int32 accepted-neighbor count
    mean_fallback: Array  # (...,)   bool: valid neighbors existed, ALL rejected
    degree_zero: Array    # (...,)   bool: no valid neighbors at all
    entropy: Array        # (...,)   f32 entropy (nats) of normalized trust weights


def pack_verdict(mask_d: Array, mask_c: Array, mask_t: Array,
                 valid: Array, accepted: Array) -> Array:
    """Pack five boolean (…, K) masks into one uint8 bitmask."""
    u8 = lambda m: m.astype(jnp.uint8)  # noqa: E731 — bool->uint8, no floats
    return (u8(mask_d)
            | (u8(mask_c) << 1)
            | (u8(mask_t) << 2)
            | (u8(valid) << 3)
            | (u8(accepted) << 4))


def unpack_verdict(verdict) -> Dict[str, "jnp.ndarray"]:
    """Inverse of :func:`pack_verdict`: name -> boolean array (host side
    works on numpy arrays too — only >> and & are used).  Also unpacks
    the transport bits (:data:`FAULT_BITS`) — zero unless
    :func:`with_fault_bits` OR'd them in."""
    return {name: ((verdict >> bit) & 1).astype(bool)
            for name, bit in {**BITS, **FAULT_BITS}.items()}


def record_from_masks(mask_d: Array, mask_c: Array, mask_t: Array,
                      valid: Array, weights: Array) -> DecisionRecord:
    """Build the record from the raw filter masks + trust weights.

    Shape-polymorphic over leading axes: (K,) mode-B vectors and (N, K)
    mode-A batches both work.  ``mean_fallback`` means the node HAD valid
    neighbors but the vote rejected all of them (it silently keeps its
    local model under the DFL convention — exactly the event satellite 2
    surfaces); ``degree_zero`` means there was nothing to aggregate in
    the first place (DoS'd / partitioned away).
    """
    valid_b = valid.astype(bool)
    acc = (weights > 0) & valid_b
    verdict = pack_verdict(mask_d.astype(bool), mask_c.astype(bool),
                           mask_t.astype(bool), valid_b, acc)
    degree = valid_b.sum(axis=-1)
    n_accepted = acc.sum(axis=-1).astype(jnp.int32)
    wsum = (weights * valid_b).sum(axis=-1)
    mean_fallback = (degree > 0) & (wsum <= 0)
    # entropy of the normalized trust distribution (0*log0 := 0); high =
    # the vote spread trust evenly, ~0 = one neighbor dominates (or all
    # rejected, where we define it as 0)
    p = (weights * valid_b) / jnp.maximum(wsum, _EPS)[..., None]
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, _EPS)), 0.0),
                   axis=-1)
    ent = jnp.where(wsum > 0, ent, 0.0).astype(jnp.float32)
    return DecisionRecord(verdict=verdict, accepted=n_accepted,
                          mean_fallback=mean_fallback,
                          degree_zero=degree == 0, entropy=ent)


def record_from_info(info: Dict[str, Array],
                     valid: Optional[Array] = None) -> DecisionRecord:
    """Build the record from a WFAgg ``info`` dict (``wfagg_batch`` /
    ``_weights_from_stats`` both emit mask_d/mask_c/mask_t/weights).
    ``valid`` falls back to info's, then to all-true (regular slates and
    mode-B identity slates have no padding)."""
    if valid is None:
        valid = info.get("valid")
    w = info["weights"]
    if valid is None:
        valid = jnp.ones(w.shape, bool)
    return record_from_masks(info["mask_d"], info["mask_c"], info["mask_t"],
                             valid, w)


def with_fault_bits(record: DecisionRecord, dropped: Array, stale: Array,
                    corrupt: Array) -> DecisionRecord:
    """OR the chaos-transport attribution bits into a record's verdict.

    Pure uint8 bit math on the already-packed mask — the summaries are
    untouched and the model trajectory cannot depend on it (telemetry
    off skips the whole record).  ``dropped``/``stale``/``corrupt`` are
    the (…, K) telemetry masks of ``faults.TransportOut``.
    """
    u8 = lambda m: m.astype(jnp.uint8)  # noqa: E731 — bool->uint8, no floats
    verdict = (record.verdict
               | (u8(dropped) << 5)
               | (u8(stale) << 6)
               | (u8(corrupt) << 7))
    return record._replace(verdict=verdict)


def record_uniform(valid: Array) -> DecisionRecord:
    """Record for aggregators with no per-edge filter verdicts (mean /
    median / Krum-family baselines): every valid edge counts as accepted
    with uniform weight, the three filter bits stay 0 (a report must not
    read them as rejections — check BIT_ACCEPTED first), and degree-0 is
    still tracked, which is what the DoS/partition scenarios need."""
    valid_b = valid.astype(bool)
    zeros = jnp.zeros(valid_b.shape, bool)
    verdict = pack_verdict(zeros, zeros, zeros, valid_b, valid_b)
    degree = valid_b.sum(axis=-1)
    return DecisionRecord(
        verdict=verdict,
        accepted=degree.astype(jnp.int32),
        mean_fallback=jnp.zeros(degree.shape, bool),
        degree_zero=degree == 0,
        entropy=jnp.where(
            degree > 0, jnp.log(jnp.maximum(degree.astype(jnp.float32), 1.0)),
            0.0),
    )
