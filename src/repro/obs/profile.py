"""Timing plane: compile-vs-steady wall clock, profiler scopes and the
achieved-bandwidth join against the ``memory_passes`` traffic table.

Everything here is host-side instrumentation AROUND jitted computations
— nothing in this module enters a traced region, so the decision plane's
no-host-transfer-in-scan guarantee is untouched.  The one JAX-profiler
integration is opt-in: :func:`annotate` wraps a round in
``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` (so device traces
attribute time to rounds), and :func:`capture` brackets a run with
``jax.profiler.trace`` for a full TensorBoard/Perfetto device capture on
TPU runs.

Timing methodology (shared with ``benchmarks/agg_microbench.py``): the
FIRST call is trace + compile + one execution and is reported as its own
number; steady state is the median over ``reps`` further calls, each
individually synchronized with ``block_until_ready`` — an async dispatch
queue otherwise attributes every round's device time to whichever call
finally blocks.
"""
from __future__ import annotations

import contextlib
import statistics
import time
from typing import Callable, List, NamedTuple, Optional

import jax


class TimingResult(NamedTuple):
    compile_s: float        # first call: trace + compile + one run
    steady_s: float         # median of the per-call steady-state times
    steady_all_s: List[float]   # every steady-state sample (reps of them)


def time_compile_steady(fn: Callable, *args, reps: int = 5) -> TimingResult:
    """Time ``fn(*args)``: separate first-call (compile) and median
    steady-state seconds, each call synchronized with
    ``block_until_ready``."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_s = time.perf_counter() - t0
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return TimingResult(compile_s, statistics.median(samples), samples)


@contextlib.contextmanager
def annotate(name: str):
    """Name a round for the device profiler: ``jax.named_scope`` tags
    ops traced inside, ``TraceAnnotation`` marks the host slice so a
    ``jax.profiler`` capture shows rounds as labelled spans."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def capture(logdir: Optional[str]):
    """Opt-in device profile capture: wraps the block in
    ``jax.profiler.trace(logdir)`` when ``logdir`` is set (TPU runs get
    a full XLA/TraceMe capture loadable in TensorBoard or Perfetto);
    no-op when falsy, so call sites don't branch."""
    if not logdir:
        yield
        return
    with jax.profiler.trace(logdir):
        yield


def round_traffic_bytes(wcfg, n_nodes: int, width: int, d: int, *,
                        indexed: bool = True,
                        include_gather: bool = True) -> float:
    """Analytic bytes moved per gossip round: the ``memory_passes``
    traffic table (src/repro/kernels/README.md) times the candidate
    bytes one pass streams — N nodes x K candidates x d floats."""
    from repro.core import wfagg as wf

    passes = wf.memory_passes(wcfg, include_gather=include_gather,
                              indexed=indexed)
    return float(passes) * n_nodes * width * d * 4.0


def achieved_bytes_per_s(traffic_bytes: float, steady_s: float) -> float:
    """Achieved HBM-ish bandwidth for one round: analytic traffic over
    measured steady-state seconds."""
    return traffic_bytes / max(steady_s, 1e-12)
