"""Export plane, part 1: the structured JSONL event log.

One run = one event stream.  Every event is a flat JSON object with a
``type`` field; the schema below is the contract the CI ``OBS_SMOKE``
step validates against and ``repro.obs.trace`` / ``repro.obs.report``
consume (docs/OBSERVABILITY.md documents it for humans).

Event types:

``run_meta``        once, first: the run's shape and knobs.
``round_decision``  per round: the decision plane — packed verdict
                    bitmask (``repro.obs.decision``), slate context and
                    per-node summaries.
``round_timing``    per round: wall seconds; ``kind`` is "compile" for
                    the first (traced+compiled) round, "steady" after.
``round_eval``      per evaluated round: benign accuracy.
``profile``         once, last: compile/steady split + the
                    memory_passes bandwidth join (repro.obs.profile).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

#: type name -> {field: allowed python types}; every event also gets
#: free-form extra fields (the schema pins the floor, not the ceiling).
SCHEMA: Dict[str, Dict[str, tuple]] = {
    "run_meta": {
        "n_nodes": (int,), "width": (int,), "rounds": (int,),
        "aggregator": (str,), "attack": (str,), "scenario": (str,),
        "backend": (str,),
    },
    "round_decision": {
        "round": (int,),            # 1-based
        "verdict": (list,),         # (N, K) uint8 bitmask, nested lists
        "neighbor_idx": (list,),    # (N, K) int
        "malicious": (list,),       # (N,) bool
        "accepted": (list,),        # (N,) int
        "mean_fallback": (list,),   # (N,) bool
        "degree_zero": (list,),     # (N,) bool
        "entropy": (list,),         # (N,) float
    },
    "round_timing": {
        "round": (int,), "wall_s": (float,), "kind": (str,),
    },
    "round_eval": {
        "round": (int,), "acc_benign_mean": (float,),
    },
    "profile": {
        "compile_s": (float,), "steady_s_median": (float,),
        "bytes_per_round": (float, int), "achieved_bytes_per_s": (float, int),
    },
}

_TIMING_KINDS = ("compile", "steady")


def _jsonable(value: Any) -> Any:
    """numpy arrays/scalars -> plain python, recursively."""
    if isinstance(value, np.ndarray):
        return value.tolist()
    if isinstance(value, (np.bool_, np.integer, np.floating)):
        return value.item()
    if isinstance(value, dict):
        return {k: _jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    return value


def validate_event(event: Dict[str, Any]) -> List[str]:
    """Schema errors for one event ([] = valid)."""
    errs = []
    etype = event.get("type")
    if etype not in SCHEMA:
        return [f"unknown event type {etype!r}"]
    for field, types in SCHEMA[etype].items():
        if field not in event:
            errs.append(f"{etype}: missing field {field!r}")
        elif not isinstance(event[field], types):
            errs.append(f"{etype}.{field}: expected {types}, got "
                        f"{type(event[field]).__name__}")
    if etype == "round_timing" and event.get("kind") not in _TIMING_KINDS:
        errs.append(f"round_timing.kind: expected one of {_TIMING_KINDS}, "
                    f"got {event.get('kind')!r}")
    return errs


def validate_events(events: Iterable[Dict[str, Any]],
                    strict: bool = False) -> List[str]:
    """Schema errors for a whole stream, plus stream-level checks: the
    stream must open with ``run_meta``, and every ``round_decision``
    verdict must be (N, K)-shaped per the meta.  ``strict`` raises."""
    events = list(events)
    errs: List[str] = []
    if not events:
        errs.append("empty event stream")
    elif events[0].get("type") != "run_meta":
        errs.append("stream must open with a run_meta event")
    meta = events[0] if events and events[0].get("type") == "run_meta" else {}
    for i, ev in enumerate(events):
        for e in validate_event(ev):
            errs.append(f"event[{i}]: {e}")
    n, k = meta.get("n_nodes"), meta.get("width")
    if isinstance(n, int) and isinstance(k, int):
        for i, ev in enumerate(events):
            if ev.get("type") != "round_decision":
                continue
            v = ev.get("verdict")
            if (not isinstance(v, list) or len(v) != n
                    or any(not isinstance(row, list) or len(row) != k
                           for row in v)):
                errs.append(f"event[{i}]: round_decision.verdict is not "
                            f"({n}, {k})-shaped")
    if strict and errs:
        raise ValueError("invalid event stream:\n  " + "\n  ".join(errs))
    return errs


class FlightRecorder:
    """Collects events in memory and (optionally) streams them to a
    JSONL file as they are emitted — a crash still leaves the rounds
    recorded so far on disk.

        with FlightRecorder("run.jsonl") as rec:
            rec.emit("run_meta", n_nodes=20, ...)
            rec.emit("round_decision", round=1, verdict=..., ...)
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[Dict[str, Any]] = []
        self._fh = open(path, "w") if path else None

    def emit(self, etype: str, **fields: Any) -> Dict[str, Any]:
        event = {"type": etype, **{k: _jsonable(v) for k, v in fields.items()}}
        errs = validate_event(event)
        if errs:
            raise ValueError("invalid event:\n  " + "\n  ".join(errs))
        self.events.append(event)
        if self._fh is not None:
            self._fh.write(json.dumps(event) + "\n")
            self._fh.flush()
        return event

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def write_events(events: Iterable[Dict[str, Any]], path: str) -> None:
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(_jsonable(ev)) + "\n")


def read_events(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
