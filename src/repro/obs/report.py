"""Export plane, part 3: the audit report.

``python -m repro.obs.report`` either replays a recorded JSONL event log
(``--events run.jsonl``) or runs a fresh experiment round by round with
the flight recorder on (defaults: the acceptance scenario — 20-node
ring, eclipse topology attack, band_rider adaptive adversary, WFAgg),
then renders:

- the per-filter decision audit: for every round, each filter's
  TRUE-CATCH rate (fraction of valid attacker edges it rejected) and
  FALSE-POSITIVE rate (fraction of valid benign edges it rejected) —
  the table that says which filter actually carried the defense;
- mean-fallback and degree-0 counts per round (satellite: a node
  silently keeping its local model is now a visible event);
- the round timeline: compile vs steady wall clock and the achieved
  bytes/s against the ``memory_passes`` traffic table;
- on fault-injected logs (verdict bits 5-7 set, see
  ``repro.dfl.faults`` and docs/FAULTS.md): a per-round
  dropped/stale/corrupted edge column and a per-fault attribution
  summary — clean logs render byte-identically to before.

With ``--out-events`` / ``--out-trace`` it writes the JSONL log and the
Perfetto ``trace_event`` JSON (load at https://ui.perfetto.dev).  The
analysis helpers (:func:`attacker_edge_mask`, :func:`filter_rates`,
:func:`attribution`) are plain numpy over the packed verdicts, reused by
``benchmarks/robustness_matrix.py`` for its per-cell filter-attribution
columns.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

import numpy as np

from repro.obs.decision import BITS, FAULT_BITS
from repro.obs import profile as obs_profile
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace

FILTERS = (("d", "mask_d"), ("c", "mask_c"), ("t", "mask_t"))

#: chaos-transport attribution bits (decision verdict bits 5-7); short
#: label -> FAULT_BITS key.  Zero on clean runs, so the audit only grows its
#: fault column when a fault-injected log is being rendered.
FAULT_KINDS = (("drp", "dropped"), ("stl", "stale"), ("cor", "corrupt"))


# ---------------------------------------------------------------------------
# analysis over packed verdicts (plain numpy, reusable)
# ---------------------------------------------------------------------------

def attacker_edge_mask(neighbor_idx: np.ndarray, valid: np.ndarray,
                       malicious: np.ndarray) -> np.ndarray:
    """(R, N, K) bool: edge (r, n, k) is valid AND its sender (the
    neighbor ``neighbor_idx[r, n, k]``) is malicious in round r."""
    idx = np.asarray(neighbor_idx)
    R = idx.shape[0]
    mal = np.asarray(malicious, bool)
    sender_mal = mal[np.arange(R)[:, None, None], idx]
    return sender_mal & np.asarray(valid, bool)


def filter_rates(verdict: np.ndarray, neighbor_idx: np.ndarray,
                 valid: np.ndarray, malicious: np.ndarray) -> Dict[str, Any]:
    """Per-round true-catch / false-positive rates per filter.

    Returns ``{"d"|"c"|"t"|"final": {"true_catch": (R,), "false_pos":
    (R,)}, "n_attacker_edges": (R,), "n_benign_edges": (R,)}`` where
    true-catch[r] is the fraction of valid attacker edges the filter
    rejected in round r (NaN when the round has no attacker edges) and
    false-pos[r] the fraction of valid benign edges it rejected.
    "final" is the 2-of-3 vote's verdict (the accepted bit).

    Caveat read with the tables: WFAgg-T abstains during its transient
    (mask_t is all-false before the EWMA bands exist), which reads as
    rejecting EVERYTHING in early rounds — per-round tables make that
    visible instead of averaging it away.
    """
    v = np.asarray(verdict, np.uint8)
    valid_b = ((v >> BITS["valid"]) & 1).astype(bool)
    attacker = attacker_edge_mask(neighbor_idx, valid, malicious) & valid_b
    benign = valid_b & ~attacker
    n_att = attacker.sum(axis=(1, 2)).astype(float)
    n_ben = benign.sum(axis=(1, 2)).astype(float)
    out: Dict[str, Any] = {"n_attacker_edges": n_att, "n_benign_edges": n_ben}
    for name, key in FILTERS + (("final", "accepted"),):
        ok = ((v >> BITS[key]) & 1).astype(bool)
        rejected = valid_b & ~ok
        with np.errstate(invalid="ignore", divide="ignore"):
            tc = np.where(n_att > 0,
                          (rejected & attacker).sum(axis=(1, 2)) / np.maximum(n_att, 1),
                          np.nan)
            fp = np.where(n_ben > 0,
                          (rejected & benign).sum(axis=(1, 2)) / np.maximum(n_ben, 1),
                          np.nan)
        out[name] = {"true_catch": tc, "false_pos": fp}
    return out


def attribution(rates: Dict[str, Any]) -> Dict[str, Any]:
    """Which filter carried the defense: mean (true-catch − false-pos)
    margin per filter over the rounds that HAD attacker edges;
    ``carried_by`` is the best filter with a STRICTLY POSITIVE margin
    (None otherwise).  The margin (not raw catch rate) keeps the
    temporal filter's transient — where it "catches" everything by
    abstaining — from claiming credit it shares with every benign edge
    it also dropped."""
    out: Dict[str, Any] = {}
    best, best_margin = None, 0.0
    for name, _ in FILTERS:
        tc, fp = rates[name]["true_catch"], rates[name]["false_pos"]
        seen = ~np.isnan(tc)
        if not seen.any():
            out[name] = {"true_catch": None, "false_pos": None, "margin": None}
            continue
        mtc = float(np.nanmean(tc))
        mfp = float(np.nanmean(np.where(seen, fp, np.nan)))
        margin = mtc - (0.0 if np.isnan(mfp) else mfp)
        out[name] = {"true_catch": round(mtc, 4),
                     "false_pos": round(mfp, 4) if not np.isnan(mfp) else None,
                     "margin": round(margin, 4)}
        # a filter only gets credit for a strictly positive margin: a
        # filter that rejects everything (e.g. WFAgg-T in transient) or
        # nothing scores <= 0 and cannot "carry" the defense
        if margin > best_margin:
            best, best_margin = name, margin
    out["carried_by"] = best
    return out


def fault_rates(verdict: np.ndarray) -> Dict[str, Any]:
    """Per-round transport-fault rates off the packed verdicts.

    Returns ``{"dropped"|"stale"|"corrupt"|"any": (R,) fraction of slate
    edges, "counts": {kind: (R,) int}}``.  The denominator is the full
    N*K slate (not the valid mask): a dropped edge is by definition no
    longer valid, so rating faults against surviving edges would hide
    exactly the events being attributed.  All zeros on clean runs —
    bits 5-7 are only OR'd in by fault-injected rounds
    (:func:`repro.obs.decision.with_fault_bits`)."""
    v = np.asarray(verdict, np.uint8)
    edges = float(v.shape[-1] * v.shape[-2])
    axes = (-2, -1)
    out: Dict[str, Any] = {"counts": {}}
    any_m = np.zeros(v.shape, bool)
    for _, kind in FAULT_KINDS:
        m = ((v >> FAULT_BITS[kind]) & 1).astype(bool)
        any_m |= m
        out["counts"][kind] = m.sum(axis=axes)
        out[kind] = m.sum(axis=axes) / edges
    out["any"] = any_m.sum(axis=axes) / edges
    return out


def fault_attribution(rates: Dict[str, Any]) -> Dict[str, Any]:
    """Mean per-kind fault rate over the run + the dominant kind (None
    when the log carries no fault bits at all — i.e. a clean run)."""
    out: Dict[str, Any] = {}
    best, best_rate = None, 0.0
    for _, kind in FAULT_KINDS:
        mean = float(np.mean(rates[kind]))
        out[kind] = round(mean, 4)
        if mean > best_rate:
            best, best_rate = kind, mean
    out["dominant"] = best
    return out


def telemetry_rates(telemetry: Dict[str, Any]) -> Dict[str, Any]:
    """:func:`filter_rates` straight off an engine ``out["telemetry"]``
    bundle (run_experiment / run_dynamic_experiment with
    ``telemetry=True``)."""
    return filter_rates(telemetry["verdict"], telemetry["neighbor_idx"],
                        telemetry["valid"], telemetry["malicious"])


def events_from_telemetry(telemetry: Dict[str, Any],
                          meta: Optional[Dict[str, Any]] = None) -> list:
    """Recorder-schema event stream from an engine ``out["telemetry"]``
    bundle — decision events only: a run that came out of one
    ``lax.scan`` has no per-round wall clock (that is the timing plane's
    trade, see :func:`run_flight`), so no ``round_timing`` events are
    synthesized."""
    verdict = np.asarray(telemetry["verdict"], np.uint8)
    R, N, K = verdict.shape
    base: Dict[str, Any] = dict(n_nodes=N, width=K, rounds=R,
                                aggregator="?", attack="?", scenario="?",
                                backend="?")
    base.update(meta or {})
    events = [obs_recorder._jsonable(dict(type="run_meta", **base))]
    for r in range(R):
        events.append(obs_recorder._jsonable(dict(
            type="round_decision", round=r + 1,
            verdict=verdict[r],
            neighbor_idx=np.asarray(telemetry["neighbor_idx"][r]),
            malicious=np.asarray(telemetry["malicious"][r], bool),
            accepted=np.asarray(telemetry["accepted"][r]),
            mean_fallback=np.asarray(telemetry["mean_fallback"][r], bool),
            degree_zero=np.asarray(telemetry["degree_zero"][r], bool),
            entropy=np.asarray(telemetry["entropy"][r]))))
    return events


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _pct(x: float) -> str:
    return "    --" if x is None or np.isnan(x) else f"{100 * x:6.1f}"


def render_audit(events) -> str:
    """The audit tables from a flight-recorder event stream."""
    meta = next((e for e in events if e.get("type") == "run_meta"), {})
    decisions = [e for e in events if e.get("type") == "round_decision"]
    if not decisions:
        return "no round_decision events — was telemetry on?"
    verdict = np.asarray([e["verdict"] for e in decisions], np.uint8)
    nidx = np.asarray([e["neighbor_idx"] for e in decisions])
    mal = np.asarray([e["malicious"] for e in decisions], bool)
    valid = ((verdict >> BITS["valid"]) & 1).astype(bool)
    rates = filter_rates(verdict, nidx, valid, mal)
    attr = attribution(rates)
    frates = fault_rates(verdict)
    has_faults = bool(np.any(frates["any"] > 0))
    wall = {e["round"]: e for e in events if e.get("type") == "round_timing"}
    acc = {e["round"]: e["acc_benign_mean"] for e in events
           if e.get("type") == "round_eval"}

    lines = []
    lines.append(
        f"flight audit: {meta.get('aggregator', '?')} vs "
        f"{meta.get('attack', '?')} attack, {meta.get('scenario', '?')} "
        f"scenario, {meta.get('n_nodes', '?')} nodes "
        f"[{meta.get('backend', '?')} backend]")
    lines.append("")
    lines.append("per-filter decision audit — true-catch % of attacker "
                 "edges / false-positive % of benign edges")
    lines.append(f"{'round':>5s} {'edges(att/ben)':>14s}"
                 + "".join(f"{f.upper() + ' tc/fp':>16s}" for f, _ in FILTERS)
                 + f"{'FINAL tc/fp':>16s}"
                 + f"{'fallbk':>7s}{'deg0':>5s}"
                 + (f"{'drp/stl/cor':>13s}" if has_faults else "")
                 + f"{'acc%':>7s}{'ms':>9s}")
    for r, dec in enumerate(decisions, start=1):
        row = f"{r:5d} {int(rates['n_attacker_edges'][r-1]):6d}/"
        row += f"{int(rates['n_benign_edges'][r-1]):<7d}"
        for name, _ in FILTERS + (("final", None),):
            tc = rates[name]["true_catch"][r - 1]
            fp = rates[name]["false_pos"][r - 1]
            row += f" {_pct(tc)}/{_pct(fp).strip():>5s}"
        row += f"{int(np.sum(dec['mean_fallback'])):7d}"
        row += f"{int(np.sum(dec['degree_zero'])):5d}"
        if has_faults:
            cts = frates["counts"]
            cell = "/".join(str(int(cts[k][r - 1])) for _, k in FAULT_KINDS)
            row += f"{cell:>13s}"
        row += (f"{100 * acc[r]:7.2f}" if r in acc else f"{'--':>7s}")
        w = wall.get(r)
        row += (f"{1e3 * w['wall_s']:9.1f}" if w else f"{'--':>9s}")
        lines.append(row)

    lines.append("")
    lines.append("filter attribution (mean over attacked rounds, margin = "
                 "true-catch − false-positive):")
    for name, _ in FILTERS:
        a = attr[name]
        if a["true_catch"] is None:
            lines.append(f"  {name.upper()}: no attacked rounds")
        else:
            lines.append(f"  {name.upper()}: true-catch {100*a['true_catch']:5.1f}%  "
                         f"false-pos {100*(a['false_pos'] or 0):5.1f}%  "
                         f"margin {100*a['margin']:+6.1f}%")
    lines.append("  defense carried by: "
                 + (attr["carried_by"].upper() if attr["carried_by"]
                    else "none (no filter beat its false-positive rate — "
                         "transient, or no attacker present)"))

    if has_faults:
        fattr = fault_attribution(frates)
        lines.append("")
        lines.append("transport-fault attribution (mean % of slate edges "
                     "per round, docs/FAULTS.md):")
        lines.append("  " + "  ".join(
            f"{kind} {100 * fattr[kind]:5.2f}%" for _, kind in FAULT_KINDS)
            + f"  dominant: {fattr['dominant'] or 'none'}")

    prof = next((e for e in events if e.get("type") == "profile"), None)
    if prof is not None:
        lines.append("")
        lines.append(
            f"timing: compile {prof['compile_s']:.2f}s, steady median "
            f"{1e3 * prof['steady_s_median']:.1f}ms/round, analytic "
            f"traffic {prof['bytes_per_round'] / 1e6:.2f} MB/round -> "
            f"achieved {prof['achieved_bytes_per_s'] / 1e9:.3f} GB/s")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# the flight run: drive an experiment round by round, recorder on
# ---------------------------------------------------------------------------

def run_flight(cfg, topo, data, schedule, recorder: obs_recorder.FlightRecorder,
               n_test: int = 256, scenario: str = "?",
               capture_dir: Optional[str] = None) -> None:
    """Run the schedule round by round with telemetry on, emitting
    decision, timing and eval events into ``recorder``.

    Same math as ``run_dynamic_experiment``'s scan (same jitted round
    core, same ``realign_temporal_history`` re-keying between slates),
    driven from the host so every round gets an honest
    ``block_until_ready`` wall clock and a ``TraceAnnotation`` scope —
    per-round timing does not exist inside a ``lax.scan`` by
    construction, so the timing plane trades the one-jit form for it.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import metrics as met
    from repro.core import wfagg as wf
    from repro.dfl import engine as eng

    state = eng.init_dfl_state(cfg, topo, degree=schedule.width)
    round_fn = eng.build_round_fn(cfg, topo, data, dynamic=True,
                                  telemetry=True)
    realign = jax.jit(wf.realign_temporal_history)
    _, fwd = eng._model_fns(cfg)
    imgs, labels = data.test_set(n_test)
    eval_fn = jax.jit(lambda params: jax.vmap(
        lambda p: met.micro_accuracy(fwd(p, imgs), labels))(params))
    ever_mal = schedule.malicious.any(axis=0)

    recorder.emit(
        "run_meta", n_nodes=int(topo.n_nodes), width=int(schedule.width),
        rounds=int(schedule.rounds), aggregator=cfg.aggregator,
        attack=cfg.attack, scenario=scenario, backend=cfg.wfagg_backend)

    idx = jnp.asarray(schedule.neighbor_idx)
    val = jnp.asarray(schedule.valid)
    mal = jnp.asarray(schedule.malicious)
    prev_r = 0
    walls = []
    with obs_profile.capture(capture_dir):
        for r in range(schedule.rounds):
            if state.temporal is not None:
                state = state._replace(temporal=realign(
                    state.temporal, idx[prev_r], val[prev_r], idx[r], val[r]))
            prev_r = r
            with obs_profile.annotate(f"round {r + 1}"):
                t0 = time.perf_counter()
                state, record = round_fn(state, idx[r], val[r], mal[r])
                record = jax.block_until_ready(record)
                jax.block_until_ready(state)
                wall = time.perf_counter() - t0
            walls.append(wall)
            recorder.emit(
                "round_decision", round=r + 1,
                verdict=np.asarray(record.verdict),
                neighbor_idx=np.asarray(idx[r]),
                malicious=np.asarray(mal[r]),
                accepted=np.asarray(record.accepted),
                mean_fallback=np.asarray(record.mean_fallback),
                degree_zero=np.asarray(record.degree_zero),
                entropy=np.asarray(record.entropy))
            recorder.emit("round_timing", round=r + 1, wall_s=wall,
                          kind="compile" if r == 0 else "steady")
            accs = np.asarray(eval_fn(state.node_params))
            recorder.emit("round_eval", round=r + 1,
                          acc_benign_mean=float(accs[~ever_mal].mean()))

    steady = sorted(walls[1:]) or walls
    steady_median = steady[len(steady) // 2]
    flat_one, _ = eng._ravel_nodes(state.node_params)
    d = int(flat_one.shape[1])
    traffic = obs_profile.round_traffic_bytes(
        cfg.wfagg_config(), topo.n_nodes, int(schedule.width), d)
    recorder.emit(
        "profile", compile_s=walls[0], steady_s_median=steady_median,
        bytes_per_round=traffic,
        achieved_bytes_per_s=obs_profile.achieved_bytes_per_s(
            traffic, steady_median))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="flight-recorder audit report (docs/OBSERVABILITY.md)")
    ap.add_argument("--events", default="",
                    help="replay a recorded JSONL event log instead of "
                         "running an experiment")
    ap.add_argument("--aggregator", default="wfagg")
    ap.add_argument("--attack", default="band_rider")
    ap.add_argument("--scenario", default="eclipse")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--malicious", type=int, default=2)
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--model", default="mlp", choices=("mlp", "lenet"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-test", type=int, default=256)
    ap.add_argument("--out-events", default="",
                    help="write the JSONL event log here")
    ap.add_argument("--out-trace", default="",
                    help="write Perfetto trace_event JSON here "
                         "(load at ui.perfetto.dev)")
    ap.add_argument("--capture-dir", default="",
                    help="opt-in jax.profiler.trace capture directory "
                         "(TensorBoard/XLA device profile — TPU runs)")
    args = ap.parse_args(argv)

    if args.events:
        events = obs_recorder.read_events(args.events)
        obs_recorder.validate_events(events, strict=True)
    else:
        from repro.core.topology import make_topology
        from repro.data.synthetic import SyntheticImages
        from repro.dfl.dynamics import make_schedule
        from repro.dfl.engine import DFLConfig

        topo = make_topology(n_nodes=args.nodes, degree=args.degree,
                             n_malicious=args.malicious, kind="ring",
                             placement="close", seed=args.seed)
        data = SyntheticImages(seed=args.seed)
        cfg = DFLConfig(aggregator=args.aggregator, attack=args.attack,
                        model=args.model, seed=args.seed,
                        wfagg_backend=args.backend)
        schedule = make_schedule(args.scenario, topo, args.rounds,
                                 seed=args.seed)
        with obs_recorder.FlightRecorder(args.out_events or None) as rec:
            run_flight(cfg, topo, data, schedule, rec, n_test=args.n_test,
                       scenario=args.scenario,
                       capture_dir=args.capture_dir or None)
        events = rec.events
        obs_recorder.validate_events(events, strict=True)

    print(render_audit(events))
    if args.out_trace:
        obs_trace.write_trace(events, args.out_trace)
        print(f"\nwrote Perfetto trace: {args.out_trace}")
    if args.out_events and not args.events:
        print(f"wrote event log:     {args.out_events}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
