"""Export plane, part 2: Chrome/Perfetto ``trace_event`` JSON.

Converts a flight-recorder event stream (``repro.obs.recorder``) into
the Trace Event Format that ``ui.perfetto.dev`` / ``chrome://tracing``
load directly: each round is a complete ("X") slice on the rounds
track, and the per-filter rejection counts, fallback counts and mean
trust entropy are counter ("C") tracks aligned to the slice starts —
scrub the timeline and watch which filter was doing the catching as the
attack/topology evolves.

Rounds without a ``round_timing`` event (e.g. a record exported from a
single ``lax.scan``, where per-round wall clock does not exist by
construction) get a nominal 1 ms slice so the counter tracks still
render on a usable time axis.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

import numpy as np

from repro.obs.decision import BITS

_PID = 0
_TID_ROUNDS = 0
_DEFAULT_DUR_US = 1000.0   # nominal slice for rounds without wall clock


def _rejections(verdict: np.ndarray) -> Dict[str, int]:
    """Per-filter rejection counts over one round's (N, K) verdict:
    rejected-by-F = valid edge whose F bit is unset.  Only meaningful
    when the filter actually ran (wfagg family); for uniform/baseline
    records the accepted bit equals valid and these all read N*K-ish —
    the report layer guards on that, the trace just plots."""
    v = np.asarray(verdict, np.uint8)
    valid = (v >> BITS["valid"]) & 1
    out = {}
    for name, key in (("D", "mask_d"), ("C", "mask_c"), ("T", "mask_t")):
        ok = (v >> BITS[key]) & 1
        out[name] = int((valid & (1 - ok)).sum())
    out["final"] = int((valid & (1 - ((v >> BITS["accepted"]) & 1))).sum())
    return out


def to_trace_events(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Flight-recorder events -> list of Trace Event Format dicts."""
    events = list(events)
    meta = next((e for e in events if e.get("type") == "run_meta"), {})
    title = (f"dfl {meta.get('aggregator', '?')} vs "
             f"{meta.get('attack', '?')} [{meta.get('scenario', '?')}]"
             if meta else "dfl flight")
    out: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": _PID,
         "args": {"name": title}},
        {"name": "thread_name", "ph": "M", "pid": _PID, "tid": _TID_ROUNDS,
         "args": {"name": "rounds"}},
    ]

    wall_us = {e["round"]: 1e6 * e["wall_s"] for e in events
               if e.get("type") == "round_timing"}
    kind = {e["round"]: e["kind"] for e in events
            if e.get("type") == "round_timing"}
    acc = {e["round"]: e["acc_benign_mean"] for e in events
           if e.get("type") == "round_eval"}
    decisions = [e for e in events if e.get("type") == "round_decision"]
    rounds = sorted({e["round"] for e in decisions} | set(wall_us))

    ts = 0.0
    for r in rounds:
        dur = wall_us.get(r, _DEFAULT_DUR_US)
        dec = next((e for e in decisions if e["round"] == r), None)
        slice_args: Dict[str, Any] = {"kind": kind.get(r, "steady")}
        if r in acc:
            slice_args["acc_benign_mean"] = round(acc[r], 4)
        if dec is not None:
            slice_args["accepted_total"] = int(np.sum(dec["accepted"]))
            slice_args["mean_fallback"] = int(np.sum(dec["mean_fallback"]))
            slice_args["degree_zero"] = int(np.sum(dec["degree_zero"]))
        out.append({"name": f"round {r}", "cat": "round", "ph": "X",
                    "ts": ts, "dur": dur, "pid": _PID, "tid": _TID_ROUNDS,
                    "args": slice_args})
        if dec is not None:
            rej = _rejections(np.asarray(dec["verdict"]))
            out.append({"name": "filter rejections", "ph": "C", "ts": ts,
                        "pid": _PID, "args": rej})
            out.append({"name": "fallback", "ph": "C", "ts": ts, "pid": _PID,
                        "args": {"mean_fallback": int(np.sum(dec["mean_fallback"])),
                                 "degree_zero": int(np.sum(dec["degree_zero"]))}})
            out.append({"name": "trust entropy (mean)", "ph": "C", "ts": ts,
                        "pid": _PID,
                        "args": {"nats": round(float(np.mean(dec["entropy"])), 4)}})
        if r in acc:
            out.append({"name": "benign accuracy", "ph": "C", "ts": ts,
                        "pid": _PID, "args": {"acc": round(acc[r], 4)}})
        ts += dur
    return out


def write_trace(events: Iterable[Dict[str, Any]], path: str) -> None:
    """Write the Perfetto-loadable JSON object form
    (``{"traceEvents": [...]}``) — the safest of the accepted container
    formats for third-party viewers."""
    with open(path, "w") as f:
        json.dump({"traceEvents": to_trace_events(events),
                   "displayTimeUnit": "ms"}, f, indent=1)
        f.write("\n")
