"""Optimizers from scratch (optax is not available offline).

API mirrors optax: ``opt = make_optimizer(cfg_or_name, **hp)`` giving
  opt.init(params)                      -> state
  opt.update(grads, state, params, lr)  -> (updates, new_state)
where ``updates`` are ADDED to params (they already include the -lr).

Implemented:
  sgd        momentum SGD (paper Section V-A: momentum=0.9)
  adamw      decoupled weight decay Adam
  adafactor  factored second moments (production choice for >=14B params:
             Adam moments for a 470B model do not fit 16 GB/chip)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Array], Tuple[Any, Any]]


def _treemap2(f, a, b):
    return jax.tree.map(f, a, b)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        mu = _treemap2(lambda m, g: momentum * m + g, state["mu"], grads)
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, {"mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = _treemap2(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = _treemap2(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            mh = m / c1
            vh = v / c2
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (simplified: factored second moment, update clipping)
# ---------------------------------------------------------------------------

def adafactor(decay: float = 0.99, eps: float = 1e-30, clip_threshold: float = 1.0,
              min_dim_factored: int = 128) -> Optimizer:
    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and p.shape[-2] >= min_dim_factored

    def init(params):
        # second-moment stats stored as a flat list aligned with
        # tree_leaves(params) order (factored leaves hold dicts).
        def make(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": [make(p) for p in jax.tree.leaves(params)],
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if factored(p):
                vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                new_v = {"vr": vr, "vc": vc}
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
            else:
                vhat = decay * v["v"] + (1 - decay) * g2
                new_v = {"v": vhat}
            u = gf * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS <= threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), new_v

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        outs = [upd(g, v, p) for g, v, p in zip(g_leaves, state["v"], p_leaves)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_v = [o[1] for o in outs]
        return updates, {"v": new_v, "t": t}

    return Optimizer(init, update)


OPTIMIZERS = {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}


def make_optimizer(name: str, **hp) -> Optimizer:
    return OPTIMIZERS[name](**hp)


# ---------------------------------------------------------------------------
# LR schedule
# ---------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1) -> Callable[[Array], Array]:
    def lr(step):
        s = jnp.asarray(step).astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, s / max(warmup, 1))
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup, warm, cos)

    return lr


def constant_lr(v: float) -> Callable[[Array], Array]:
    return lambda step: jnp.full((), v, jnp.float32)
