"""Pytree checkpointing without orbax: npz blobs + a JSON manifest.

Layout:  <dir>/<name>.npz   flat arrays keyed by tree path
         <dir>/<name>.json  treedef + shapes/dtypes + user metadata
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # numpy has no bfloat16: store extended dtypes as f32 (restore
            # casts back to the dtype of the `like` leaf)
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, name: str, tree, metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    npz_path = os.path.join(directory, f"{name}.npz")
    np.savez(npz_path, **flat)
    manifest = {
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(directory, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return npz_path


def restore_checkpoint(directory: str, name: str, like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(os.path.join(directory, f"{name}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, f"{name}.npz"))
    flat_like = _flatten_with_paths(like)
    if sorted(flat_like) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]} ...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [jax.numpy.asarray(data[k]).astype(l.dtype) for k, l in zip(paths, leaves_like)]
    return treedef.unflatten(leaves), manifest["metadata"]


def load_metadata(directory: str, name: str) -> Dict:
    """Read a checkpoint's user metadata without touching the arrays."""
    with open(os.path.join(directory, f"{name}.json")) as f:
        return json.load(f)["metadata"]


# ---------------------------------------------------------------------------
# dynamic-experiment snapshots (chaos transport crash-exact resume)
# ---------------------------------------------------------------------------
# One snapshot = the dynamic scan's full carry (per-node models +
# momentum pytrees, the WFAgg-T temporal ring buffers, the transport
# delivery ring + served-lag table, the previous-round slate, and the
# round counter — every in-scan PRNG stream is derived from that
# counter, so the keys need no separate blob) PLUS the in-flight
# topology + fault schedule stacks.  Restoring both and re-entering the
# scan at the recorded round reproduces the uninterrupted trajectory
# bit-exactly; see repro.dfl.engine.run_dynamic_experiment and
# docs/FAULTS.md.

def save_experiment_checkpoint(directory: str, name: str, carry, sched,
                               metadata: Optional[Dict] = None) -> str:
    """Snapshot a dynamic-experiment scan mid-run.

    ``carry`` is whatever the chaos scan carries between rounds;
    ``sched`` the tuple of full schedule stacks (topology + faults).
    ``metadata`` must include ``round`` — the number of rounds already
    run, i.e. where the resumed scan re-enters.
    """
    if not metadata or "round" not in metadata:
        raise ValueError("experiment checkpoints need metadata['round'] "
                         "(rounds already run) to know where to resume")
    return save_checkpoint(directory, name,
                           {"carry": carry, "sched": list(sched)}, metadata)


def restore_experiment_checkpoint(directory: str, name: str,
                                  like_carry, like_sched
                                  ) -> Tuple[Any, tuple, Dict]:
    """Inverse of :func:`save_experiment_checkpoint`.

    Returns ``(carry, sched, metadata)`` restored into the structures of
    ``like_carry`` / ``like_sched`` (build both from the same config +
    schedules that produced the snapshot)."""
    tree, meta = restore_checkpoint(
        directory, name, {"carry": like_carry, "sched": list(like_sched)})
    return tree["carry"], tuple(tree["sched"]), meta
