"""Pytree checkpointing without orbax: npz blobs + a JSON manifest.

Layout:  <dir>/<name>.npz   flat arrays keyed by tree path
         <dir>/<name>.json  treedef + shapes/dtypes + user metadata
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # numpy has no bfloat16: store extended dtypes as f32 (restore
            # casts back to the dtype of the `like` leaf)
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, name: str, tree, metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    npz_path = os.path.join(directory, f"{name}.npz")
    np.savez(npz_path, **flat)
    manifest = {
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(directory, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return npz_path


def restore_checkpoint(directory: str, name: str, like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(os.path.join(directory, f"{name}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, f"{name}.npz"))
    flat_like = _flatten_with_paths(like)
    if sorted(flat_like) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]} ...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [jax.numpy.asarray(data[k]).astype(l.dtype) for k, l in zip(paths, leaves_like)]
    return treedef.unflatten(leaves), manifest["metadata"]
