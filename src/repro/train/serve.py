"""Serving step builders: prefill + single-token decode.

Decode shapes (decode_32k, long_500k) lower ``serve_step``: ONE new token
against a KV cache (or SSM state) of seq_len positions.  Params are
FSDP+TP sharded over both mesh axes (no gradient state — weights
all-gather per layer under GSPMD), the cache is batch-sharded over
'data'/'pod' and head-sharded over 'model'.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.data.specs import ENC_LEN_DECODE
from repro.distributed import sharding as shd
from repro.distributed.logical import use_sharding
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    multi_pod: bool = False

    def data_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)


def serve_rules(sc: ServeConfig):
    rules = shd.activation_rules("gspmd", sc.multi_pod)
    return rules


def cache_shapes(cfg: ArchConfig, shape: InputShape) -> Any:
    """Abstract decode-cache pytree for an input shape (no allocation)."""
    return jax.eval_shape(
        lambda: M.init_cache(
            cfg, shape.global_batch, shape.seq_len,
            enc_len=ENC_LEN_DECODE if cfg.is_encoder_decoder else 0,
        )
    )


def build_decode_step(cfg: ArchConfig, sc: ServeConfig, mesh: Mesh) -> Callable:
    """jitted fn(params, cache, tokens (B,1)) -> (logits, new_cache)."""
    rules = serve_rules(sc)

    def fn(params, cache, tokens):
        if isinstance(tokens, dict):
            tokens = tokens["tokens"]
        with use_sharding(mesh, rules):
            return M.decode_step(cfg, params, cache, tokens)

    return jax.jit(fn, donate_argnums=(1,))


def build_prefill(cfg: ArchConfig, sc: ServeConfig, mesh: Mesh) -> Callable:
    """jitted fn(params, batch) -> logits (full-sequence forward)."""
    rules = serve_rules(sc)

    def fn(params, batch):
        with use_sharding(mesh, rules):
            logits, _ = M.forward(cfg, params, batch)
            return logits

    return jax.jit(fn)


def serve_shardings(cfg: ArchConfig, sc: ServeConfig, mesh: Mesh,
                    params_shape: Any, cache_shape: Any):
    data_axes = sc.data_axes()
    ns = lambda s: NamedSharding(mesh, s)
    pspecs = jax.tree.map(ns, shd.param_specs(cfg, params_shape, fsdp=True, data_axes=data_axes, mesh=mesh))
    cspecs = jax.tree.map(ns, shd.cache_specs(cfg, cache_shape, data_axes=data_axes, mesh=mesh))
    return pspecs, cspecs
