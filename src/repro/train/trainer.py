"""Training step builders.

Two execution modes (DESIGN.md Section 3):

* ``robust_dp`` — the paper's technique as a first-class distributed
  feature: partial-manual shard_map over the candidate axes ('data', and
  'pod' when multi-pod).  Each worker computes its own gradient (GSPMD
  tensor-parallel over 'model'), Byzantine workers optionally poison it
  (integration tests / demos), and `robust_allreduce` replaces the mean
  all-reduce.  Params are replicated across candidates, TP-sharded over
  'model'.

* ``gspmd`` — conventional jit data-parallel training (mean aggregation,
  FSDP+TP param sharding).  Used for the >=100B arch whose K full
  gradient candidates cannot coexist in pod HBM (arctic-480b), and as the
  non-robust performance baseline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.topology import spaced_malicious
from repro.distributed import sharding as shd
from repro.distributed.logical import use_sharding
from repro.distributed.robust_allreduce import (
    AggState,
    RobustAggConfig,
    TreeAggState,
    apply_distributed_attack,
    apply_stacked_attack,
    init_agg_state,
    init_tree_agg_state,
    robust_allreduce,
    robust_allreduce_stacked,
)
from repro.models import model as M
from repro.optim.optimizers import make_optimizer, warmup_cosine

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    mode: str = "robust_dp"                    # robust_dp | gspmd
    agg: RobustAggConfig = RobustAggConfig()
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    attack: str = "none"
    n_malicious: int = 0
    multi_pod: bool = False
    donate: bool = True
    # FSDP-shard params + optimizer state over the data axes (stacked
    # layout only): costs one param all-gather per step at the grad
    # shard_map boundary, divides train-state HBM by the data size — the
    # change that lets >30B robust_dp archs hold Adam state at all
    # (EXPERIMENTS.md Section Perf, pair C).
    fsdp_params: bool = False
    # split each worker's local batch into m microbatches accumulated in a
    # scan: activation peak /m, gradient semantics identical (the
    # candidate gradient is the mean over its own microbatches).
    microbatches: int = 1

    def candidate_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    agg_state: Optional[AggState]
    step: Array


def _n_candidates(mesh: Mesh, tc: TrainConfig) -> int:
    n = mesh.shape["data"]
    if tc.multi_pod:
        n *= mesh.shape["pod"]
    return int(n)


def init_train_state(cfg: ArchConfig, tc: TrainConfig, key: Array,
                     mesh: Optional[Mesh] = None, abstract: bool = False) -> TrainState:
    """Materialize (or eval_shape when abstract=True) the train state."""
    opt = make_optimizer(cfg.optimizer)
    K = _n_candidates(mesh, tc) if mesh is not None else 1

    def build(key):
        params = M.init_params(cfg, key)
        opt_state = opt.init(params)
        agg_state = None
        if tc.mode == "robust_dp" and tc.agg.method in ("wfagg", "alt_wfagg") \
                and tc.agg.wfagg.use_temporal:
            if tc.agg.layout == "stacked":
                agg_state = init_tree_agg_state(tc.agg, K, params)
            else:
                agg_state = init_agg_state(tc.agg, K)
        return TrainState(params, opt_state, agg_state, jnp.zeros((), jnp.int32))

    if abstract:
        return jax.eval_shape(build, key)
    return build(key)


def state_shardings(cfg: ArchConfig, tc: TrainConfig, mesh: Mesh,
                    state_shape: TrainState) -> TrainState:
    """NamedShardings for the train state under the chosen mode."""
    data_axes = tc.candidate_axes()
    fsdp = tc.mode == "gspmd" or (tc.fsdp_params and tc.agg.layout == "stacked")
    pspecs = shd.param_specs(cfg, state_shape.params, fsdp=fsdp, data_axes=data_axes, mesh=mesh)
    # optimizer state mirrors param sharding where shapes match; replicate
    # the rest (Adafactor row/col factors, scalars).
    flat_p = {id(l): s for l, s in zip(jax.tree.leaves(state_shape.params),
                                       jax.tree.leaves(pspecs))}
    p_shapes = {tuple(l.shape): s for l, s in zip(jax.tree.leaves(state_shape.params),
                                                  jax.tree.leaves(pspecs))}

    def opt_spec(leaf):
        return p_shapes.get(tuple(leaf.shape), P())

    ospecs = jax.tree.map(opt_spec, state_shape.opt_state)
    if state_shape.agg_state is None:
        aspecs = None
    elif isinstance(state_shape.agg_state, TreeAggState):
        # prev: leading candidate axis over the data axes, inner dims keep
        # the param's TP sharding (shifted one dim right).
        prev_p = shd.param_specs(cfg, state_shape.params, fsdp=False,
                                 data_axes=data_axes, mesh=mesh)
        dax = data_axes if len(data_axes) > 1 else data_axes[0]
        prev_specs = jax.tree.map(lambda sp: P(dax, *tuple(sp)), prev_p)
        aspecs = TreeAggState(prev=prev_specs,
                              hist_s=P(), hist_b=P(), count=P(), t=P())
    else:
        aspecs = jax.tree.map(lambda _: P(), state_shape.agg_state)
    ns = lambda spec: NamedSharding(mesh, spec)
    return TrainState(
        params=jax.tree.map(ns, pspecs),
        opt_state=jax.tree.map(ns, ospecs),
        agg_state=jax.tree.map(ns, aspecs) if aspecs is not None else None,
        step=ns(P()),
    )


def batch_shardings(tc: TrainConfig, mesh: Mesh, batch_shape: Any) -> Any:
    specs = shd.batch_specs(batch_shape, data_axes=tc.candidate_axes(), mesh=mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, tc: TrainConfig, mesh: Mesh) -> Callable:
    """Returns jitted fn(state, batch) -> (state, metrics)."""
    opt = make_optimizer(cfg.optimizer)
    lr_fn = warmup_cosine(tc.lr, tc.warmup, tc.total_steps)
    axes = tc.candidate_axes()
    K = _n_candidates(mesh, tc)
    malicious = jnp.asarray(spaced_malicious(K, tc.n_malicious))
    rules = shd.activation_rules(tc.mode, tc.multi_pod)

    def loss_of(params, batch):
        if tc.attack == "label_flip":
            # data poisoning analog for LM batches: flip target ids
            batch = dict(batch, tokens=(cfg.vocab_size - 1) - batch["tokens"])
            # only malicious nodes flip; handled by caller via lax.cond-free
            # select in robust_dp mode (see _node_step)
        return M.loss_fn(cfg, params, batch)

    if tc.mode == "robust_dp":
        stacked = tc.agg.layout == "stacked"
        axis_spec = axes if len(axes) > 1 else axes[0]

        def _node_step(params, opt_state, agg_state, step, batch):
            # batch here is this node's LOCAL slice (manual over candidate axes)
            if tc.attack == "label_flip" and tc.n_malicious > 0:
                from repro.distributed.robust_allreduce import my_index
                me = my_index(axes)
                bad = malicious[me]
                batch = dict(
                    batch,
                    tokens=jnp.where(bad, (cfg.vocab_size - 1) - batch["tokens"],
                                     batch["tokens"]),
                )
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.loss_fn(cfg, p, batch), has_aux=True
            )(params)
            attacking = tc.attack not in ("none", "label_flip") and tc.n_malicious > 0
            akey = jax.random.fold_in(jax.random.PRNGKey(tc.agg.seed + 1), step)

            flat, unravel = ravel_pytree(grads)
            if attacking:
                flat = apply_distributed_attack(flat, axes, malicious,
                                                tc.attack, akey)
            agg_flat, new_agg, info = robust_allreduce(flat, axes, tc.agg,
                                                       agg_state)
            grads = unravel(agg_flat)
            gn = jnp.sqrt(jnp.sum(agg_flat.astype(jnp.float32) ** 2))
            lr = lr_fn(step)
            updates, new_opt = opt.update(grads, opt_state, params, lr)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
            mean_loss = jax.lax.pmean(loss, axes)
            out_metrics = {
                "loss": mean_loss,
                "lr": lr,
                "grad_norm": gn,
                "n_accepted": info.get("n_accepted", jnp.asarray(K)),
                "weights": info.get("weights", jnp.ones((K,), jnp.float32)),
            }
            return new_params, new_opt, new_agg, step + 1, out_metrics

        # ------------- layout='flat': the paper-shaped baseline -------------
        def flat_step_fn(state: TrainState, batch):
            has_agg = state.agg_state is not None
            agg_in = state.agg_state if has_agg else jnp.zeros((), jnp.float32)
            bspecs = shd.batch_specs(batch, data_axes=axes, mesh=mesh)

            def wrapped(params, opt_state, agg_state, step, batch):
                agg = agg_state if has_agg else None
                p, o, a, s, m = _node_step(params, opt_state, agg, step, batch)
                a = a if a is not None else jnp.zeros((), jnp.float32)
                return p, o, a, s, m

            out = shd.shard_map_compat(
                wrapped,
                mesh=mesh,
                in_specs=(P(), P(), P(), P(), bspecs),
                out_specs=(P(), P(), P(), P(), P()),
                axis_names=set(axes),
                check_vma=False,
            )(state.params, state.opt_state, agg_in, state.step, batch)
            p, o, a, s, m = out
            return TrainState(p, o, a if has_agg else None, s), m

        # --------- layout='stacked': sharded-gradient fast path -------------
        # shard_map computes ONLY per-worker (loss, grads), returned with a
        # leading candidate axis sharded over the data axes; attacks,
        # robust aggregation and the optimizer run OUTSIDE in pure GSPMD,
        # where every gradient leaf keeps its TP sharding (manual
        # collectives in partial-manual regions force auto-axis
        # replication — measured in EXPERIMENTS.md Section Perf).
        pspecs_tp = shd.param_specs(cfg, jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0))),
            fsdp=False, data_axes=axes, mesh=mesh)
        stacked_specs = jax.tree.map(lambda sp: P(axis_spec, *tuple(sp)), pspecs_tp)

        def grad_worker(params, step, batch):
            if tc.attack == "label_flip" and tc.n_malicious > 0:
                from repro.distributed.robust_allreduce import my_index
                me = my_index(axes)
                bad = malicious[me]
                batch = dict(
                    batch,
                    tokens=jnp.where(bad, (cfg.vocab_size - 1) - batch["tokens"],
                                     batch["tokens"]),
                )
            mb = tc.microbatches
            if mb > 1:
                batch_r = jax.tree.map(
                    lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                    batch)

                def one_mb(carry, mbatch):
                    acc, lsum = carry
                    (loss, _), g = jax.value_and_grad(
                        lambda p: M.loss_fn(cfg, p, mbatch), has_aux=True
                    )(params)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(a.dtype) / mb, acc, g)
                    return (acc, lsum + loss / mb), None

                acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
                (grads, loss), _ = jax.lax.scan(
                    one_mb, (acc0, jnp.zeros((), jnp.float32)), batch_r)
            else:
                (loss, _), grads = jax.value_and_grad(
                    lambda p: M.loss_fn(cfg, p, batch), has_aux=True
                )(params)
            return jax.tree.map(lambda g: g[None], grads), loss[None]

        def stacked_step_fn(state: TrainState, batch):
            has_agg = state.agg_state is not None
            bspecs = shd.batch_specs(batch, data_axes=axes, mesh=mesh)
            grads_stacked, losses = shd.shard_map_compat(
                grad_worker,
                mesh=mesh,
                in_specs=(P(), P(), bspecs),
                out_specs=(jax.tree.map(lambda _: P(axis_spec), state.params),
                           P(axis_spec)),
                axis_names=set(axes),
                check_vma=False,
            )(state.params, state.step, batch)
            # pin the stacked candidate layout: (K over data axes, TP inner)
            grads_stacked = jax.tree.map(
                lambda g, sp: jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, sp)),
                grads_stacked, stacked_specs)

            if tc.attack not in ("none", "label_flip") and tc.n_malicious > 0:
                akey = jax.random.fold_in(jax.random.PRNGKey(tc.agg.seed + 1),
                                          state.step)
                grads_stacked = apply_stacked_attack(
                    grads_stacked, malicious, tc.attack, akey)

            agg = state.agg_state if has_agg else None
            grads, new_agg, info = robust_allreduce_stacked(
                grads_stacked, tc.agg, agg)

            lr = lr_fn(state.step)
            updates, new_opt = opt.update(grads, state.opt_state, state.params, lr)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                      state.params, updates)
            gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                              for g in jax.tree.leaves(grads)))
            m = {
                "loss": jnp.mean(losses),
                "lr": lr,
                "grad_norm": gn,
                "n_accepted": info.get("n_accepted", jnp.asarray(K)),
                "weights": info.get("weights", jnp.ones((K,), jnp.float32)),
            }
            return TrainState(new_params, new_opt,
                              new_agg if has_agg else None,
                              state.step + 1), m

        step_fn = stacked_step_fn if stacked else flat_step_fn

        def jit_step(state, batch):
            with use_sharding(mesh, rules):
                return step_fn(state, batch)

        return jax.jit(jit_step, donate_argnums=(0,) if tc.donate else ())

    # ------------------------------ gspmd mode ------------------------------
    assert tc.agg.method == "mean", "gspmd mode supports mean aggregation only"

    def gspmd_step(state: TrainState, batch):
        with use_sharding(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.loss_fn(cfg, p, batch), has_aux=True
            )(state.params)
            lr = lr_fn(state.step)
            updates, new_opt = opt.update(grads, state.opt_state, state.params, lr)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state.params, updates)
            gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
            m = {"loss": loss, "lr": lr, "grad_norm": gn,
                 "n_accepted": jnp.asarray(K), "weights": jnp.ones((K,), jnp.float32)}
            return TrainState(new_params, new_opt, None, state.step + 1), m

    return jax.jit(gspmd_step, donate_argnums=(0,) if tc.donate else ())
