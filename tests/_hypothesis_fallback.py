"""Minimal stand-in for ``hypothesis`` when it is not installed.

The test-suite uses a small slice of the hypothesis API: ``@settings``,
``@given`` and the ``integers``/``floats`` strategies.  This shim replays
each property test over a fixed number of deterministically-seeded random
samples — far weaker than real hypothesis (no shrinking, no database, no
adaptive generation) but it keeps the property tests meaningful and the
suite collectable everywhere.  ``tests/conftest.py`` installs it into
``sys.modules`` only when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 - 1 if max_value is None else int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi))


def floats(min_value=None, max_value=None, allow_nan=True,
           allow_infinity=True, **_) -> _Strategy:
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise NotImplementedError(
            "the hypothesis fallback shim only supports keyword strategies")

    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in kw_strategies.items()}
                f(*args, **drawn, **kwargs)
        # hide the strategy-driven parameters from pytest's fixture
        # resolution (real hypothesis does the same)
        sig = inspect.signature(f)
        params = [p for name, p in sig.parameters.items()
                  if name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(f):
        # cap the replay count: without shrinking, extra examples buy
        # little coverage but cost jit retraces on shape-valued draws
        f._shim_max_examples = min(int(max_examples), _DEFAULT_EXAMPLES)
        return f

    return deco


def install() -> None:
    """Register this shim as the ``hypothesis`` package in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
