"""Subprocess body for the 8-virtual-device SPMD parity checks.

Run via ``python tests/_spmd_parity_main.py <mode>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the
environment (set BEFORE jax imports — hence the subprocess; the tier-1
suite itself runs on however many devices the session has).

Modes:
  round   one sharded gossip round vs wfagg_batch(fused_two_launch)
  scan    R sharded rounds (lax.scan inside shard_map, with temporal
          slot-history realignment) vs the same loop single-process
  stacked mode-B robust_allreduce_stacked(backend="reference") jitted
          over the (1, 8) mesh vs the unsharded call
  engine  two full DFL rounds (train + attack + gossip) with
          DFLConfig.mesh_model_shards=8 vs the single-process engine
  lint    python -m repro.analysis over the three sharded entries,
          in-process — must exit 0 (zero gate failures)
  gather_fire  the doctored replicated-output twin of the sharded
          round — the full-d all-gather GSPMD inserts MUST trip
          spmd-model-dim-allgather and spmd-collective-contract

Prints PARITY_OK:<mode> on success so the pytest wrapper can assert on
stdout rather than exit codes alone.
"""
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import wfagg as wf
from repro.distributed import spmd

N, K, D, ROUNDS, SEED = 10, 4, 50890, 3, 7


def _cfg():
    return wf.WFAggConfig(backend="fused_two_launch", f=1, window=3,
                          transient=1)


def _fixture(rng, rounds=1):
    models = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    # one Byzantine column so the filters actually reject something
    models = models.at[3].multiply(40.0)
    idx = np.stack([rng.choice(np.delete(np.arange(N), n), size=K,
                               replace=False) for n in range(N)])
    sched_idx = jnp.asarray(
        np.stack([np.roll(idx, r, axis=1) for r in range(rounds)]),
        dtype=jnp.int32)
    # degree churn: drop one slot per node in later rounds
    sched_valid = np.ones((rounds, N, K), dtype=bool)
    for r in range(1, rounds):
        sched_valid[r, np.arange(N), (np.arange(N) + r) % K] = False
    return models, sched_idx, jnp.asarray(sched_valid)


def _state(prev):
    return spmd.batched_matrix_state(N, K, D, _cfg().window)._replace(
        prev=prev)


def _close(name, a, b, atol=2e-4, rtol=2e-4):
    a, b = np.asarray(a), np.asarray(b)
    if not np.allclose(a, b, atol=atol, rtol=rtol):
        err = np.max(np.abs(a - b))
        raise SystemExit(f"parity FAIL [{name}]: max |diff| = {err}")


def check_round():
    cfg = _cfg()
    mesh = spmd.aggregation_mesh(8)
    rng = np.random.default_rng(SEED)
    models, sched_idx, _ = _fixture(rng)
    idx = sched_idx[0]
    state = _state(prev=models * 0.97)

    ref_out, ref_state, ref_info = wf.wfagg_batch(
        models, models, state, cfg, neighbor_idx=idx)
    out, new_state, info = spmd.wfagg_batch_sharded(
        models, models, state, cfg, idx, mesh=mesh)

    for m in ("mask_d", "mask_c", "mask_t"):
        if not np.array_equal(np.asarray(info[m]), np.asarray(ref_info[m])):
            raise SystemExit(f"parity FAIL [round {m}]: masks differ")
    _close("round weights", info["weights"], ref_info["weights"], atol=1e-6)
    _close("round out", out, ref_out)
    _close("round prev", new_state.prev, ref_state.prev)
    _close("round hist_s", new_state.hist_s, ref_state.hist_s, atol=1e-4)
    print("PARITY_OK:round")


def check_scan():
    cfg = _cfg()
    mesh = spmd.aggregation_mesh(8)
    rng = np.random.default_rng(SEED + 1)
    models, sched_idx, sched_valid = _fixture(rng, rounds=ROUNDS)
    state = _state(prev=models)

    # single-process reference: the same realign + round loop
    m_ref, st_ref = models, state
    prev_idx, prev_val = sched_idx[0], jnp.ones_like(sched_valid[0])
    for r in range(ROUNDS):
        idx, val = sched_idx[r], sched_valid[r]
        st_ref = wf.realign_temporal_history(st_ref, prev_idx, prev_val,
                                             idx, val)
        m_ref, st_ref, _ = wf.wfagg_batch(m_ref, m_ref, st_ref, cfg,
                                          neighbor_idx=idx, valid=val)
        prev_idx, prev_val = idx, val

    pad = spmd.pad_to_shards(models, 8)
    st_pad = state._replace(prev=spmd.pad_to_shards(state.prev, 8))
    m_sh, st_sh = spmd.wfagg_scan_sharded(pad, st_pad, cfg, sched_idx,
                                          sched_valid, mesh=mesh)
    _close("scan models", m_sh[..., :D], m_ref)
    _close("scan prev", st_sh.prev[..., :D], st_ref.prev)
    _close("scan hist_s", st_sh.hist_s, st_ref.hist_s, atol=1e-4)
    print("PARITY_OK:scan")


def check_stacked():
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K_, rng = 6, np.random.default_rng(SEED + 2)
    g = {"w": jnp.asarray(rng.normal(size=(K_, 24, 8)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(K_, 80)).astype(np.float32))}
    cfg = RobustAggConfig(method="wfagg", layout="stacked",
                          backend="reference",
                          wfagg=wf.WFAggConfig(f=1, transient=1, window=2))
    state = init_tree_agg_state(cfg, K_, jax.tree.map(lambda x: x[0], g))

    ref_out, ref_state, _ = jax.jit(
        lambda s, st: robust_allreduce_stacked(s, cfg, st))(g, state)

    mesh = spmd.aggregation_mesh(8)
    shardings = {"w": NamedSharding(mesh, P(None, None, "model")),
                 "b": NamedSharding(mesh, P(None, "model"))}
    repl = NamedSharding(mesh, P())
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, P(*s.spec[1:])),
                          shardings)
    # prev keeps the candidate axis -> shard like the stacked input
    st_sh = jax.tree.map(lambda _: repl, state)._replace(prev=shardings)
    fn = jax.jit(lambda s, st: robust_allreduce_stacked(s, cfg, st),
                 in_shardings=(shardings, st_sh),
                 out_shardings=(out_sh, st_sh, None))
    out, new_state, _ = fn(g, state)
    _close("stacked w", out["w"], ref_out["w"])
    _close("stacked b", out["b"], ref_out["b"])
    _close("stacked hist_s", new_state.hist_s, ref_state.hist_s, atol=1e-4)
    print("PARITY_OK:stacked")


def check_engine():
    from repro.core.topology import make_topology
    from repro.data.synthetic import SyntheticImages
    from repro.dfl import dynamics as dyn
    from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

    topo = make_topology(n_nodes=N, degree=K, n_malicious=2, kind="ring",
                         seed=0)
    data = SyntheticImages()
    sched = dyn.churn_schedule(topo, 2, seed=1)
    finals = []
    for shards in (0, 8):
        cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp",
                        wfagg_backend="fused_two_launch",
                        mesh_model_shards=shards)
        fn = build_round_fn(cfg, topo, data, dynamic=True)
        state = init_dfl_state(cfg, topo, degree=sched.width)
        for r in range(2):
            state = fn(state, jnp.asarray(sched.neighbor_idx[r]),
                       jnp.asarray(sched.valid[r]),
                       jnp.asarray(sched.malicious[r]))
        finals.append(state)
    ref, sh = finals
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref.node_params),
            jax.tree_util.tree_leaves_with_path(sh.node_params)):
        _close(f"engine params {jax.tree_util.keystr(path)}", b, a, atol=3e-4)
    _close("engine prev", sh.temporal.prev, ref.temporal.prev, atol=3e-4)
    print("PARITY_OK:engine")


def check_lint():
    from repro.analysis.__main__ import main as lint_main

    rc = lint_main(["--entry", "sharded_one_launch_round",
                    "--entry", "sharded_dynamic_scan",
                    "--entry", "sharded_stacked_mode_b"])
    if rc != 0:
        raise SystemExit(f"parity FAIL [lint]: exit code {rc}")
    print("PARITY_OK:lint")


def check_gather_fire():
    import dataclasses

    from repro.analysis.artifacts import Artifacts
    from repro.analysis.entry_points import entry_points
    from repro.analysis.rules import run_rules

    entry = entry_points()["sharded_one_launch_round"]
    cfg = _cfg()
    mesh = spmd.aggregation_mesh(8)
    d_pad = spmd.shard_padded_d(D, 8)
    fn, args = spmd.sharded_round_jit(cfg, mesh, n=N, k=K, d=d_pad,
                                      replicate_out=True)
    entry = dataclasses.replace(entry, build=lambda: (fn, args))
    findings = run_rules(Artifacts(fn, args), entry, {})
    fired = {f.rule for f in findings if f.severity == "error"}
    want = {"spmd-model-dim-allgather", "spmd-collective-contract"}
    if not want <= fired:
        raise SystemExit(f"parity FAIL [gather_fire]: expected {want} "
                         f"to fire on the replicated twin, got {fired}")
    print("PARITY_OK:gather_fire")


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "round"
    if len(jax.devices()) < 8:
        raise SystemExit("need 8 devices — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    {"round": check_round, "scan": check_scan, "stacked": check_stacked,
     "engine": check_engine, "lint": check_lint,
     "gather_fire": check_gather_fire}[mode]()
