"""Test-suite bootstrap.

Installs the deterministic ``hypothesis`` fallback shim when the real
package is absent (see _hypothesis_fallback.py), so the property tests
collect and run everywhere, and registers the ``slow`` marker.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests (trainer loops)")
