"""Adaptive (defense-aware) adversaries + topology attacks + the
robustness gate: band_rider's sent models must land verifiably inside
the WFAgg-T acceptance bands it rides, min_max must sit under the
distance-filter radii, eclipse/dos/collusion schedules must be
deterministic and mask-consistent, all three WFAgg backends must agree
under every adaptive attack, the baseline aggregators must run dynamic
schedules through their valid-mask-aware variants, and
scripts/robustness_gate.py must reject a doctored run (mean passed off
as wfagg under IPM)."""
import importlib.util
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg_lib
from repro.core import attacks as atk
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl import dynamics as dyn
from repro.dfl import engine as eng

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
ATOL = 3e-5


def _close_topo(n=10, degree=4, n_mal=2, seed=0):
    return make_topology(n_nodes=n, degree=degree, n_malicious=n_mal,
                         kind="ring", seed=seed, placement="close")


# ---------------------------------------------------------------------------
# band_rider: in-band by construction
# ---------------------------------------------------------------------------

def test_band_rider_inside_temporal_bands():
    """Run the real engine past the WFAgg-T transient, then replay the
    attack step by hand: every (benign victim, malicious sender) edge
    with an active band must see the attacker's sent model INSIDE the
    band — s_t and b_t both — because the attack solved for exactly
    that.  Also: the ride must be a real deviation (not the attacker's
    own previous model)."""
    topo = _close_topo()
    data = SyntheticImages(seed=0)
    cfg = eng.DFLConfig(aggregator="wfagg", attack="band_rider",
                        model="mlp", seed=0, batches_per_round=1)
    state = eng.init_dfl_state(cfg, topo)
    round_fn = eng.build_round_fn(cfg, topo, data)
    for _ in range(6):                      # transient=3: bands active now
        state = round_fn(state)

    mal = jnp.asarray(topo.malicious)
    nidx = jnp.asarray(topo.neighbor_indices)
    params, _ = eng._local_train(cfg, data, mal, state.node_params,
                                 state.node_momentum, state.rnd)
    flat, _ = eng._ravel_nodes(params)
    view = eng._defense_view(cfg, state, nidx, None)
    assert view is not None and view.tbands is not None
    attacked = np.asarray(eng._apply_attacks(cfg, mal, flat, state.rnd, view))

    tb = np.asarray(view.tbands).reshape(topo.n_nodes, 4, -1)
    prev = np.asarray(view.prev)
    malv = np.asarray(topo.malicious)
    idx = np.asarray(topo.neighbor_indices)
    checked = 0
    for n in range(topo.n_nodes):
        if malv[n]:
            continue
        for k in range(idx.shape[1]):
            j = idx[n, k]
            lo_d, hi_d, lo_c, hi_c = tb[n, :, k]
            if not malv[j] or not np.isfinite(hi_d):
                continue
            p, c = prev[j], attacked[j]
            s = float(((c - p) ** 2).sum())
            b = 1.0 - float((c * p).sum()
                            / max(np.linalg.norm(c) * np.linalg.norm(p),
                                  1e-12))
            tol_d = 1e-3 * max(1.0, abs(hi_d))
            assert lo_d - tol_d <= s <= hi_d + tol_d, (n, k, s, lo_d, hi_d)
            assert lo_c - 1e-4 <= b <= hi_c + 1e-4, (n, k, b, lo_c, hi_c)
            assert s > 0.0                  # a ride, not a replay
            checked += 1
    assert checked > 0                      # bands were actually active


def test_band_rider_falls_back_without_view():
    """No DefenseView (or a bandless one) -> ALIE-style mimicry from the
    benign cohort, never NaNs."""
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    mal = jnp.asarray(np.array([1, 0, 0, 0, 1, 0, 0, 0], bool))
    cfg = atk.AttackConfig(name="band_rider")
    for view in (None, atk.DefenseView(prev=u)):
        out = np.asarray(atk.apply_matrix_attack(
            "band_rider", u, mal, jax.random.PRNGKey(0), cfg, view=view))
        assert np.isfinite(out).all()
        ben = np.asarray(u)[~np.asarray(mal)]
        expect = ben.mean(0) - cfg.alie_zmax * ben.std(0)
        assert np.allclose(out[0], expect, atol=1e-5)
        assert np.allclose(out[4], expect, atol=1e-5)
        # benign rows untouched
        assert np.array_equal(out[1], np.asarray(u)[1])


def test_min_max_under_filter_radii():
    """The min_max deviation must keep the attacked model within the max
    pairwise benign distance of EVERY benign model (the Krum/Multi-Krum
    acceptance region) and within the benign radius around the
    coordinate median (WFAgg-D's region) — and still deviate."""
    rng = np.random.default_rng(7)
    u = jnp.asarray(rng.normal(size=(10, 64)).astype(np.float32))
    mal = jnp.asarray((np.arange(10) < 3))
    out = np.asarray(atk.apply_matrix_attack(
        "min_max", u, mal, jax.random.PRNGKey(0)))
    ben = np.asarray(u)[3:]
    c = out[0]
    assert np.array_equal(out[0], out[1])   # colluders send one model
    dmax = max(np.linalg.norm(a - b) for a in ben for b in ben)
    assert max(np.linalg.norm(c - b) for b in ben) <= dmax + 1e-3
    med = np.median(ben, axis=0)
    rmed = max(np.linalg.norm(b - med) for b in ben)
    assert np.linalg.norm(c - med) <= rmed + 1e-3
    mu = ben.mean(0)
    assert np.linalg.norm(c - mu) > 0.1 * dmax      # it actually deviates


# ---------------------------------------------------------------------------
# topology attacks
# ---------------------------------------------------------------------------
# (determinism / symmetry / padding invariants and the end-to-end runs
# are covered for ALL scenarios — including these — by the parametrized
# tests in test_dynamics.py; here: the attack SEMANTICS.)

def test_eclipse_monopolizes_victim_slate():
    topo = _close_topo()
    sched = dyn.make_schedule("eclipse", topo, 4, seed=0)
    mal = topo.malicious
    adj = sched.adjacency[-1]
    eclipsed = [n for n in range(topo.n_nodes)
                if not mal[n] and adj[n].sum() > 0
                and adj[n][mal].sum() == adj[n].sum()]
    assert len(eclipsed) == 1               # exactly one victim, fully
    v = eclipsed[0]
    assert adj[v].sum() == mal.sum()        # every attacker points at it
    # valid slots of the victim row reference only malicious senders
    senders = sched.neighbor_idx[-1, v][sched.valid[-1, v]]
    assert mal[senders].all()
    # everyone else's slate is unchanged from the base graph
    others = [n for n in range(topo.n_nodes) if n != v]
    base = topo.adjacency.copy()
    assert np.array_equal(adj[np.ix_(others, others)],
                          base[np.ix_(others, others)])
    # start > 0 delays the attack
    late = dyn.make_schedule("eclipse", topo, 4, seed=0, start=2)
    assert np.array_equal(late.adjacency[1], base)
    assert np.array_equal(late.adjacency[2], adj)


def test_dos_window_silences_victim_then_restores():
    topo = _close_topo()
    sched = dyn.make_schedule("dos", topo, 6, seed=0)   # window [2, 4)
    base_deg = topo.adjacency.sum(1)
    degs = sched.adjacency.sum(2)
    down = (degs == 0).any(axis=1)
    assert list(down) == [False, False, True, True, False, False]
    victim = int(np.flatnonzero(degs[2] == 0)[0])
    assert not topo.malicious[victim]
    # during the window the victim's padded row is all-invalid and
    # self-referential (the degree-0 local-fallback contract)
    assert not sched.valid[2, victim].any()
    assert (sched.neighbor_idx[2, victim] == victim).all()
    # outside the window the base graph is fully restored
    assert np.array_equal(sched.adjacency[0], topo.adjacency)
    assert np.array_equal(sched.adjacency[5], topo.adjacency)
    assert (degs[2] == np.where(np.arange(topo.n_nodes) == victim, 0,
                                base_deg - topo.adjacency[victim])).all()


def test_collusion_concentrates_attackers():
    topo = make_topology(n_nodes=12, degree=4, n_malicious=3, kind="ring",
                         seed=1, placement="spaced")
    sched = dyn.make_schedule("collusion", topo, 3, seed=0)
    mal = topo.malicious
    adj = sched.adjacency[0]
    att = np.flatnonzero(mal)
    # static across rounds; attackers share IDENTICAL victim sets,
    # no attacker-attacker edges
    assert all(np.array_equal(sched.adjacency[r], adj) for r in range(3))
    victims = np.flatnonzero(adj[att[0]])
    for a in att[1:]:
        assert np.array_equal(np.flatnonzero(adj[a]), victims)
    assert not adj[np.ix_(att, att)].any()
    assert not mal[victims].any()
    # each shared victim sees EVERY attacker — the concentration the
    # spaced placement was supposed to rule out
    for v in victims:
        assert adj[v][mal].sum() == len(att)
    # malicious mask rides through unchanged
    assert (sched.malicious == mal[None, :]).all()


# ---------------------------------------------------------------------------
# 3-backend parity under the adaptive attacks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attack", atk.ADAPTIVE_ATTACKS + ("ipm",))
def test_backend_parity_under_adaptive_attacks(attack):
    """fused / fused_two_launch / reference must produce the same models
    under each adaptive attack (and the newly-registered generic "ipm"):
    the DefenseView is built from shared state, so any backend skew
    would compound round over round."""
    topo = _close_topo(n=8, degree=4, n_mal=2)
    data = SyntheticImages(seed=0)
    sched = dyn.make_schedule("eclipse", topo, 3, seed=2)
    finals = {}
    for backend in ("fused", "fused_two_launch", "reference"):
        cfg = eng.DFLConfig(aggregator="wfagg", attack=attack, model="mlp",
                            seed=0, batches_per_round=1,
                            wfagg_backend=backend)
        out = eng.run_dynamic_experiment(cfg, topo, data, sched, n_test=64)
        finals[backend] = np.asarray(out["final"]["acc_all"])
    assert np.allclose(finals["fused"], finals["fused_two_launch"],
                       atol=ATOL)
    assert np.allclose(finals["fused"], finals["reference"], atol=1e-3)


# ---------------------------------------------------------------------------
# dynamic baselines (valid-mask-aware aggregators through the engine)
# ---------------------------------------------------------------------------

def test_dyn_aggregators_match_static_when_all_valid():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))
    valid = jnp.ones((8,), bool)
    for name, fn in agg_lib.DYN_AGGREGATORS.items():
        a_s, m_s = agg_lib.AGGREGATORS[name](u, f=2, m=2, beta=0.1)
        a_d, m_d = fn(u, valid, f=2, m=2, beta=0.1)
        assert np.allclose(np.asarray(a_s), np.asarray(a_d), atol=1e-5), name
        assert np.asarray(m_d).dtype == bool


def test_dyn_aggregators_ignore_invalid_slots():
    """Dyn result on a padded slate == static result on the compacted
    valid subset (padding rows carry garbage on purpose)."""
    rng = np.random.default_rng(1)
    u = np.asarray(rng.normal(size=(8, 24)), np.float32)
    u[2] = 1e6                              # garbage in invalid slots
    u[5] = -1e6
    valid = np.array([1, 1, 0, 1, 1, 0, 1, 1], bool)
    sub = jnp.asarray(u[valid])
    uj, vj = jnp.asarray(u), jnp.asarray(valid)
    for name in ("mean", "median", "trimmed_mean", "krum", "clustering"):
        a_d, m_d = agg_lib.DYN_AGGREGATORS[name](uj, vj, f=1, beta=0.1)
        a_s, _ = agg_lib.AGGREGATORS[name](sub, f=1, beta=0.1)
        assert np.allclose(np.asarray(a_d), np.asarray(a_s), atol=1e-4), name
        assert not np.asarray(m_d)[~valid].any(), name


@pytest.mark.parametrize("aggregator", ("median", "multi_krum", "clustering"))
def test_dynamic_experiment_runs_baseline_aggregators(aggregator):
    """The lifted restriction end to end: baselines under a dynamic
    schedule with degree-0 rounds — finite models, sane accuracy."""
    topo = _close_topo()
    data = SyntheticImages(seed=0)
    cfg = eng.DFLConfig(aggregator=aggregator, attack="ipm_100",
                        model="mlp", seed=0, batches_per_round=1)
    sched = dyn.make_schedule("dos", topo, 4, seed=1)
    out = eng.run_dynamic_experiment(cfg, topo, data, sched, n_test=64)
    accs = np.asarray(out["final"]["acc_all"])
    assert np.isfinite(accs).all()
    assert 0.0 <= out["final"]["acc_benign_mean"] <= 1.0
    assert len(out["trace"]) == 4


# ---------------------------------------------------------------------------
# the gate
# ---------------------------------------------------------------------------

def _load_gate_module():
    spec = importlib.util.spec_from_file_location(
        "robustness_gate", os.path.join(REPO, "scripts",
                                        "robustness_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_gate_rejects_mean_substituted_for_wfagg():
    """The ISSUE's self-test contract: substituting mean's cells for
    wfagg's under ipm_100 must fail the gate, and the committed baseline
    must pass against itself."""
    gate = _load_gate_module()
    with open(os.path.join(REPO, "benchmarks",
                           "BENCH_robustness.json")) as f:
        baseline = json.load(f)
    assert gate.compare(baseline, baseline["cells"]) == []
    doctored = dict(baseline["cells"])
    for scenario in baseline["meta"]["scenarios"]:
        doctored[f"ipm_100|{scenario}|wfagg"] = \
            doctored[f"ipm_100|{scenario}|mean"]
    failures = gate.compare(baseline, doctored)
    assert failures                          # per-cell acc regression
    assert any("wfagg" in f for f in failures)
    # the structural wfagg-holds-on-static claim fires too
    assert any("robustness claim" in f for f in failures)
    # a dropped cell is a failure, not a silent pass
    partial = dict(baseline["cells"])
    partial.pop(next(iter(partial)))
    assert any("missing cell" in f
               for f in gate.compare(baseline, partial))


def test_gate_cli_self_test():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts",
                                      "robustness_gate.py"), "--self-test"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "robustness_gate self-test: OK" in proc.stdout


def test_attack_names_single_source():
    """Every attack-choice surface derives from ATTACK_NAMES."""
    assert "ipm" in atk.ATTACK_NAMES
    assert set(atk.ADAPTIVE_ATTACKS) <= set(atk.ATTACK_NAMES)
    from benchmarks.robustness_matrix import (DEFAULT_ATTACKS, GATE_GRID,
                                              SMOKE_GRID)
    assert set(DEFAULT_ATTACKS) <= set(atk.ATTACK_NAMES)
    assert set(GATE_GRID["attacks"]) <= set(atk.ATTACK_NAMES)
    assert set(SMOKE_GRID["attacks"]) <= set(atk.ATTACK_NAMES)
    from benchmarks.table1_attacks import ATTACKS, FAST_ATTACKS
    assert set(ATTACKS) <= set(atk.ATTACK_NAMES)
    assert set(FAST_ATTACKS) <= set(atk.ATTACK_NAMES)
