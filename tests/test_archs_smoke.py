"""Per-architecture smoke tests: REDUCED variant of each assigned arch
(2 layers, d_model<=256, <=4 experts) runs one robust-dp train step and
one decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.wfagg import WFAggConfig
from repro.data.specs import ENC_LEN_DECODE, dummy_batch
from repro.distributed.robust_allreduce import RobustAggConfig
from repro.models import model as M
from repro.train.trainer import TrainConfig, build_train_step, init_train_state

ARCH_NAMES = sorted(ARCHS)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_train_step(name):
    cfg = ARCHS[name].reduced()
    mesh = _mesh()
    tc = TrainConfig(
        mode="robust_dp",
        lr=1e-3,
        agg=RobustAggConfig(method="mean", chunk_size=4096,
                            wfagg=WFAggConfig(use_temporal=False)),
    )
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0), mesh)
    step = build_train_step(cfg, tc, mesh)
    batch = dummy_batch(cfg, 2, 32)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    for leaf in jax.tree.leaves(state.params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_decode_step(name):
    cfg = ARCHS[name].reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, total = 2, 16
    cache = M.init_cache(cfg, B, total,
                         enc_len=ENC_LEN_DECODE if cfg.is_encoder_decoder else 0)
    if cfg.is_encoder_decoder:
        cache["enc_out"] = 0.1 * jnp.ones_like(cache["enc_out"])
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
    step = jax.jit(lambda c, t: M.decode_step(cfg, params, c, t))
    for _ in range(3):
        logits, cache = step(cache, toks)
    assert logits.shape == (B, 1, cfg.vocab_size), name
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
    assert int(cache["idx"]) == 3


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_metadata(name):
    """The FULL configs are exercised only via the dry-run; here we check
    the analytic parameter counts are in the advertised ballpark."""
    cfg = ARCHS[name]
    n = cfg.param_count()
    expected = {
        "moonshot-v1-16b-a3b": (10e9, 40e9),
        "stablelm-3b": (2e9, 4e9),
        "zamba2-1.2b": (0.8e9, 1.8e9),
        "arctic-480b": (400e9, 520e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "yi-6b": (5e9, 7e9),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "llava-next-34b": (30e9, 38e9),
    }[name]
    assert expected[0] <= n <= expected[1], (name, n)
