"""Chaos transport: fault schedules must be deterministic, the
sanitizer must keep every WFAgg backend finite when any single payload
is corrupted, transport re-keying must obey the staleness budget, a
fault-free fault schedule must reproduce the clean scan bit-exactly,
telemetry must not perturb trajectories, and kill-and-resume must equal
the uninterrupted run bit-for-bit (docs/FAULTS.md)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wfagg as wf
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl import dynamics as dyn
from repro.dfl import faults as flt
from repro.dfl.engine import DFLConfig, run_dynamic_experiment
from repro.obs.decision import FAULT_BITS


def _topo(n=10, degree=4, n_mal=2, seed=0):
    return make_topology(n_nodes=n, degree=degree, n_malicious=n_mal,
                         kind="ring", placement="close", seed=seed)


def _ring_idx(N, K):
    return jnp.asarray(
        [[(n + j + 1) % N for j in range(K)] for n in range(N)],
        jnp.int32)


def _matrix_state(N, K, d, window):
    return wf.TemporalState(
        prev=jnp.zeros((N, d)), hist_s=jnp.zeros((N, window, K)),
        hist_b=jnp.zeros((N, window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", flt.FAULT_NAMES)
def test_fault_schedules_deterministic_and_shaped(name):
    """Same (name, shape, intensity, seed) -> byte-identical schedule;
    shapes track the topology schedule; lags never exceed the ring."""
    topo = _topo()
    sched = dyn.make_schedule("churn", topo, 5, seed=1)
    f1 = flt.make_fault_schedule(name, sched, 0.4, seed=7)
    f2 = flt.make_fault_schedule(name, sched, 0.4, seed=7)
    for field in ("drop", "lag", "dup", "corrupt", "down"):
        assert np.array_equal(getattr(f1, field), getattr(f2, field)), field
    R, N, K = sched.rounds, sched.n_nodes, sched.width
    assert f1.drop.shape == (R, N, K) and f1.down.shape == (R, N)
    assert f1.rounds == R
    assert f1.lag.min() >= 0 and f1.lag.max() <= f1.config.ring_depth
    summary = f1.summary()
    if name == "none":
        assert all(v == 0 for v in summary.values())
    elif name != "stale":  # stale only schedules lags
        assert any(v > 0 for v in summary.values()), summary


def test_make_faulty_schedule_pairs_and_unknown_name():
    topo = _topo()
    sched, fs = dyn.make_faulty_schedule("churn", topo, 4, fault="drop",
                                         intensity=0.3, seed=2, fault_seed=3)
    assert fs.rounds == sched.rounds
    assert fs.drop.shape == (4, topo.n_nodes, sched.width)
    with pytest.raises(ValueError, match="unknown fault"):
        flt.make_fault_schedule("nope", sched, 0.1)


# ---------------------------------------------------------------------------
# sanitizer: every backend finite under a corrupted payload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["fused", "fused_two_launch",
                                     "reference"])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_sanitizer_every_backend_finite(backend, bad):
    """One non-finite candidate row must never reach the coordinate-wise
    median / mean-fallback paths: the sanitizer demotes the edges that
    read it BEFORE filter statistics, and the aggregate plus the carried
    temporal state stay finite on all three backends."""
    N, K, d = 8, 4, 300
    cfg = wf.WFAggConfig(backend=backend, transient=1, window=2)
    idx = _ring_idx(N, K)
    valid = jnp.ones((N, K), bool)
    st = _matrix_state(N, K, d, cfg.window)
    for r in range(3):
        local = np.array(
            jax.random.normal(jax.random.PRNGKey(40 + r), (N, d)) + 0.3,
            np.float32)
        u = local.copy()
        if r == 1:
            # node 2's TRANSMITTED payload arrives bit-damaged (its own
            # local copy is fine — corruption is a transport event)
            u[2, :] = bad
        out, st, info = wf.wfagg_batch(jnp.asarray(local), jnp.asarray(u),
                                       st, cfg, neighbor_idx=idx,
                                       valid=valid)
        assert np.isfinite(np.asarray(out)).all(), (backend, bad, r)
        assert np.isfinite(np.asarray(st.prev)).all(), (backend, bad, r)
        assert np.isfinite(np.asarray(info["weights"])).all()
        if r == 1:
            # every edge reading the corrupted row was demoted
            demoted = np.asarray(idx) == 2
            w = np.asarray(info["weights"])
            assert (w[demoted] == 0).all(), (backend, bad)


def test_sanitizer_static_reference_path_finite():
    """The valid=None per-node reference dispatch (a different code
    path) also never lets a NaN candidate through to the aggregate."""
    N, K, d = 6, 4, 200
    cfg = wf.WFAggConfig(backend="reference", transient=1, window=2)
    idx = _ring_idx(N, K)
    local = np.array(jax.random.normal(jax.random.PRNGKey(3), (N, d)) + 0.2,
                     np.float32)
    u = local.copy()
    u[1, :] = np.nan
    out, _, info = wf.wfagg_batch(jnp.asarray(local), jnp.asarray(u),
                                  _matrix_state(N, K, d, cfg.window), cfg,
                                  neighbor_idx=idx)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(info["weights"])).all()


def test_sanitizer_off_reproduces_the_bug():
    """With the guard disabled the NaN propagates — proof the sanitizer
    (not luck) is what keeps the aggregate finite."""
    N, K, d = 8, 4, 200
    cfg = wf.WFAggConfig(backend="reference", use_temporal=False,
                         sanitize=False)
    local = np.array(jax.random.normal(jax.random.PRNGKey(4), (N, d)) + 0.2,
                     np.float32)
    u = local.copy()
    u[2, :] = np.nan
    out, _, _ = wf.wfagg_batch(jnp.asarray(local), jnp.asarray(u), None, cfg,
                               neighbor_idx=_ring_idx(N, K),
                               valid=jnp.ones((N, K), bool))
    assert not np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("aggregator", ["mean", "median", "wfagg"])
def test_engine_finite_under_corruption(aggregator):
    """End-to-end: a corrupt-heavy fault schedule through the one-jit
    chaos scan leaves every aggregator's accuracy series finite — the
    transport sanitizer guards the baseline (mean / coordinate-median)
    paths too, not just WFAgg's filter bank."""
    topo = _topo()
    data = SyntheticImages(seed=0)
    sched, fs = dyn.make_faulty_schedule("churn", topo, 3, fault="corrupt",
                                         intensity=0.5, seed=1, fault_seed=2)
    cfg = DFLConfig(aggregator=aggregator, attack="none", model="mlp",
                    batches_per_round=1)
    out = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                 faults=fs)
    series = np.asarray(out["series"]["acc_benign_mean"])
    assert np.isfinite(series).all()
    assert np.isfinite(out["final"]["acc_benign_mean"])
    assert out["faults"]["corrupt_rate"] > 0


# ---------------------------------------------------------------------------
# transport semantics
# ---------------------------------------------------------------------------

def test_apply_transport_rekeys_and_budgets():
    """Unit semantics of the stacked-ring re-keying: fresh edges read the
    flat block, scheduled lags read the ring block, corrupt edges read
    the bank, drops fall back to an aged redelivery, and a lag beyond
    the staleness budget (or a down receiver) demotes the edge."""
    M, K, d = 6, 3, 16
    cfg = flt.FaultConfig(ring_depth=2, staleness_budget=1, bank_size=4,
                          max_lag=2)
    idx = _ring_idx(M, K)
    valid = jnp.ones((M, K), bool)
    flat = jnp.ones((M, d), jnp.float32)
    ts = flt.TransportState(
        ring=2.0 * jnp.ones((cfg.ring_depth, M, d), jnp.float32),
        served_lag=jnp.zeros((M, K), jnp.int32))
    drop = jnp.zeros((M, K), bool).at[0, 0].set(True)
    lag = jnp.zeros((M, K), jnp.int32).at[1, 1].set(1).at[2, 2].set(2)
    corrupt = jnp.zeros((M, K), bool).at[3, 0].set(True)
    down = jnp.zeros((M,), bool).at[4].set(True)
    fr = flt.FaultRound(drop=drop, lag=lag, dup=jnp.zeros((M, K), bool),
                        corrupt=corrupt, down=down)
    out = flt.apply_transport(flat, ts, idx, valid, fr, cfg,
                              jnp.asarray(5, jnp.int32))

    eff_idx = np.asarray(out.eff_idx)
    eff_valid = np.asarray(out.eff_valid)
    nidx = np.asarray(idx)
    # fresh edge: reads the flat block at the neighbor's row
    assert eff_idx[5, 0] == nidx[5, 0] and eff_valid[5, 0]
    # dropped edge: re-serves last delivery aged to lag 1 (within budget)
    assert eff_idx[0, 0] == 1 * M + nidx[0, 0]
    assert eff_valid[0, 0] and out.dropped[0, 0] and out.stale[0, 0]
    # scheduled lag 1: ring block, still valid, flagged stale
    assert eff_idx[1, 1] == 1 * M + nidx[1, 1] and eff_valid[1, 1]
    assert out.stale[1, 1]
    # scheduled lag 2: beyond staleness_budget=1 -> demoted, not served
    assert not eff_valid[2, 2] and out.dropped[2, 2]
    # corrupt edge: re-keyed into the bank block past the ring
    assert eff_idx[3, 0] >= (cfg.ring_depth + 1) * M
    assert out.corrupt[3, 0]
    # down receiver loses its whole slate
    assert not eff_valid[4].any()
    # the sanitized stacked matrix is finite everywhere
    assert np.isfinite(np.asarray(out.full)).all()
    # sender crash: every edge READING a down sender is a drop
    sender_down = np.asarray(down)[nidx]
    assert np.asarray(out.dropped)[sender_down].all()


def test_served_lag_walks_the_ring_until_budget():
    """Consecutive drops on one edge re-age the last delivery round over
    round; the edge stays valid while within budget, then demotes."""
    M, K, d = 4, 2, 8
    cfg = flt.FaultConfig(ring_depth=3, staleness_budget=2, max_lag=2)
    idx = _ring_idx(M, K)
    valid = jnp.ones((M, K), bool)
    flat = jnp.ones((M, d), jnp.float32)
    ts = flt.init_transport_state(cfg, M, K, d)
    zeros = jnp.zeros((M, K), bool)
    fr = flt.FaultRound(drop=jnp.ones((M, K), bool),
                        lag=jnp.zeros((M, K), jnp.int32), dup=zeros,
                        corrupt=zeros, down=jnp.zeros((M,), bool))
    lags, valids = [], []
    for rnd in range(4):
        out = flt.apply_transport(flat, ts, idx, valid, fr, cfg,
                                  jnp.asarray(rnd + 10, jnp.int32))
        lags.append(int(np.asarray(out.served_lag)[0, 0]))
        valids.append(bool(np.asarray(out.eff_valid)[0, 0]))
        ts = flt.advance_ring(ts, flat, out.served_lag)
    assert lags == [1, 2, 3, 3]          # ages until the ring depth caps it
    assert valids == [True, True, False, False]  # budget=2 demotes at lag 3


# ---------------------------------------------------------------------------
# equivalences: fault-none == clean, telemetry changes nothing
# ---------------------------------------------------------------------------

def test_fault_none_equals_clean_scan():
    """An all-quiet fault schedule through the chaos scan reproduces the
    clean scan bit-exactly — the transport layer at rest is a no-op."""
    topo = _topo()
    data = SyntheticImages(seed=0)
    sched = dyn.make_schedule("churn", topo, 4, seed=1)
    cfg = DFLConfig(aggregator="wfagg", attack="alie", model="mlp",
                    batches_per_round=1)
    clean = run_dynamic_experiment(cfg, topo, data, sched, n_test=64)
    quiet = run_dynamic_experiment(
        cfg, topo, data, sched, n_test=64,
        faults=flt.make_fault_schedule("none", sched, 0.0))
    assert np.array_equal(np.asarray(clean["series"]["acc_benign_mean"]),
                          np.asarray(quiet["series"]["acc_benign_mean"]))
    assert clean["final"]["acc_benign_mean"] == quiet["final"]["acc_benign_mean"]


def test_chaos_telemetry_off_trajectory_identical():
    """Fault attribution is observation, not intervention: the same
    chaos run with and without the decision plane yields bit-identical
    accuracy series, and with it on, the verdict carries fault bits."""
    topo = _topo()
    data = SyntheticImages(seed=0)
    sched, fs = dyn.make_faulty_schedule("churn", topo, 4, fault="chaos",
                                         intensity=0.5, seed=1, fault_seed=3)
    cfg = DFLConfig(aggregator="wfagg", attack="alie", model="mlp",
                    batches_per_round=1)
    silent = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                    faults=fs)
    loud = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                  faults=fs, telemetry=True)
    assert np.array_equal(np.asarray(silent["series"]["acc_benign_mean"]),
                          np.asarray(loud["series"]["acc_benign_mean"]))
    verdict = np.asarray(loud["telemetry"]["verdict"])
    fault_bits = ((verdict >> FAULT_BITS["dropped"])
                  | (verdict >> FAULT_BITS["stale"])
                  | (verdict >> FAULT_BITS["corrupt"])) & 1
    assert fault_bits.any()

    from repro.obs import report as obs_report
    frates = obs_report.fault_rates(verdict)
    attr = obs_report.fault_attribution(frates)
    assert attr["dominant"] in ("dropped", "stale", "corrupt")
    # and a clean run's verdict carries NO fault bits
    clean = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                   telemetry=True)
    cv = np.asarray(clean["telemetry"]["verdict"])
    assert not obs_report.fault_rates(cv)["any"].any()


# ---------------------------------------------------------------------------
# crash-exact kill-and-resume
# ---------------------------------------------------------------------------

def test_kill_and_resume_bit_exact(tmp_path):
    """Stop a chaos run mid-schedule, snapshot, restore, finish: the
    stitched trajectory equals the uninterrupted one bit-for-bit —
    models, WFAgg-T ring buffers, transport ring and the in-flight fault
    schedules all survive the round trip (train/checkpoint.py)."""
    topo = _topo()
    data = SyntheticImages(seed=0)
    R, stop = 6, 3
    sched, fs = dyn.make_faulty_schedule("churn", topo, R, fault="chaos",
                                         intensity=0.4, seed=1, fault_seed=3)
    cfg = DFLConfig(aggregator="wfagg", attack="alie", model="mlp",
                    batches_per_round=1)
    full = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                  faults=fs)
    ckpt_dir = str(tmp_path / "snap")
    part = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                  faults=fs, stop_after=stop,
                                  checkpoint_dir=ckpt_dir)
    assert part["rounds_run"] == [0, stop]
    resumed = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                     faults=fs, resume_from=ckpt_dir)
    assert resumed["rounds_run"] == [stop, R]

    full_series = np.asarray(full["series"]["acc_benign_mean"])
    stitched = np.concatenate([
        np.asarray(part["series"]["acc_benign_mean"]),
        np.asarray(resumed["series"]["acc_benign_mean"])])
    assert np.array_equal(full_series, stitched)
    assert full["final"]["acc_benign_mean"] == resumed["final"]["acc_benign_mean"]
    assert full["final"]["r_squared"] == resumed["final"]["r_squared"]


def test_checkpoint_requires_faults_and_metadata(tmp_path):
    topo = _topo()
    data = SyntheticImages(seed=0)
    sched = dyn.make_schedule("churn", topo, 3, seed=1)
    cfg = DFLConfig(aggregator="wfagg", attack="none", model="mlp")
    with pytest.raises(NotImplementedError, match="chaos scan"):
        run_dynamic_experiment(cfg, topo, data, sched, stop_after=1)
    from repro.train import checkpoint as ckpt
    with pytest.raises(ValueError, match="round"):
        ckpt.save_experiment_checkpoint(str(tmp_path), "x",
                                        {"a": jnp.zeros(2)}, [jnp.zeros(2)])
