"""Dynamic-topology scenario engine: schedule generators must be
deterministic and self-consistent, degree-0 (churned-out) nodes must
fall back to their local model without NaNs, and the one-jit dynamic
round must (a) never retrace as the graph changes, (b) stay
(N, K, d)-free in HLO, and (c) match the per-node reference pipeline
under a churn schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wfagg as wf
from repro.core.topology import (
    make_topology, padded_neighbor_table, schedule_from_adjacencies,
    static_schedule)
from repro.data.synthetic import SyntheticImages
from repro.dfl import dynamics as dyn
from repro.dfl.engine import (
    DFLConfig, build_round_fn, init_dfl_state, run_dynamic_experiment,
    run_experiment)
from repro.kernels.robust_stats.ops import robust_stats_indexed
from repro.kernels.robust_stats.ref import robust_stats_indexed_ref

ATOL = 2e-5


def _topo(n=10, degree=4, n_mal=2, kind="ring", seed=0):
    return make_topology(n_nodes=n, degree=degree, n_malicious=n_mal,
                         kind=kind, seed=seed)


# ---------------------------------------------------------------------------
# scenario generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", dyn.SCENARIO_NAMES)
def test_scenarios_deterministic_and_consistent(name):
    """Same seed -> identical schedule; every schedule is internally
    consistent: valid slots are real symmetric edges of that round's
    adjacency, padded slots carry the node's own index, shapes share one
    (R, N, K) padding across rounds."""
    topo = _topo()
    R = 6
    s1 = dyn.make_schedule(name, topo, R, seed=5)
    s2 = dyn.make_schedule(name, topo, R, seed=5)
    for f in ("neighbor_idx", "valid", "malicious", "adjacency"):
        assert np.array_equal(getattr(s1, f), getattr(s2, f)), (name, f)

    N = topo.n_nodes
    assert s1.neighbor_idx.shape == (R, N, s1.width)
    assert s1.valid.shape == (R, N, s1.width)
    assert s1.malicious.shape == (R, N)
    assert s1.adjacency.shape == (R, N, N)
    assert s1.width == max(1, int(s1.adjacency.sum(axis=2).max()))
    for r in range(R):
        adj = s1.adjacency[r]
        assert np.array_equal(adj, adj.T) and not adj.diagonal().any()
        assert (s1.valid[r].sum(axis=1) == adj.sum(axis=1)).all()
        for n in range(N):
            sel = s1.neighbor_idx[r, n][s1.valid[r, n]]
            assert set(sel) == set(np.nonzero(adj[n])[0]), (name, r, n)
            assert (s1.neighbor_idx[r, n][~s1.valid[r, n]] == n).all()


def test_scenarios_differ_across_seeds_and_change_rounds():
    topo = _topo()
    a = dyn.churn_schedule(topo, 8, seed=0, p_leave=0.3)
    b = dyn.churn_schedule(topo, 8, seed=1, p_leave=0.3)
    assert not np.array_equal(a.adjacency, b.adjacency)
    # churn/link-failure/mobility must actually vary the graph
    for name in ("churn", "link_failure", "mobility"):
        s = dyn.make_schedule(name, topo, 8, seed=0)
        assert s.diff().sum() > 0, name


def test_partition_cuts_and_heals():
    topo = _topo(n=12, degree=4)
    s = dyn.partition_schedule(topo, 9, seed=2, split_at=3, heal_at=6)
    base = topo.adjacency
    assert np.array_equal(s.adjacency[0], base)
    assert np.array_equal(s.adjacency[8], base)
    mid = s.adjacency[4]
    assert mid.sum() < base.sum()
    # the partition round's graph is exactly base minus cross edges of a
    # 2-coloring: reachable sets never span both sides
    assert (base & ~mid).sum() > 0


def test_sleeper_wakes_at_round():
    topo = _topo()
    s = dyn.sleeper_schedule(topo, 6, wake_at=4)
    assert not s.malicious[:4].any()
    assert np.array_equal(s.malicious[4], topo.malicious)
    assert np.array_equal(s.malicious[5], topo.malicious)
    # static graph throughout
    assert (s.adjacency == topo.adjacency[None]).all()


def test_static_schedule_matches_topology():
    topo = _topo(kind="erdos_renyi", seed=3)
    s = static_schedule(topo, 4)
    assert s.width == topo.degree
    assert s.diff().sum() == 0          # nothing changes round to round
    for r in range(4):
        assert np.array_equal(s.adjacency[r], topo.adjacency)
        assert (s.valid[r].sum(axis=1) == topo.degrees).all()


def test_schedule_degree_stats_and_diff_shapes():
    topo = _topo()
    s = dyn.churn_schedule(topo, 5, seed=1)
    assert s.degree_stats().shape == (5, 3)
    assert s.diff().shape == (4, 2)
    assert (s.degree_stats()[:, 0] <= s.degree_stats()[:, 2]).all()


def test_make_schedule_rejects_unknown():
    with pytest.raises(ValueError):
        dyn.make_schedule("quakes", _topo(), 3)


# ---------------------------------------------------------------------------
# degree-0 (fully churned-out) nodes
# ---------------------------------------------------------------------------

def test_padded_neighbor_table_degree0_row():
    """An isolated node yields an all-invalid all-self row, and ``width``
    pads beyond this graph's own max degree."""
    adj = np.zeros((4, 4), dtype=bool)
    adj[0, 1] = adj[1, 0] = True       # node 2, 3 isolated
    t, v = padded_neighbor_table(adj)
    assert not v[2].any() and (t[2] == 2).all()
    assert not v[3].any() and (t[3] == 3).all()
    t5, v5 = padded_neighbor_table(adj, width=5)
    assert t5.shape == (4, 5) and (t5[2] == 2).all()
    with pytest.raises(ValueError):
        padded_neighbor_table(np.ones((4, 4), bool) ^ np.eye(4, dtype=bool),
                              width=1)


def test_indexed_stats_degree0_finite_zero_median():
    """The kernel's empty-median guard: an all-invalid row must produce
    finite statistics (median = 0 -> dist2 = norm2, dotmed = 0), in both
    the Pallas kernel and the jnp oracle."""
    N, K, d = 4, 3, 256
    models = jax.random.normal(jax.random.PRNGKey(0), (N, d), jnp.float32)
    idx = np.array([[1, 2, 3], [0, 2, 3], [2, 2, 2], [0, 1, 2]], np.int32)
    valid = np.array([[1, 1, 1], [1, 1, 0], [0, 0, 0], [1, 1, 1]], bool)
    for fn in (robust_stats_indexed, robust_stats_indexed_ref):
        st = fn(models, jnp.asarray(idx), jnp.asarray(valid))
        for name in ("dist2", "dotmed", "norm2", "mednorm2"):
            arr = np.asarray(getattr(st, name))
            assert np.isfinite(arr).all(), (fn.__name__, name)
        # node 2: empty median = 0 => dist2 == norm2, dotmed == 0
        np.testing.assert_allclose(np.asarray(st.dist2)[2],
                                   np.asarray(st.norm2)[2], rtol=1e-5)
        np.testing.assert_allclose(np.asarray(st.dotmed)[2], 0.0, atol=1e-6)
        assert float(np.asarray(st.mednorm2)[2]) == 0.0


@pytest.mark.parametrize("filters", ["wfagg", "alt"])
def test_wfagg_batch_degree0_keeps_local_model(filters):
    """A churned-out node (all-invalid row) must keep its local model
    exactly (all weights zero -> WFAgg-E alpha gates to 0), with no NaNs
    anywhere in the batch."""
    N, K, d = 6, 4, 300
    mk = wf.alt_wfagg_config if filters == "alt" else wf.WFAggConfig
    cfg = mk(backend="fused", use_temporal=False, f=1)
    models = jax.random.normal(jax.random.PRNGKey(1), (N, d), jnp.float32)
    idx = np.stack([[(n + o) % N for o in range(1, K + 1)] for n in range(N)]
                   ).astype(np.int32)
    valid = np.ones((N, K), bool)
    idx[2] = 2
    valid[2] = False                   # node 2 fully churned out
    out, _, info = wf.wfagg_batch(models, models, None, cfg,
                                  neighbor_idx=jnp.asarray(idx),
                                  valid=jnp.asarray(valid))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(models[2]),
                               rtol=1e-6, atol=1e-6)
    assert int(np.asarray(info["n_accepted"])[2]) == 0
    # the batch as a whole still aggregates (degree-0 doesn't poison it)
    assert (np.asarray(info["n_accepted"]) > 0).any()


# ---------------------------------------------------------------------------
# the dynamic round: compile-once, HLO-clean, reference parity
# ---------------------------------------------------------------------------

def test_dynamic_round_compiles_once_across_changing_graphs():
    """Round-varying neighbor tables / valid masks / malicious masks are
    traced inputs: R rounds through R different graphs must hit ONE
    compiled executable (no retrace per graph)."""
    topo = _topo()
    data = SyntheticImages()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    sched = dyn.churn_schedule(topo, 5, seed=7, p_leave=0.4)
    fn = build_round_fn(cfg, topo, data, dynamic=True)
    state = init_dfl_state(cfg, topo, degree=sched.width)
    for r in range(sched.rounds):
        state = fn(state, jnp.asarray(sched.neighbor_idx[r]),
                   jnp.asarray(sched.valid[r]),
                   jnp.asarray(sched.malicious[r]))
    assert fn._cache_size() == 1
    flat = np.asarray(jax.vmap(
        lambda t: jax.flatten_util.ravel_pytree(t)[0])(state.node_params))
    assert np.isfinite(flat).all()


def test_dynamic_round_hlo_is_gossip_tensor_free():
    """The dynamic round keeps PR 2's guarantee: no (N, K, d)-shaped f32
    buffer anywhere in the compiled HLO (shared ``repro.analysis``
    scanner — the ``no-nkd-buffer`` rule's engine)."""
    from repro.analysis import scan_nkd_buffers

    topo = _topo()
    data = SyntheticImages()
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp")
    sched = dyn.churn_schedule(topo, 3, seed=1)
    N, K = topo.n_nodes, sched.width
    fn = build_round_fn(cfg, topo, data, dynamic=True)
    state = init_dfl_state(cfg, topo, degree=K)
    hlo = fn.lower(state, jnp.asarray(sched.neighbor_idx[0]),
                   jnp.asarray(sched.valid[0]),
                   jnp.asarray(sched.malicious[0])).compile().as_text()
    assert scan_nkd_buffers(hlo, N, K) == []


def test_dynamic_engine_rejects_unsupported_configs():
    topo = _topo()
    data = SyntheticImages()
    # only WFAgg component ablations (slot-keyed temporal state, no
    # valid-mask-aware form) and CFL remain unsupported under schedules
    for bad in (DFLConfig(aggregator="wfagg_t"),
                DFLConfig(aggregator="wfagg", centralized=True)):
        with pytest.raises(NotImplementedError):
            build_round_fn(bad, topo, data, dynamic=True)
    # the reference backend is no longer rejected: the valid-aware
    # pure-jnp oracle honors per-round valid masks (dynamic keep counts)
    build_round_fn(DFLConfig(aggregator="wfagg", wfagg_backend="reference"),
                   topo, data, dynamic=True)
    # baseline aggregators route through the DYN_AGGREGATORS variants
    build_round_fn(DFLConfig(aggregator="median"), topo, data, dynamic=True)


def test_indexed_vs_reference_parity_under_churn():
    """Under a churn schedule the fused indexed round must match, node by
    node and round by round, the plain per-node reference pipeline run on
    each node's TRUE (possibly empty) neighbor slate."""
    topo = _topo(n=8, degree=4, n_mal=0)
    data = SyntheticImages()
    cfg = DFLConfig(aggregator="wfagg", attack="none", model="mlp",
                    batches_per_round=1)
    sched = dyn.churn_schedule(topo, 3, seed=9, p_leave=0.35)
    assert (sched.degrees() == 0).any()     # churn actually bites
    fn = build_round_fn(cfg, topo, data, dynamic=True)
    state = init_dfl_state(cfg, topo, degree=sched.width)
    ref_flat = None
    p = cfg.paper
    for r in range(sched.rounds):
        prev_state = state
        state = fn(state, jnp.asarray(sched.neighbor_idx[r]),
                   jnp.asarray(sched.valid[r]),
                   jnp.asarray(sched.malicious[r]))
        # reference: recompute this round's aggregation per node from the
        # trained (pre-aggregation) models.  Rounds stay inside the
        # WFAgg-T transient (3), so temporal masks are inactive in both
        # paths and the reference needs no ring-buffer bookkeeping.
        from repro.dfl.engine import _local_train, _ravel_nodes
        trained, _ = _local_train(
            cfg, data, jnp.asarray(sched.malicious[r]),
            prev_state.node_params, prev_state.node_momentum,
            prev_state.rnd)
        flat, _ = _ravel_nodes(trained)
        flat = np.asarray(flat)
        got_flat, _ = _ravel_nodes(state.node_params)
        got_flat = np.asarray(got_flat)
        rcfg = wf.WFAggConfig(f=p.f, tau1=p.tau1, tau2=p.tau2, tau3=p.tau3,
                              alpha=p.alpha, window=p.window,
                              transient=p.transient, use_temporal=False,
                              backend="reference")
        for n in range(topo.n_nodes):
            sel = sched.neighbor_idx[r, n][sched.valid[r, n]]
            if len(sel) == 0:
                np.testing.assert_allclose(got_flat[n], flat[n],
                                           rtol=ATOL, atol=ATOL)
                continue
            out_n, _, _ = wf.wfagg(jnp.asarray(flat[n]),
                                   jnp.asarray(flat[sel]), None, rcfg)
            np.testing.assert_allclose(got_flat[n], np.asarray(out_n),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"round {r} node {n}")


# ---------------------------------------------------------------------------
# temporal-history realignment across slate changes
# ---------------------------------------------------------------------------

def test_realign_temporal_history_maps_by_identity():
    """Columns move with their neighbor: a shifted slot carries its
    history along, a newly-seen neighbor starts from zero, a static
    slate is a no-op."""
    N, K, W, d = 2, 3, 2, 8
    hist = jnp.arange(N * W * K, dtype=jnp.float32).reshape(N, W, K)
    st = wf.TemporalState(prev=jnp.zeros((N, d)), hist_s=hist,
                          hist_b=10.0 * hist,
                          count=jnp.ones((N,), jnp.int32),
                          t=jnp.ones((N,), jnp.int32))
    prev_idx = jnp.asarray([[3, 5, 7], [1, 2, 0]], jnp.int32)
    ones = jnp.ones((N, K), bool)
    # identity slate -> identity histories
    same = wf.realign_temporal_history(st, prev_idx, ones, prev_idx, ones)
    np.testing.assert_array_equal(np.asarray(same.hist_s), np.asarray(hist))
    # node 0: [3,5,7] -> [7,3,9]: slot 0 gets old slot 2, slot 1 gets old
    # slot 0, slot 2 (neighbor 9, unseen) starts zeroed; node 1 drops its
    # slot-1 neighbor (slot 1 invalid this round)
    idx = jnp.asarray([[7, 3, 9], [0, 2, 1]], jnp.int32)
    valid = jnp.asarray([[1, 1, 1], [1, 0, 1]], bool)
    out = wf.realign_temporal_history(st, prev_idx, ones, idx, valid)
    h = np.asarray(hist)
    got = np.asarray(out.hist_s)
    np.testing.assert_array_equal(got[0, :, 0], h[0, :, 2])
    np.testing.assert_array_equal(got[0, :, 1], h[0, :, 0])
    np.testing.assert_array_equal(got[0, :, 2], 0.0)
    np.testing.assert_array_equal(got[1, :, 0], h[1, :, 2])   # id 0
    np.testing.assert_array_equal(got[1, :, 1], 0.0)          # invalid slot
    np.testing.assert_array_equal(got[1, :, 2], h[1, :, 0])   # id 1
    np.testing.assert_array_equal(np.asarray(out.hist_b),
                                  10.0 * np.asarray(out.hist_s))


def test_temporal_masks_invariant_to_slot_permutation():
    """The same graph listed in per-round-permuted slot order must, with
    realignment, produce slot-permuted copies of the SAME temporal masks
    and the same aggregates — i.e. histories follow neighbors, not
    slots."""
    N, K, d = 6, 4, 120
    cfg = wf.WFAggConfig(backend="fused", transient=1, f=1)
    idx_a = np.stack([[(n + o) % N for o in range(1, K + 1)]
                      for n in range(N)]).astype(np.int32)
    ones = jnp.ones((N, K), bool)
    mk_state = lambda: wf.TemporalState(
        prev=jnp.zeros((N, d)), hist_s=jnp.zeros((N, cfg.window, K)),
        hist_b=jnp.zeros((N, cfg.window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))
    st_a, st_b = mk_state(), mk_state()
    rng = np.random.default_rng(4)
    prev_idx_b = idx_a
    saw_active = False
    for r in range(5):
        u = jax.random.normal(jax.random.PRNGKey(90 + r), (N, d)) + 0.2
        perm = np.stack([rng.permutation(K) for _ in range(N)])
        idx_b = np.take_along_axis(idx_a, perm, axis=1)
        st_b = wf.realign_temporal_history(
            st_b, jnp.asarray(prev_idx_b), ones, jnp.asarray(idx_b), ones)
        prev_idx_b = idx_b
        out_a, st_a, info_a = wf.wfagg_batch(u, u, st_a, cfg,
                                             neighbor_idx=jnp.asarray(idx_a))
        out_b, st_b, info_b = wf.wfagg_batch(u, u, st_b, cfg,
                                             neighbor_idx=jnp.asarray(idx_b))
        for m in ("mask_d", "mask_c", "mask_t"):
            a = np.take_along_axis(np.asarray(info_a[m]), perm, axis=1)
            assert np.array_equal(a, np.asarray(info_b[m])), (r, m)
        np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                                   rtol=ATOL, atol=ATOL)
        saw_active = saw_active or bool(np.asarray(info_a["mask_t"]).any())
    assert saw_active    # the temporal filter actually fired in this test


# ---------------------------------------------------------------------------
# end-to-end scenario runs + series output
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", dyn.SCENARIO_NAMES)
def test_run_dynamic_experiment_all_scenarios(name):
    topo = _topo()
    data = SyntheticImages()
    cfg = DFLConfig(aggregator="wfagg", attack="sign_flip", model="mlp")
    sched = dyn.make_schedule(name, topo, 3, seed=2)
    out = run_dynamic_experiment(cfg, topo, data, sched, n_test=32)
    assert len(out["trace"]) == 3
    s = out["series"]
    assert s["round"] == [1, 2, 3]
    assert np.isfinite(s["acc_benign_mean"]).all()
    assert np.isfinite(s["r_squared"]).all()
    assert len(s["degree_min_mean_max"]) == 3
    # final keeps evaluate's dict shape
    assert set(out["final"]) >= {"acc_benign_mean", "r_squared", "acc_all",
                                 "acc_by_malicious_neighbors", "round"}


def test_run_experiment_emits_series():
    """The static path grew the same columnar series (back-compat:
    trace/final keep their shapes)."""
    topo = _topo()
    data = SyntheticImages()
    out = run_experiment(DFLConfig(aggregator="mean"), topo, data,
                         rounds=2, eval_every=1)
    assert out["series"]["round"] == [1, 2]
    assert len(out["series"]["acc_benign_mean"]) == 2
    assert out["final"] == out["trace"][-1]


def test_sleeper_malicious_mask_threads_through_attack():
    """Before the wake round the attacker rows are untouched; after it
    they are poisoned — the per-round mask reaches apply_matrix_attack."""
    from repro.dfl.engine import _apply_attacks
    topo = _topo(n=8, degree=4, n_mal=2)
    cfg = DFLConfig(attack="sign_flip")
    flat = jax.random.normal(jax.random.PRNGKey(3), (8, 32), jnp.float32)
    rnd = jnp.zeros((), jnp.int32)
    asleep = _apply_attacks(cfg, jnp.zeros((8,), bool), flat, rnd)
    np.testing.assert_allclose(np.asarray(asleep), np.asarray(flat))
    awake = _apply_attacks(cfg, jnp.asarray(topo.malicious), flat, rnd)
    mal = np.asarray(topo.malicious)
    np.testing.assert_allclose(np.asarray(awake)[mal],
                               -np.asarray(flat)[mal])
