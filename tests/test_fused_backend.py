"""Backend parity: the fused (single-pass Pallas) WFAgg execution path
must reproduce the reference (multi-pass jnp) pipeline — masks bit-equal,
aggregates within float tolerance — across candidate counts, temporal
state, attacks, and the batched (N, K, d) launch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as atk
from repro.core import wfagg as wf
from repro.kernels.robust_stats.ops import robust_stats, robust_stats_batch
from repro.kernels.robust_stats.ref import robust_stats_ref

ATOL = 1e-5


def _updates(K, d, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (K, d), jnp.float32)


def _run_rounds(cfg, K, d, rounds=6, seed=0):
    """Drive wfagg for several rounds (past the temporal transient) and
    collect (out, info) per round."""
    local = _updates(K, d, seed + 1)[0]
    state = wf.init_temporal_state(K, d, cfg.window) if cfg.use_temporal else None
    outs = []
    for r in range(rounds):
        u = _updates(K, d, seed + 10 + r) + 0.5
        out, state, info = wf.wfagg(local, u, state, cfg)
        outs.append((out, info))
    return outs


# ---------------------------------------------------------------------------
# full WFAgg parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [5, 7, 8, 12])
@pytest.mark.parametrize("use_temporal", [True, False])
def test_wfagg_backend_parity(K, use_temporal):
    d = 700
    cfg_r = wf.WFAggConfig(backend="reference", use_temporal=use_temporal)
    cfg_f = wf.WFAggConfig(backend="fused", use_temporal=use_temporal)
    for (o_r, i_r), (o_f, i_f) in zip(
        _run_rounds(cfg_r, K, d), _run_rounds(cfg_f, K, d)
    ):
        for m in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(np.asarray(i_r[m]), np.asarray(i_f[m])), m
        np.testing.assert_allclose(np.asarray(i_r["weights"]),
                                   np.asarray(i_f["weights"]), atol=ATOL)
        np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_f),
                                   rtol=ATOL, atol=ATOL)


def test_wfagg_temporal_filter_activates():
    """Sanity: the parity runs above exercise a *live* temporal filter."""
    cfg = wf.WFAggConfig(backend="fused")
    _, info = _run_rounds(cfg, 8, 500)[-1]
    assert np.asarray(info["mask_t"]).any()


@pytest.mark.parametrize("K", [7, 8])
def test_alt_wfagg_backend_parity(K):
    """Multi-Krum + Clustering filters, fused via the pairwise Gram kernel."""
    d = 600
    cfg_r = wf.alt_wfagg_config(backend="reference")
    cfg_f = wf.alt_wfagg_config(backend="fused")
    for (o_r, i_r), (o_f, i_f) in zip(
        _run_rounds(cfg_r, K, d), _run_rounds(cfg_f, K, d)
    ):
        for m in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(np.asarray(i_r[m]), np.asarray(i_f[m])), m
        np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_f),
                                   rtol=ATOL, atol=ATOL)


@pytest.mark.parametrize("attack", atk.ATTACK_NAMES)
def test_wfagg_parity_under_attacks(attack):
    """Masks and aggregates must agree when Byzantine rows are present."""
    K, d, n_mal = 9, 500, 2
    u = np.array(_updates(K, d, seed=3) + 1.0)
    benign = jnp.asarray(u[n_mal:])
    key = jax.random.PRNGKey(7)
    for j in range(n_mal):
        u[j] = np.asarray(atk.apply_model_attack(
            attack, jnp.asarray(u[j]), benign, jax.random.fold_in(key, j)))
    u = jnp.asarray(u)
    local = u[-1]
    cfg_r = wf.WFAggConfig(backend="reference", use_temporal=False)
    cfg_f = wf.WFAggConfig(backend="fused", use_temporal=False)
    o_r, _, i_r = wf.wfagg(local, u, None, cfg_r)
    o_f, _, i_f = wf.wfagg(local, u, None, cfg_f)
    for m in ("mask_d", "mask_c"):
        assert np.array_equal(np.asarray(i_r[m]), np.asarray(i_f[m])), m
    np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_f),
                               rtol=ATOL, atol=ATOL)


# ---------------------------------------------------------------------------
# standalone filter aggregators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [5, 8])
def test_wfagg_d_c_agg_backend_parity(K):
    u = _updates(K, 700, seed=11)
    for fn in (wf.wfagg_d_agg, wf.wfagg_c_agg):
        out_r, m_r = fn(u, 2, backend="reference")
        out_f, m_f = fn(u, 2, backend="fused")
        assert np.array_equal(np.asarray(m_r), np.asarray(m_f))
        np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_f),
                                   rtol=ATOL, atol=ATOL)


def test_wfagg_t_select_backend_parity():
    K, d = 8, 400
    cfg_r = wf.WFAggConfig(backend="reference", transient=1)
    cfg_f = wf.WFAggConfig(backend="fused", transient=1)
    s_r = wf.init_temporal_state(K, d, cfg_r.window)
    s_f = wf.init_temporal_state(K, d, cfg_f.window)
    for r in range(5):
        u = _updates(K, d, seed=20 + r)
        m_r, s_r = wf.wfagg_t_select(s_r, u, cfg_r)
        m_f, s_f = wf.wfagg_t_select(s_f, u, cfg_f)
        assert np.array_equal(np.asarray(m_r), np.asarray(m_f)), r
        np.testing.assert_allclose(np.asarray(s_r.hist_s), np.asarray(s_f.hist_s),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# batched kernel and batched WFAgg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_prev", [True, False])
def test_batched_stats_match_single_node_kernel(with_prev):
    N, K, D = 5, 8, 1000
    u = jax.random.normal(jax.random.PRNGKey(0), (N, K, D), jnp.float32)
    p = jax.random.normal(jax.random.PRNGKey(1), (N, K, D), jnp.float32) \
        if with_prev else None
    got = robust_stats_batch(u, p)
    for n in range(N):
        one = robust_stats(u[n], p[n] if with_prev else None)
        ref = robust_stats_ref(u[n], prev=p[n] if with_prev else None)
        for name in got._fields:
            g, s, r = getattr(got, name), getattr(one, name), getattr(ref, name)
            if g is None:
                assert s is None and r is None
                continue
            np.testing.assert_allclose(g[n], s, rtol=3e-5, atol=3e-5,
                                       err_msg=f"batch-vs-single {name}")
            np.testing.assert_allclose(g[n], r, rtol=3e-5, atol=3e-5,
                                       err_msg=f"batch-vs-oracle {name}")


@pytest.mark.parametrize("filters", ["wfagg", "alt"])
def test_wfagg_batch_matches_per_node_reference(filters):
    N, K, d = 4, 8, 600
    if filters == "alt":
        cfg_f = wf.alt_wfagg_config(backend="fused")
        cfg_r = wf.alt_wfagg_config(backend="reference")
    else:
        cfg_f = wf.WFAggConfig(backend="fused")
        cfg_r = wf.WFAggConfig(backend="reference")
    local = jax.random.normal(jax.random.PRNGKey(0), (N, d), jnp.float32)
    state_b = jax.vmap(lambda _: wf.init_temporal_state(K, d, cfg_f.window))(
        jnp.arange(N))
    states = [wf.init_temporal_state(K, d, cfg_r.window) for _ in range(N)]
    for r in range(6):
        u = jax.random.normal(jax.random.PRNGKey(100 + r), (N, K, d)) + 0.3
        out_b, state_b, info_b = wf.wfagg_batch(local, u, state_b, cfg_f)
        for n in range(N):
            out_1, states[n], info_1 = wf.wfagg(local[n], u[n], states[n], cfg_r)
            for m in ("mask_d", "mask_c", "mask_t"):
                assert np.array_equal(np.asarray(info_b[m][n]),
                                      np.asarray(info_1[m])), (r, n, m)
            np.testing.assert_allclose(np.asarray(out_b[n]), np.asarray(out_1),
                                       rtol=ATOL, atol=ATOL)


# ---------------------------------------------------------------------------
# stacked (distributed) layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["wfagg", "alt_wfagg", "multi_krum", "clustering"])
def test_stacked_fused_matches_reference(method):
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = 6
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 32, 8)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (K, 100))}
    wcfg = wf.WFAggConfig(f=1, transient=1, window=2)
    cfg_r = RobustAggConfig(method=method, wfagg=wcfg, layout="stacked",
                            backend="reference")
    cfg_f = dataclasses.replace(cfg_r, backend="fused")
    needs_state = method in ("wfagg", "alt_wfagg")
    like = jax.tree.map(lambda x: x[0], g)
    s_r = init_tree_agg_state(cfg_r, K, like) if needs_state else None
    s_f = init_tree_agg_state(cfg_f, K, like) if needs_state else None
    for r in range(4):
        gr = jax.tree.map(lambda x: x + 0.1 * r, g)
        o_r, s_r, i_r = robust_allreduce_stacked(gr, cfg_r, s_r)
        o_f, s_f, i_f = robust_allreduce_stacked(gr, cfg_f, s_f)
        np.testing.assert_allclose(np.asarray(i_r["weights"]),
                                   np.asarray(i_f["weights"]), atol=ATOL)
        for k in g:
            np.testing.assert_allclose(np.asarray(o_r[k]), np.asarray(o_f[k]),
                                       rtol=1e-4, atol=ATOL)


def test_stacked_fused_gather_dtype_keeps_temporal_masks():
    """gather_dtype quantizes the D/C/Gram statistics only: the WFAgg-T
    round-over-round metrics stay full-precision in both backends, so the
    temporal masks must agree even under bfloat16 gathers."""
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = 6
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (K, 64))}
    wcfg = wf.WFAggConfig(f=1, transient=1, window=2)
    cfg_r = RobustAggConfig(method="wfagg", wfagg=wcfg, layout="stacked",
                            backend="reference", gather_dtype="bfloat16")
    cfg_f = dataclasses.replace(cfg_r, backend="fused")
    like = jax.tree.map(lambda x: x[0], g)
    s_r = init_tree_agg_state(cfg_r, K, like)
    s_f = init_tree_agg_state(cfg_f, K, like)
    for r in range(4):
        gr = jax.tree.map(lambda x: x + 0.05 * r, g)
        _, s_r, i_r = robust_allreduce_stacked(gr, cfg_r, s_r)
        _, s_f, i_f = robust_allreduce_stacked(gr, cfg_f, s_f)
        assert np.array_equal(np.asarray(i_r["mask_t"]),
                              np.asarray(i_f["mask_t"])), r
        np.testing.assert_allclose(np.asarray(s_r.hist_s), np.asarray(s_f.hist_s),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# DFL engine: fused backend end-to-end
# ---------------------------------------------------------------------------

def test_engine_fused_matches_reference_metrics():
    """Acceptance: experiment metrics (benign accuracy, R^2) unchanged when
    the round function runs through the fused backend (the default)."""
    from repro.core.topology import paper_topology
    from repro.data.synthetic import SyntheticImages
    from repro.dfl.engine import DFLConfig, run_experiment

    data = SyntheticImages()
    topo = paper_topology()
    res = {}
    for backend in ("fused", "reference"):
        cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp",
                        wfagg_backend=backend)
        res[backend] = run_experiment(cfg, topo, data, rounds=2, eval_every=2)["final"]
    assert res["fused"]["acc_benign_mean"] == pytest.approx(
        res["reference"]["acc_benign_mean"], abs=0.02)
    assert res["fused"]["r_squared"] == pytest.approx(
        res["reference"]["r_squared"], abs=0.02)


def test_memory_passes_accounting():
    """The fused path must cost at least 2x fewer (K, d)-sized passes."""
    cfg_r = wf.WFAggConfig(backend="reference")
    cfg_f = wf.WFAggConfig(backend="fused")
    assert wf.memory_passes(cfg_f) == 2
    assert wf.memory_passes(cfg_r) >= 2 * wf.memory_passes(cfg_f)
    # Alt-WFAgg needs one extra Gram pass in both backends
    assert wf.memory_passes(wf.alt_wfagg_config(backend="fused")) == 3
