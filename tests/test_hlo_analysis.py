"""Validate the trip-count-aware HLO analyzer against analytic ground truth.

The whole §Roofline pipeline rests on this module, so we check:
  * dot FLOPs exact on a plain matmul;
  * scan(L) total ~= L x per-iteration cost (the thing raw cost_analysis
    misses);
  * scanned == unrolled totals to within fusion noise;
  * collective wire bytes inside a scan get multiplied by the trip count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def _cost(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return ha.analyze(compiled.as_text(), n_devices=1)


def test_matmul_flops_exact():
    xs = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    ws = jax.ShapeDtypeStruct((512, 384), jnp.float32)
    c = _cost(lambda x, w: x @ w, xs, ws)
    assert c.flops == pytest.approx(2 * 256 * 512 * 384, rel=0.05)


def test_scan_trip_count_multiplies():
    L, B, D = 24, 128, 256

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, ws)
        return h

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = _cost(f, xs, ws)
    expected = L * 2 * B * D * D
    assert c.flops == pytest.approx(expected, rel=0.1)
    assert c.n_while == 1
    assert c.trip_counts == [L]


def test_scanned_matches_unrolled():
    L, B, D = 8, 64, 128

    def scanned(x, ws):
        h, _ = jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), ()), x, ws)
        return h

    def unrolled(x, ws):
        h = x
        for i in range(L):
            h = jnp.tanh(h @ ws[i])
        return h

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    cs, cu = _cost(scanned, xs, ws), _cost(unrolled, xs, ws)
    assert cs.flops == pytest.approx(cu.flops, rel=0.15)
    # bytes: scanned re-reads weights per iteration either way
    assert cs.bytes == pytest.approx(cu.bytes, rel=0.5)


def test_grad_of_scan_counts_backward_pass():
    L, B, D = 16, 32, 64

    def loss(x, ws):
        h, _ = jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), ()), x, ws)
        return h.sum()

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = _cost(jax.grad(loss, argnums=(0, 1)), xs, ws)
    # fwd (2BDD) + two backward matmuls (2x 2BDD) per layer
    expected = 3 * L * 2 * B * D * D
    assert c.flops == pytest.approx(expected, rel=0.25)
    assert c.n_while >= 2  # fwd scan + bwd scan


def test_collectives_inside_scan_multiplied(monkeypatch):
    if jax.device_count() < 4:
        pytest.skip("needs forced host devices")
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((4,), ("data",))
    N, C, D = 4, 6, 1024

    def f(x):
        def body(acc, chunk):
            g = jax.lax.all_gather(chunk, "data")  # (4, D) f32
            return acc + g.sum(), None
        acc, _ = jax.lax.scan(body, 0.0, x)
        return acc

    from repro.distributed.sharding import shard_map_compat
    sf = shard_map_compat(f, mesh=mesh, in_specs=(P(None, None),),
                          out_specs=P(), check_vma=False)
    xs = jax.ShapeDtypeStruct((C, D), jnp.float32)
    compiled = jax.jit(sf).lower(xs).compile()
    c = ha.analyze(compiled.as_text(), n_devices=4)
    per_gather_wire = 4 * D * 4 * (4 - 1) / 4  # out_bytes*(S-1)/S
    assert c.wire_bytes == pytest.approx(C * per_gather_wire, rel=0.3)


def test_indexed_wfagg_round_is_gossip_tensor_free():
    """The gather-free (fused, neighbor-indexed) DFL round must not
    allocate ANY (N, K, d)-shaped f32 buffer — the K-fold gossip tensor,
    its padded variants AND the per-edge temporal state are all gone.
    The reference backend still materializes them (sanity check that the
    scanner actually catches the gather).  Asserted through the shared
    ``repro.analysis.scan_nkd_buffers`` — the same scanner behind the
    ``no-nkd-buffer`` rule in ``python -m repro.analysis``."""
    from repro.analysis import scan_nkd_buffers
    from repro.core.topology import paper_topology
    from repro.data.synthetic import SyntheticImages
    from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

    topo = paper_topology()
    data = SyntheticImages()
    N, K = topo.n_nodes, topo.degree
    hits = {}
    for backend in ("fused", "reference"):
        cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp",
                        wfagg_backend=backend)
        state = init_dfl_state(cfg, topo)
        fn = build_round_fn(cfg, topo, data)
        hlo = fn.lower(state).compile().as_text()
        hits[backend] = scan_nkd_buffers(hlo, N, K)
    assert hits["fused"] == [], hits["fused"]
    assert hits["reference"], "reference round should materialize the gather"


def test_dynamic_update_slice_counts_update_only():
    cap, D = 65536, 512

    def f(buf, upd, idx):
        return jax.lax.dynamic_update_slice(buf, upd, (idx, 0))

    bs = jax.ShapeDtypeStruct((cap, D), jnp.float32)
    us = jax.ShapeDtypeStruct((1, D), jnp.float32)
    isx = jax.ShapeDtypeStruct((), jnp.int32)
    compiled = jax.jit(f, donate_argnums=(0,)).lower(bs, us, isx).compile()
    c = ha.analyze(compiled.as_text(), n_devices=1)
    # traffic should be ~the update (2*2KB), not the 128MB buffer
    assert c.bytes < 1e6
