"""Gather-free gossip aggregation: the neighbor-indexed kernels and the
``wfagg_batch(neighbor_idx=...)`` path must reproduce the gathered
reference — masks bit-equal, aggregates within float tolerance — across
backends, odd/even K, per-edge vs matrix temporal state, and irregular
(padded, erdos_renyi-style) degrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wfagg as wf
from repro.core.topology import make_topology, padded_neighbor_table
from repro.kernels.robust_stats.ops import robust_stats_batch, robust_stats_indexed
from repro.kernels.robust_stats.ref import robust_stats_indexed_ref
from repro.kernels.weighted_agg.ops import weighted_agg, weighted_agg_indexed

ATOL = 2e-5


def _ring_idx(N, K):
    """(N, K) neighbor table of a K-regular ring lattice (K even) or a
    complete-graph slice (K = N - 1)."""
    if K == N - 1:
        return jnp.stack([
            jnp.concatenate([jnp.arange(n), jnp.arange(n + 1, N)])
            for n in range(N)
        ]).astype(jnp.int32)
    half = K // 2
    offs = np.concatenate([np.arange(-half, 0), np.arange(1, K - half + 1)])
    return jnp.asarray(
        (np.arange(N)[:, None] + offs[None, :]) % N, jnp.int32)


def _irregular(N, K, seed=0):
    """Padded (idx, valid) with per-node degrees in [1, K]."""
    rng = np.random.default_rng(seed)
    idx = np.full((N, K), 0, np.int32)
    valid = np.zeros((N, K), bool)
    for n in range(N):
        v = int(rng.integers(1, K + 1))
        nbrs = rng.choice([i for i in range(N) if i != n], size=v, replace=False)
        idx[n, :v] = nbrs
        idx[n, v:] = n          # pad with self (finite, in-bounds)
        valid[n, :v] = True
    return jnp.asarray(idx), jnp.asarray(valid)


# ---------------------------------------------------------------------------
# indexed robust_stats kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [5, 8])
@pytest.mark.parametrize("prev_kind", ["none", "edge", "matrix"])
def test_indexed_stats_match_gathered_batch(K, prev_kind):
    N, d = 9, 900
    models = jax.random.normal(jax.random.PRNGKey(0), (N, d), jnp.float32)
    idx = _ring_idx(N, K) if K < N - 1 else _ring_idx(N, N - 1)
    prev_m = jax.random.normal(jax.random.PRNGKey(1), (N, d), jnp.float32)
    prev_arg = {"none": None, "edge": prev_m[idx], "matrix": prev_m}[prev_kind]
    got = robust_stats_indexed(models, idx, None, prev_arg)
    exp = robust_stats_batch(models[idx],
                             prev_m[idx] if prev_kind != "none" else None,
                             need_center=False)
    for name in got._fields:
        g, e = getattr(got, name), getattr(exp, name)
        if g is None:
            assert e is None, name
            continue
        np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                   rtol=1e-6, atol=1e-6, err_msg=name)


@pytest.mark.parametrize("with_prev", [False, True])
def test_indexed_stats_irregular_match_oracle(with_prev):
    N, K, d = 10, 6, 700
    models = jax.random.normal(jax.random.PRNGKey(2), (N, d), jnp.float32)
    idx, valid = _irregular(N, K, seed=4)
    prev = (jax.random.normal(jax.random.PRNGKey(3), (N, d), jnp.float32)
            if with_prev else None)
    got = robust_stats_indexed(models, idx, valid, prev)
    ref = robust_stats_indexed_ref(models, idx, valid, prev)
    vmask = np.asarray(valid)
    for name in got._fields:
        g, r = getattr(got, name), getattr(ref, name)
        if g is None:
            assert r is None, name
            continue
        g, r = np.asarray(g), np.asarray(r)
        np.testing.assert_allclose(g, r, rtol=3e-5, atol=3e-5, err_msg=name)
        assert np.isfinite(g).all(), name  # padded slots stay finite


def test_indexed_median_spans_valid_rows_only():
    """A padded slot with a huge model must not perturb the median."""
    N, K, d = 4, 3, 256
    models = jax.random.normal(jax.random.PRNGKey(5), (N, d), jnp.float32)
    models = models.at[3].set(1e6)  # the row the padded slot points at
    idx = jnp.array([[1, 2, 3], [0, 2, 3], [0, 1, 3], [0, 1, 2]], jnp.int32)
    valid = jnp.array([[1, 1, 0], [1, 1, 0], [1, 1, 0], [1, 1, 1]], bool)
    got = robust_stats_indexed(models, idx, valid)
    # node 0 median = median(models[[1, 2]]) — row 3 excluded
    med2 = 0.5 * (models[1] + models[2])
    exp_d2 = np.sum((np.asarray(models[idx[0]]) - np.asarray(med2)) ** 2, -1)
    np.testing.assert_allclose(np.asarray(got.dist2[0]), exp_d2,
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# indexed WFAgg-E combine kernel
# ---------------------------------------------------------------------------

def test_weighted_agg_indexed_matches_single_node_kernel():
    N, K, d = 7, 6, 800
    models = jax.random.normal(jax.random.PRNGKey(6), (N, d), jnp.float32)
    local = jax.random.normal(jax.random.PRNGKey(7), (N, d), jnp.float32)
    idx = _ring_idx(N, K)
    w = jax.random.uniform(jax.random.PRNGKey(8), (N, K))
    w = w.at[2].set(0.0)   # all-rejected node keeps its local model
    got = weighted_agg_indexed(local, models, idx, w, alpha=0.8)
    for n in range(N):
        exp = weighted_agg(local[n], models[idx[n]], w[n], alpha=0.8)
        np.testing.assert_allclose(np.asarray(got[n]), np.asarray(exp),
                                   rtol=ATOL, atol=ATOL, err_msg=str(n))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(local[2]),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# wfagg_batch(neighbor_idx=...) — regular-topology parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("filters", ["wfagg", "alt"])
@pytest.mark.parametrize("K", [4, 5])
def test_wfagg_batch_indexed_matches_gathered(filters, K):
    """Indexed fused vs gathered fused AND vs gathered reference: masks
    bit-equal, aggregates within tolerance, across 5 temporal rounds."""
    N, d = 8, 500
    mk = (wf.alt_wfagg_config if filters == "alt"
          else wf.WFAggConfig)
    cfg_f = mk(backend="fused")
    cfg_r = mk(backend="reference")
    idx = _ring_idx(N, K)
    st_i = wf.TemporalState(   # matrix-prev state (the engine's layout)
        prev=jnp.zeros((N, d)),
        hist_s=jnp.zeros((N, cfg_f.window, K)),
        hist_b=jnp.zeros((N, cfg_f.window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))
    st_g = jax.vmap(lambda _: wf.init_temporal_state(K, d, cfg_f.window))(
        jnp.arange(N))
    st_r = jax.vmap(lambda _: wf.init_temporal_state(K, d, cfg_f.window))(
        jnp.arange(N))
    for r in range(5):
        u = jax.random.normal(jax.random.PRNGKey(30 + r), (N, d)) + 0.3
        out_i, st_i, info_i = wf.wfagg_batch(u, u, st_i, cfg_f,
                                             neighbor_idx=idx)
        out_g, st_g, info_g = wf.wfagg_batch(u, u[idx], st_g, cfg_f)
        out_r, st_r, info_r = wf.wfagg_batch(u, u[idx], st_r, cfg_r)
        for m in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(np.asarray(info_i[m]),
                                  np.asarray(info_g[m])), (r, m, "fused")
            assert np.array_equal(np.asarray(info_i[m]),
                                  np.asarray(info_r[m])), (r, m, "reference")
        np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_g),
                                   rtol=ATOL, atol=ATOL)
        np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                                   rtol=ATOL, atol=ATOL)
        # matrix-prev state carries the post-round models, never (N, K, d)
        assert st_i.prev.shape == (N, d)


def test_wfagg_batch_indexed_edge_state_matches_matrix_state():
    """Per-edge (N, K, d) prev and matrix (N, d) prev are equivalent on a
    static topology (prev[idx] IS the per-edge history)."""
    N, K, d = 6, 4, 300
    cfg = wf.WFAggConfig(backend="fused", transient=1)
    idx = _ring_idx(N, K)
    st_m = wf.TemporalState(
        prev=jnp.zeros((N, d)), hist_s=jnp.zeros((N, cfg.window, K)),
        hist_b=jnp.zeros((N, cfg.window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))
    st_e = jax.vmap(lambda _: wf.init_temporal_state(K, d, cfg.window))(
        jnp.arange(N))
    for r in range(4):
        u = jax.random.normal(jax.random.PRNGKey(60 + r), (N, d)) + 0.2
        out_m, st_m, info_m = wf.wfagg_batch(u, u, st_m, cfg, neighbor_idx=idx)
        out_e, st_e, info_e = wf.wfagg_batch(u, u, st_e, cfg, neighbor_idx=idx)
        assert st_m.prev.ndim == 2 and st_e.prev.ndim == 3
        for m in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(np.asarray(info_m[m]), np.asarray(info_e[m])), (r, m)
        np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_e),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# irregular degrees: fused indexed vs per-node gathered reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("filters", ["wfagg", "alt"])
def test_wfagg_batch_indexed_irregular_matches_per_node_reference(filters):
    """On a padded irregular slate, node n's aggregation must equal the
    plain single-node reference pipeline run on its TRUE v_n neighbors
    (the gathered reference at K = v_n)."""
    N, K, d = 10, 6, 400
    models = jax.random.normal(jax.random.PRNGKey(9), (N, d), jnp.float32) + 0.3
    idx, valid = _irregular(N, K, seed=11)
    mk = wf.alt_wfagg_config if filters == "alt" else wf.WFAggConfig
    cfg = mk(backend="fused", use_temporal=False, f=1,
             **({"multi_krum_m": 2} if filters == "alt" else {}))
    out, _, info = wf.wfagg_batch(models, models, None, cfg,
                                  neighbor_idx=idx, valid=valid)
    for n in range(N):
        sel = np.asarray(idx[n])[np.asarray(valid[n])]
        v = len(sel)
        u_n = models[jnp.asarray(sel)]
        cfg_n = mk(backend="reference", use_temporal=False, f=1,
                   **({"multi_krum_m": min(2, v)} if filters == "alt" else {}))
        out_n, _, info_n = wf.wfagg(models[n], u_n, None, cfg_n)
        for m in ("mask_d", "mask_c"):
            got_m = np.asarray(info[m][n])[np.asarray(valid[n])]
            assert np.array_equal(got_m, np.asarray(info_n[m])), (n, m, v)
            assert not np.asarray(info[m][n])[~np.asarray(valid[n])].any()
        np.testing.assert_allclose(np.asarray(out[n]), np.asarray(out_n),
                                   rtol=ATOL, atol=ATOL, err_msg=str(n))


def test_wfagg_batch_indexed_irregular_temporal():
    """Temporal filter on an irregular slate: per-node decision matches
    the reference wfagg_t_decide on the valid slots, padded slots never
    pass, and the matrix prev state stays (N, d)."""
    N, K, d = 8, 5, 300
    cfg = wf.WFAggConfig(backend="fused", transient=1, f=1)
    idx, valid = _irregular(N, K, seed=13)
    st = wf.TemporalState(
        prev=jnp.zeros((N, d)), hist_s=jnp.zeros((N, cfg.window, K)),
        hist_b=jnp.zeros((N, cfg.window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))
    hist = {"s": np.zeros((N, cfg.window, K)), "b": np.zeros((N, cfg.window, K))}
    count = np.zeros((N,), np.int32)
    t = np.zeros((N,), np.int32)
    prev_m = np.zeros((N, d), np.float32)
    for r in range(4):
        u = np.asarray(jax.random.normal(jax.random.PRNGKey(80 + r), (N, d))) + 0.2
        _, st, info = wf.wfagg_batch(jnp.asarray(u), jnp.asarray(u), st, cfg,
                                     neighbor_idx=idx, valid=valid)
        mask_t = np.asarray(info["mask_t"])
        assert not mask_t[~np.asarray(valid)].any()
        for n in range(N):
            nb = np.asarray(idx[n])
            cur, prv = u[nb], prev_m[nb]
            s_t = ((cur - prv) ** 2).sum(-1)
            den = np.maximum(np.linalg.norm(cur, axis=-1)
                             * np.linalg.norm(prv, axis=-1), 1e-12)
            b_t = 1.0 - (cur * prv).sum(-1) / den
            m_ref, hs, hb, c_ref, t_ref = wf.wfagg_t_decide(
                jnp.asarray(hist["s"][n]), jnp.asarray(hist["b"][n]),
                jnp.asarray(count[n]), jnp.asarray(t[n]),
                jnp.asarray(s_t), jnp.asarray(b_t), cfg)
            m_ref = np.asarray(m_ref) & np.asarray(valid[n])
            assert np.array_equal(mask_t[n], m_ref), (r, n)
            hist["s"][n], hist["b"][n] = np.asarray(hs), np.asarray(hb)
            count[n], t[n] = int(c_ref), int(t_ref)
        prev_m = u
        assert st.prev.shape == (N, d)


# ---------------------------------------------------------------------------
# engine end-to-end on irregular topologies
# ---------------------------------------------------------------------------

def test_engine_runs_on_erdos_renyi():
    from repro.data.synthetic import SyntheticImages
    from repro.dfl.engine import DFLConfig, run_experiment

    topo = make_topology(n_nodes=12, degree=4, n_malicious=1,
                         kind="erdos_renyi", seed=3)
    assert not topo.is_regular          # the interesting case
    assert (topo.degrees >= 1).all()
    data = SyntheticImages()
    for aggregator in ("wfagg", "alt_wfagg"):
        cfg = DFLConfig(aggregator=aggregator, attack="ipm_100", model="mlp")
        out = run_experiment(cfg, topo, data, rounds=2, eval_every=2)
        assert np.isfinite(out["final"]["acc_benign_mean"])


def test_engine_irregular_aggregator_support():
    """Irregular graphs accept wfagg/alt_wfagg and every DYN_AGGREGATORS
    baseline (valid-mask-aware path); per-filter variants like wfagg_t
    have no masked implementation and must still be rejected."""
    from repro.data.synthetic import SyntheticImages
    from repro.dfl.engine import DFLConfig, build_round_fn

    topo = make_topology(n_nodes=12, degree=4, n_malicious=1,
                         kind="erdos_renyi", seed=3)
    data = SyntheticImages()
    build_round_fn(DFLConfig(aggregator="median"), topo, data)
    with pytest.raises(NotImplementedError):
        build_round_fn(DFLConfig(aggregator="wfagg_t"), topo, data)


# ---------------------------------------------------------------------------
# satellite bugfixes
# ---------------------------------------------------------------------------

def test_engine_attacks_honor_attack_config():
    """dfl.engine._apply_attacks must route AttackConfig hyper-parameters
    (previously z_max / mu / sigma were hardcoded) through the shared
    core.attacks implementation."""
    from repro.core import attacks as atk
    from repro.core.topology import paper_topology
    from repro.dfl import engine as eng

    topo = paper_topology()
    N = topo.n_nodes
    flat = jax.random.normal(jax.random.PRNGKey(0), (N, 64), jnp.float32)
    rnd = jnp.zeros((), jnp.int32)
    mal = np.asarray(topo.malicious)
    benign = np.asarray(flat)[~mal]

    mal_mask = jnp.asarray(topo.malicious)
    for zmax in (0.5, 1.5):
        cfg = eng.DFLConfig(attack="alie",
                            attack_params=atk.AttackConfig(alie_zmax=zmax))
        out = np.asarray(eng._apply_attacks(cfg, mal_mask, flat, rnd))
        expect = benign.mean(0) - zmax * benign.std(0)
        for j in np.nonzero(mal)[0]:
            np.testing.assert_allclose(out[j], expect, rtol=1e-4,
                                       err_msg=f"zmax={zmax}")
        np.testing.assert_allclose(out[~mal], benign)  # benign untouched

    # custom noise parameters reach the noise attack
    cfg = eng.DFLConfig(attack="noise", seed=0,
                        attack_params=atk.AttackConfig(noise_mu=5.0,
                                                       noise_sigma=0.0))
    out = np.asarray(eng._apply_attacks(cfg, mal_mask, flat, rnd))
    np.testing.assert_allclose(out[mal], np.asarray(flat)[mal] + 5.0,
                               rtol=1e-6)

    # custom IPM epsilon via the generic "ipm" name
    cfg = eng.DFLConfig(attack="ipm",
                        attack_params=atk.AttackConfig(ipm_eps=7.0))
    out = np.asarray(eng._apply_attacks(cfg, mal_mask, flat, rnd))
    np.testing.assert_allclose(out[mal][0], -7.0 * benign.mean(0), rtol=1e-4)


def test_stacked_attack_matches_engine_attack():
    """engine and robust_allreduce now share ONE copy of the stacked
    attack math — same inputs, same poisoned rows."""
    from repro.core import attacks as atk
    from repro.distributed.robust_allreduce import apply_stacked_attack

    K, d = 8, 96
    g = jax.random.normal(jax.random.PRNGKey(1), (K, d), jnp.float32)
    malicious = jnp.zeros((K,), bool).at[2].set(True).at[6].set(True)
    key = jax.random.PRNGKey(3)
    for attack in ("alie", "ipm_100", "ipm_0.5", "sign_flip", "noise"):
        via_stacked = apply_stacked_attack({"w": g}, malicious, attack,
                                           key)["w"]
        # apply_stacked_attack folds the leaf index into the key
        direct = atk.apply_matrix_attack(attack, g, malicious,
                                         jax.random.fold_in(key, 0))
        np.testing.assert_allclose(np.asarray(via_stacked),
                                   np.asarray(direct), rtol=1e-6,
                                   err_msg=attack)


def test_mode_b_multi_krum_m_prefers_wfagg_config():
    """alt_wfagg mask parity: distributed._weights_from_stats must honor
    WFAggConfig.multi_krum_m (like core.wfagg._distance_mask does) and
    only fall back to RobustAggConfig.multi_krum_m."""
    import dataclasses as dc

    from repro.distributed.robust_allreduce import (
        RobustAggConfig, _stacked_stats, _weights_from_stats)

    K, d = 9, 120
    u = jax.random.normal(jax.random.PRNGKey(4), (K, d), jnp.float32)
    # (WFAggConfig.m, RobustAggConfig.m) -> effective m (preference order)
    for wf_m, ra_m, eff_m in ((3, None, 3), (3, 5, 3), (None, 5, 5),
                              (None, None, max(1, K // 4))):
        wcfg = wf.alt_wfagg_config(f=1, use_temporal=False,
                                   multi_krum_m=wf_m)
        cfg = RobustAggConfig(method="alt_wfagg", wfagg=wcfg,
                              multi_krum_m=ra_m, layout="stacked")
        stats = _stacked_stats({"w": u}, cfg)
        _, _, info = _weights_from_stats(stats, None, None, cfg)
        mask_a = wf._distance_mask(                   # mode-A path
            u, dc.replace(wcfg, multi_krum_m=eff_m))
        assert int(np.asarray(info["mask_d"]).sum()) == eff_m
        assert np.array_equal(np.asarray(info["mask_d"]),
                              np.asarray(mask_a)), (wf_m, ra_m)


def test_evaluate_buckets_cover_dense_placements():
    """Benign nodes with >= 3 malicious neighbors must appear in
    acc_by_malicious_neighbors instead of being silently dropped."""
    from repro.core.topology import Topology, padded_neighbor_table, ring_lattice
    from repro.data.synthetic import SyntheticImages
    from repro.dfl.engine import DFLConfig, evaluate, init_dfl_state

    n = 12
    adj = ring_lattice(n, 6)
    mal = np.zeros(n, bool)
    mal[[0, 1, 2]] = True           # contiguous cluster: node 3 sees 3
    table, valid = padded_neighbor_table(adj)
    topo = Topology(n_nodes=n, adjacency=adj, neighbor_indices=table,
                    malicious=mal, neighbor_valid=valid)
    mal_nb = topo.malicious_neighbor_count()
    assert mal_nb[~mal].max() >= 3   # the placement this test is about

    cfg = DFLConfig(aggregator="mean", model="mlp")
    state = init_dfl_state(cfg, topo)
    res = evaluate(cfg, topo, SyntheticImages(), state, n_test=64)
    by = res["acc_by_malicious_neighbors"]
    assert set(by) == set(range(int(mal_nb[~mal].max()) + 1))
    # every benign node lands in exactly one bucket (none dropped)
    counted = sum(int((~mal & (mal_nb == m)).sum()) for m in by)
    assert counted == int((~mal).sum())
    assert np.isfinite(by[3])


def test_padded_neighbor_table_invariants():
    topo = make_topology(n_nodes=16, degree=5, n_malicious=2,
                         kind="erdos_renyi", seed=7)
    idx, valid = topo.neighbor_indices, topo.neighbor_valid
    degs = topo.adjacency.sum(axis=1)
    assert (valid.sum(axis=1) == degs).all()
    for n in range(16):
        nbrs = set(np.nonzero(topo.adjacency[n])[0])
        assert set(idx[n][valid[n]]) == nbrs
        assert (idx[n][~valid[n]] == n).all()   # padded with self
    # regular graphs keep an all-valid table
    ring = make_topology(n_nodes=12, degree=4, kind="ring")
    assert ring.is_regular and ring.neighbor_valid.all()
    t2, v2 = padded_neighbor_table(ring.adjacency)
    assert np.array_equal(np.sort(t2, axis=1),
                          np.sort(ring.neighbor_indices, axis=1))
    assert v2.all()
