"""Infrastructure coverage: checkpointing round-trip, the training
launcher CLI, data pipeline determinism, optimizer behaviours."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import SyntheticImages, TokenStream
from repro.optim.optimizers import make_optimizer, warmup_cosine
from repro.train import checkpoint as ckpt


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layer": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "b": jnp.zeros((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    ckpt.save_checkpoint(str(tmp_path), "t1", tree, {"note": "hi"})
    restored, meta = ckpt.restore_checkpoint(str(tmp_path), "t1", tree)
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_launcher_cli_end_to_end(tmp_path, capsys):
    """repro.launch.train main() runs a few robust steps and checkpoints."""
    from repro.launch import train as T
    T.main([
        "--arch", "qwen1.5-0.5b", "--reduced",
        "--d-model", "64", "--n-layers", "2", "--vocab", "128",
        "--steps", "3", "--seq-len", "32", "--global-batch", "4",
        "--chunk-size", "4096", "--sketch-dim", "128",
        "--log-every", "1",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
    ])
    out = capsys.readouterr().out
    assert "step     3" in out
    assert "done: 3 steps" in out
    assert os.path.exists(os.path.join(str(tmp_path), "step_3.npz"))


def test_token_stream_deterministic():
    s = TokenStream(vocab_size=256, seq_len=16, batch_size=4, seed=3)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 256


def test_synthetic_images_class_structure():
    """Same-label images must be closer than cross-label (learnable task)."""
    data = SyntheticImages()
    imgs, labels = data.batch(jax.random.PRNGKey(0), 256)
    imgs, labels = np.asarray(imgs), np.asarray(labels)
    tpl = np.asarray(data.templates())
    d_own = np.linalg.norm((imgs - tpl[labels]).reshape(256, -1), axis=1)
    d_other = np.linalg.norm((imgs - tpl[(labels + 1) % 10]).reshape(256, -1), axis=1)
    assert (d_own < d_other).mean() > 0.95


@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizers_descend_quadratic(name):
    opt = make_optimizer(name)
    params = {"w": jnp.full((8,), 5.0)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, 0.1)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < float(jnp.sum(jnp.full((8,), 5.0) ** 2)) * 0.2


def test_warmup_cosine_schedule():
    fn = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(fn(0)) < 2e-4
    assert float(fn(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(fn(99)) < float(fn(50)) < float(fn(10))


def test_microbatched_gradients_match_full_batch():
    """TrainConfig.microbatches must not change the per-worker gradient."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.distributed.robust_allreduce import RobustAggConfig
    from repro.launch.mesh import make_test_mesh
    from repro.train import trainer as tr

    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32)
    mesh = make_test_mesh(data=jax.device_count(), model=1)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    batch = stream.batch(0)
    outs = {}
    for m in (1, 4):
        tc = tr.TrainConfig(mode="robust_dp",
                            agg=RobustAggConfig(method="mean", layout="stacked"),
                            microbatches=m, donate=False, lr=1e-2, warmup=0)
        state = tr.init_train_state(cfg, tc, jax.random.PRNGKey(0), mesh)
        step = tr.build_train_step(cfg, tc, mesh)
        with mesh:
            new_state, metrics = step(state, batch)
        outs[m] = (metrics["loss"], new_state.params)
    assert float(outs[1][0]) == pytest.approx(float(outs[4][0]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)
