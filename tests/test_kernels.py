"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests.

Every Pallas kernel is executed in interpret=True mode (the kernel body
runs in Python on CPU) and compared against its pure-jnp oracle in ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.pairwise_dist.ops import pairwise_sq_dists
from repro.kernels.pairwise_dist.ref import pairwise_dist_ref
from repro.kernels.robust_stats.ops import robust_stats
from repro.kernels.robust_stats.ref import robust_stats_ref
from repro.kernels.weighted_agg.ops import weighted_agg
from repro.kernels.weighted_agg.ref import weighted_agg_ref

KS = [4, 5, 8, 9, 16, 20, 32]
DS = [128, 777, 2048]
BLOCKS = [256, 512]


def _rand(key, shape, dtype, scale=3.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("D", DS)
def test_robust_stats_matches_oracle(K, D):
    u = _rand(jax.random.PRNGKey(K * 1000 + D), (K, D), jnp.float32)
    got = robust_stats(u, beta=0.1, block_d=256)
    ref = robust_stats_ref(u, beta=0.1)
    for name in got._fields:
        g = getattr(got, name)
        if g is None:  # temporal tail absent without a prev input
            assert getattr(ref, name) is None
            continue
        np.testing.assert_allclose(
            g, getattr(ref, name), rtol=3e-5, atol=3e-5, err_msg=name
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_robust_stats_dtypes(dtype):
    u = _rand(jax.random.PRNGKey(7), (8, 512), dtype)
    got = robust_stats(u, beta=0.1, block_d=256)
    ref = robust_stats_ref(u.astype(jnp.float32), beta=0.1)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got.med, ref.med, rtol=tol, atol=tol)
    np.testing.assert_allclose(got.dist2, ref.dist2, rtol=tol, atol=tol * 512)


@pytest.mark.parametrize("block_d", BLOCKS)
def test_robust_stats_block_invariance(block_d):
    """Kernel output must not depend on the VMEM block size."""
    u = _rand(jax.random.PRNGKey(3), (16, 1024), jnp.float32)
    a = robust_stats(u, beta=0.1, block_d=block_d)
    b = robust_stats(u, beta=0.1, block_d=1024)
    for name in a._fields:
        if getattr(a, name) is None:
            continue
        np.testing.assert_allclose(getattr(a, name), getattr(b, name), rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("K", [4, 8, 16, 31])
@pytest.mark.parametrize("D", [128, 1000])
def test_pairwise_matches_oracle(K, D):
    u = _rand(jax.random.PRNGKey(K + D), (K, D), jnp.float32)
    got = pairwise_sq_dists(u, block_d=256)
    ref = pairwise_dist_ref(u)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-2)
    assert np.all(np.diag(np.asarray(got)) == 0.0)


@pytest.mark.parametrize("K", [4, 8, 16])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 0.8, 1.0])
def test_weighted_agg_matches_oracle(K, alpha):
    key = jax.random.PRNGKey(K)
    k1, k2, k3 = jax.random.split(key, 3)
    u = _rand(k1, (K, 700), jnp.float32)
    local = _rand(k2, (700,), jnp.float32)
    w = jnp.abs(_rand(k3, (K,), jnp.float32))
    got = weighted_agg(local, u, w, alpha=alpha, block_d=256)
    ref = weighted_agg_ref(local, u, w, alpha)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


def test_weighted_agg_zero_weights_returns_local():
    u = _rand(jax.random.PRNGKey(0), (8, 300), jnp.float32)
    local = _rand(jax.random.PRNGKey(1), (300,), jnp.float32)
    got = weighted_agg(local, u, jnp.zeros((8,)), alpha=0.8, block_d=256)
    np.testing.assert_allclose(got, local, rtol=1e-6, atol=1e-6)


# ------------------------- hypothesis property tests -------------------------

@settings(max_examples=25, deadline=None)
@given(
    K=st.integers(min_value=3, max_value=12),
    D=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_median_permutation_invariance(K, D, seed):
    """The fused stats must be invariant to candidate order (median, trim)
    and equivariant (row-permuted) for the per-candidate statistics."""
    u = np.asarray(_rand(jax.random.PRNGKey(seed), (K, D), jnp.float32))
    perm = np.random.default_rng(seed).permutation(K)
    a = robust_stats(jnp.asarray(u), beta=0.1, block_d=256)
    b = robust_stats(jnp.asarray(u[perm]), beta=0.1, block_d=256)
    np.testing.assert_allclose(a.med, b.med, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a.trim, b.trim, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.dist2)[perm], b.dist2, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    K=st.integers(min_value=3, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    shift=st.floats(min_value=-5.0, max_value=5.0, allow_nan=False),
)
def test_median_translation_equivariance(K, seed, shift):
    """median(u + c) == median(u) + c."""
    u = _rand(jax.random.PRNGKey(seed), (K, 256), jnp.float32)
    a = robust_stats(u, beta=0.1, block_d=256)
    b = robust_stats(u + shift, beta=0.1, block_d=256)
    np.testing.assert_allclose(np.asarray(a.med) + shift, b.med, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# chunked online-softmax attention vs dense reference
# ---------------------------------------------------------------------------

def test_sdpa_chunked_matches_dense_causal():
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import layers as L
    B, H, S, hd = 2, 4, 512, 32
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(kk, (B, H, S, hd), jnp.float32)
    v = jax.random.normal(kv, (B, H, S, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = (pos[:, None, :] <= pos[:, :, None])[:, None, :, :]
    scale = 1.0 / np.sqrt(hd)
    ref = L._sdpa(q, k, v, mask, scale)

    def mask_fn(off, C):
        kpos_c = off + jnp.arange(C)
        return (kpos_c[None, None, None, :] <= pos[:, None, :, None])

    out = L._sdpa_chunked(q, k, v, scale, mask_fn, chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sdpa_chunked_ragged_and_gradient():
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import layers as L
    B, H, Sq, Sk, hd = 1, 2, 64, 300, 16  # Sk not a chunk multiple
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, hd), jnp.float32)
    k = jax.random.normal(kk, (B, H, Sk, hd), jnp.float32)
    v = jax.random.normal(kv, (B, H, Sk, hd), jnp.float32)
    scale = 0.25

    ref = L._sdpa(q, k, v, None, scale)
    out = L._sdpa_chunked(q, k, v, scale, None, chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # gradients flow through the checkpointed scan
    g_ref = jax.grad(lambda q: L._sdpa(q, k, v, None, scale).sum())(q)
    g_out = jax.grad(
        lambda q: L._sdpa_chunked(q, k, v, scale, None, chunk=128).sum())(q)
    np.testing.assert_allclose(np.asarray(g_out), np.asarray(g_ref),
                               rtol=5e-5, atol=5e-5)


def test_rmsnorm_custom_vjp_matches_autodiff():
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.layers import _rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 64), jnp.float32)
    s = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)

    def ref(x, s):
        xf = x.astype(jnp.float32)
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        return xf * jax.lax.rsqrt(ms + 1e-5) * s

    o1 = _rmsnorm(x, s, 1e-5)
    o2 = ref(x, s)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5)
    g1 = jax.grad(lambda x, s: _rmsnorm(x, s, 1e-5).sum(), argnums=(0, 1))(x, s)
    g2 = jax.grad(lambda x, s: ref(x, s).sum(), argnums=(0, 1))(x, s)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash-attention Pallas kernel (interpret mode) vs dense oracle
# ---------------------------------------------------------------------------

import itertools as _it

import pytest as _pytest


@_pytest.mark.parametrize("B,H,Sq,Sk,hd,causal,dtype", [
    (1, 2, 128, 128, 64, True, "float32"),
    (2, 1, 256, 256, 32, True, "float32"),
    (1, 1, 128, 384, 64, True, "float32"),    # decode-style Sq < Sk
    (1, 2, 130, 200, 32, True, "float32"),    # ragged (padding masked)
    (1, 1, 128, 256, 64, False, "float32"),
    (1, 2, 128, 128, 64, True, "bfloat16"),
])
def test_flash_attention_kernel_matches_ref(B, H, Sq, Sk, hd, causal, dtype):
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels.flash_attn.ops import flash_attention
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, H, Sk, hd), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, H, Sk, hd), jnp.float32).astype(dt)
    scale = 1.0 / np.sqrt(hd)
    out = flash_attention(q, k, v, scale, causal=causal, block_q=64, block_k=64)
    ref = flash_attention(q, k, v, scale, causal=causal, use_kernel=False)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_head_padding_is_exact():
    """pad_heads_to is a sharding-layout change only: outputs must be
    bit-comparable with the unpadded path."""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_config
    from repro.models import layers as L
    cfg = dataclasses.replace(
        get_config("yi-6b").reduced(), n_heads=7, n_kv_heads=7, d_model=224,
        head_dim=32, vocab_size=64)
    cfgp = dataclasses.replace(cfg, pad_heads_to=8)
    key = jax.random.PRNGKey(0)
    p = L.init_attention(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 224), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    o1, _ = L.attention_fwd(cfg, p, x, pos)
    o2, _ = L.attention_fwd(cfgp, p, x, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6)
