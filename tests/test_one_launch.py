"""Single-launch gossip round: the fused WFAgg-E combine folded into the
indexed robust_stats kernel (backend="fused") must reproduce the
two-launch fallback (backend="fused_two_launch") and the valid-aware
pure-jnp reference oracle — masks bit-equal, aggregates within fp32
tolerance — across every dynamics scenario (including degree-0
churned-out rows), irregular erdos_renyi-style degrees, both filter
families, and the stacked (mode-B) layout; and the jitted round must
lower to exactly ONE aggregation pallas_call with no (N, K, d) buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wfagg as wf
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl import dynamics as dyn
from repro.dfl.engine import DFLConfig, build_round_fn, init_dfl_state

ATOL = 3e-5
BACKENDS = ("fused", "fused_two_launch", "reference")


def _matrix_state(N, K, d, cfg):
    """Matrix-prev temporal state (the engine's (N, K, d)-free layout)."""
    return wf.TemporalState(
        prev=jnp.zeros((N, d)),
        hist_s=jnp.zeros((N, cfg.window, K)),
        hist_b=jnp.zeros((N, cfg.window, K)),
        count=jnp.zeros((N,), jnp.int32),
        t=jnp.zeros((N,), jnp.int32))


def _irregular(N, K, seed=0, min_degree=0):
    """Padded (idx, valid) with per-node degrees in [min_degree, K]."""
    rng = np.random.default_rng(seed)
    idx = np.zeros((N, K), np.int32)
    valid = np.zeros((N, K), bool)
    for n in range(N):
        v = int(rng.integers(min_degree, K + 1))
        if v:
            nbrs = rng.choice([i for i in range(N) if i != n], size=v,
                              replace=False)
            idx[n, :v] = nbrs
        idx[n, v:] = n
        valid[n, :v] = True
    return jnp.asarray(idx), jnp.asarray(valid)


# ---------------------------------------------------------------------------
# parity across every dynamics scenario (single vs two-launch vs reference)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", dyn.SCENARIO_NAMES)
def test_one_launch_parity_across_scenarios(scenario):
    """Drive the schedule's round-varying slates through the gather-free
    aggregation under all three backends, with live temporal state
    re-keyed between rounds exactly like the engine: masks bit-equal,
    aggregates within fp32 tolerance, degree-0 rows keep their local
    model."""
    topo = make_topology(n_nodes=10, degree=4, n_malicious=2, kind="ring",
                         seed=0)
    params = {"churn": {"p_leave": 0.45}}.get(scenario, {})
    sched = dyn.make_schedule(scenario, topo, 3, seed=5, **params)
    N, K, d = topo.n_nodes, sched.width, 192
    cfgs = {b: wf.WFAggConfig(backend=b, transient=1, f=1) for b in BACKENDS}
    states = {b: _matrix_state(N, K, d, c) for b, c in cfgs.items()}
    prev_idx = jnp.asarray(sched.neighbor_idx[0])
    prev_val = jnp.asarray(sched.valid[0])
    saw_deg0 = False
    for r in range(sched.rounds):
        idx = jnp.asarray(sched.neighbor_idx[r])
        val = jnp.asarray(sched.valid[r])
        u = jax.random.normal(jax.random.PRNGKey(70 + r), (N, d)) + 0.3
        outs, infos = {}, {}
        for b, c in cfgs.items():
            # re-key the slot-positional ring buffers to this round's
            # slate by neighbor identity, exactly like the engine
            st = wf.realign_temporal_history(states[b], prev_idx, prev_val,
                                             idx, val)
            outs[b], states[b], infos[b] = wf.wfagg_batch(
                u, u, st, c, neighbor_idx=idx, valid=val)
        prev_idx, prev_val = idx, val
        for b in ("fused_two_launch", "reference"):
            for m in ("mask_d", "mask_c", "mask_t"):
                assert np.array_equal(np.asarray(infos["fused"][m]),
                                      np.asarray(infos[b][m])), (r, b, m)
            np.testing.assert_allclose(np.asarray(outs["fused"]),
                                       np.asarray(outs[b]),
                                       rtol=ATOL, atol=ATOL,
                                       err_msg=f"{scenario} r{r} {b}")
        deg0 = np.asarray(val).sum(axis=1) == 0
        if deg0.any():
            saw_deg0 = True
            np.testing.assert_allclose(np.asarray(outs["fused"])[deg0],
                                       np.asarray(u)[deg0],
                                       rtol=1e-6, atol=1e-6)
        assert np.isfinite(np.asarray(outs["fused"])).all()
        assert states["fused"].prev.shape == (N, d)   # matrix state kept
    if scenario == "churn":
        assert saw_deg0, "churn schedule never produced a degree-0 node"


@pytest.mark.parametrize("filters", ["wfagg", "alt"])
def test_one_launch_irregular_parity(filters):
    """erdos_renyi-style irregular padded slates, both filter families
    (Alt-WFAgg exercises the in-kernel Gram + Multi-Krum/Clustering
    derivation), temporal state live."""
    N, K, d = 9, 5, 220
    idx, val = _irregular(N, K, seed=8, min_degree=0)
    assert (np.asarray(val).sum(1) == 0).any()   # a degree-0 row rides along
    mk = wf.alt_wfagg_config if filters == "alt" else wf.WFAggConfig
    cfgs = {b: mk(backend=b, transient=1, f=1,
                  **({"multi_krum_m": 2} if filters == "alt" else {}))
            for b in BACKENDS}
    states = {b: _matrix_state(N, K, d, c) for b, c in cfgs.items()}
    for r in range(4):
        u = jax.random.normal(jax.random.PRNGKey(90 + r), (N, d)) + 0.2
        outs, infos = {}, {}
        for b, c in cfgs.items():
            outs[b], states[b], infos[b] = wf.wfagg_batch(
                u, u, states[b], c, neighbor_idx=idx, valid=val)
        for b in ("fused_two_launch", "reference"):
            for m in ("mask_d", "mask_c", "mask_t"):
                assert np.array_equal(np.asarray(infos["fused"][m]),
                                      np.asarray(infos[b][m])), (r, b, m)
            np.testing.assert_allclose(np.asarray(outs["fused"]),
                                       np.asarray(outs[b]),
                                       rtol=ATOL, atol=ATOL)


def test_one_launch_regular_matches_unmasked():
    """valid=None (regular slate) runs the same single launch with an
    implicit all-valid mask — must equal the explicit all-ones mask."""
    N, K, d = 8, 4, 300
    idx = jnp.asarray(
        [[(n + o) % N for o in range(1, K + 1)] for n in range(N)], jnp.int32)
    cfg = wf.WFAggConfig(backend="fused", use_temporal=False)
    u = jax.random.normal(jax.random.PRNGKey(4), (N, d)) + 0.1
    o1, _, i1 = wf.wfagg_batch(u, u, None, cfg, neighbor_idx=idx)
    o2, _, i2 = wf.wfagg_batch(u, u, None, cfg, neighbor_idx=idx,
                               valid=jnp.ones((N, K), bool))
    assert np.array_equal(np.asarray(i1["mask_d"]), np.asarray(i2["mask_d"]))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_one_launch_multiblock_d_matches_single_block():
    """Force n_d > 1 through the round op (interpret mode defaults to ONE
    D block): the phase boundary fires on the LAST D block, the combine
    output is pinned during phase 0 and re-walked in phase 1 — block
    count must not change the result beyond fp32 reassociation."""
    from repro.kernels.robust_stats.ops import wfagg_round_indexed

    N, K, d = 6, 4, 384
    models = jax.random.normal(jax.random.PRNGKey(12), (N, d), jnp.float32) + 0.2
    prev = jax.random.normal(jax.random.PRNGKey(13), (N, d), jnp.float32)
    idx, val = _irregular(N, K, seed=3, min_degree=1)
    cfg = wf.WFAggConfig(transient=0, f=1)
    tbands = jax.vmap(
        lambda hs, hb: wf.trust.temporal_bands(
            hs, hb, jnp.asarray(2), jnp.asarray(3), cfg)
    )(0.5 * jnp.ones((N, cfg.window, K)), 0.5 * jnp.ones((N, cfg.window, K)))
    rs = {}
    for label, block in (("one", None), ("multi", 128)):
        rs[label] = wfagg_round_indexed(models, models, idx, val, cfg,
                                        prev=prev, tbands=tbands,
                                        block_d=block)
    for a, b in zip(rs["one"], rs["multi"]):
        for ga, gb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                       rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# satellite: the reference backend's valid-aware oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("filters", ["wfagg", "alt"])
def test_reference_backend_honors_valid_mask(filters):
    """wfagg_batch(backend="reference") with a padded valid mask used to
    raise NotImplementedError; the valid-aware oracle must now match the
    plain single-node reference pipeline run on each node's TRUE (and
    compacted) neighbor slate."""
    N, K, d = 10, 6, 260
    models = jax.random.normal(jax.random.PRNGKey(9), (N, d), jnp.float32) + 0.3
    idx, valid = _irregular(N, K, seed=11, min_degree=1)
    mk = wf.alt_wfagg_config if filters == "alt" else wf.WFAggConfig
    cfg = mk(backend="reference", use_temporal=False, f=1,
             **({"multi_krum_m": 2} if filters == "alt" else {}))
    out, _, info = wf.wfagg_batch(models, models, None, cfg,
                                  neighbor_idx=idx, valid=valid)
    for n in range(N):
        sel = np.asarray(idx[n])[np.asarray(valid[n])]
        v = len(sel)
        cfg_n = mk(backend="reference", use_temporal=False, f=1,
                   **({"multi_krum_m": min(2, v)} if filters == "alt" else {}))
        out_n, _, info_n = wf.wfagg(models[n], models[jnp.asarray(sel)],
                                    None, cfg_n)
        for m in ("mask_d", "mask_c"):
            got = np.asarray(info[m][n])[np.asarray(valid[n])]
            assert np.array_equal(got, np.asarray(info_n[m])), (n, m, v)
            assert not np.asarray(info[m][n])[~np.asarray(valid[n])].any()
        np.testing.assert_allclose(np.asarray(out[n]), np.asarray(out_n),
                                   rtol=ATOL, atol=ATOL, err_msg=str(n))


def test_reference_backend_degree0_keeps_local():
    N, K, d = 6, 3, 128
    idx, valid = _irregular(N, K, seed=2, min_degree=0)
    valid = valid.at[1].set(False)        # force at least one empty slate
    idx = idx.at[1].set(1)
    cfg = wf.WFAggConfig(backend="reference", use_temporal=False, f=1)
    u = jax.random.normal(jax.random.PRNGKey(1), (N, d)) + 0.1
    out, _, info = wf.wfagg_batch(u, u, None, cfg, neighbor_idx=idx,
                                  valid=valid)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(u[1]),
                               rtol=1e-6, atol=1e-6)
    assert int(np.asarray(info["n_accepted"])[1]) == 0


# ---------------------------------------------------------------------------
# launch-count + HLO assertions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", ["wfagg", "alt_wfagg"])
def test_round_is_single_pallas_launch(aggregator):
    """The jitted dynamic round must contain exactly ONE aggregation
    pallas_call under the single-launch backend (the two-launch fallback
    keeps two — sanity check that the counter sees them), and its
    compiled HLO must stay (N, K, d)-free.  Both properties are asserted
    through the shared ``repro.analysis`` rule API (the same walkers the
    ``python -m repro.analysis`` gate runs)."""
    from repro.analysis import count_pallas_calls, scan_nkd_buffers

    topo = make_topology(n_nodes=10, degree=4, n_malicious=2, kind="ring",
                         seed=0)
    data = SyntheticImages()
    sched = dyn.churn_schedule(topo, 3, seed=1)
    N, K = topo.n_nodes, sched.width
    counts = {}
    for backend in ("fused", "fused_two_launch"):
        cfg = DFLConfig(aggregator=aggregator, attack="ipm_100", model="mlp",
                        wfagg_backend=backend)
        fn = build_round_fn(cfg, topo, data, dynamic=True)
        state = init_dfl_state(cfg, topo, degree=K)
        args = (state, jnp.asarray(sched.neighbor_idx[0]),
                jnp.asarray(sched.valid[0]), jnp.asarray(sched.malicious[0]))
        jaxpr = jax.make_jaxpr(fn)(*args)
        counts[backend] = count_pallas_calls(jaxpr.jaxpr)
        if backend == "fused":
            hlo = fn.lower(*args).compile().as_text()
            # d-sized (N, K, d) buffers only: the alt_wfagg (N, K, K)
            # Gram is a legit O(K^2) statistic, not a gossip tensor
            hits = scan_nkd_buffers(hlo, N, K, min_d=16 * K)
            assert hits == [], hits
    assert counts["fused"] == 1, counts
    assert counts["fused_two_launch"] >= 2, counts


def test_memory_passes_one_launch_accounting():
    """The indexed single-launch round reports ~1 candidate pass; the
    two-launch fallback keeps 2; Alt-WFAgg folds its Gram in-kernel."""
    one = wf.WFAggConfig()
    two = wf.WFAggConfig(backend="fused_two_launch")
    assert wf.memory_passes(one, include_gather=True, indexed=True) == 1
    assert wf.memory_passes(two, include_gather=True, indexed=True) == 2
    assert wf.memory_passes(
        wf.alt_wfagg_config(), include_gather=True, indexed=True) == 1
    # non-indexed entries keep the two-launch accounting
    assert wf.memory_passes(one) == 2
    assert wf.memory_passes(wf.alt_wfagg_config()) == 3


# ---------------------------------------------------------------------------
# stacked (mode-B) layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["wfagg", "alt_wfagg"])
def test_stacked_one_launch_matches_fallbacks(method):
    import dataclasses

    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = 6
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 24, 6)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (K, 80))}
    wcfg = wf.WFAggConfig(f=1, transient=1, window=2)
    base = RobustAggConfig(method=method, wfagg=wcfg, layout="stacked")
    cfgs = {b: dataclasses.replace(base, backend=b) for b in BACKENDS}
    like = jax.tree.map(lambda x: x[0], g)
    states = {b: init_tree_agg_state(c, K, like) for b, c in cfgs.items()}
    for r in range(4):
        gr = jax.tree.map(lambda x: x + 0.1 * r, g)
        res = {}
        for b, c in cfgs.items():
            out, states[b], info = robust_allreduce_stacked(gr, c, states[b])
            res[b] = (out, info)
        for b in ("fused_two_launch", "reference"):
            np.testing.assert_allclose(
                np.asarray(res["fused"][1]["weights"]),
                np.asarray(res[b][1]["weights"]), atol=ATOL)
            for k in g:
                np.testing.assert_allclose(
                    np.asarray(res["fused"][0][k]),
                    np.asarray(res[b][0][k]), rtol=1e-4, atol=ATOL,
                    err_msg=(r, b, k))
