"""Tier-1 coverage for the SPMD communication-contract analyzer.

Three layers:
  * registry + contract well-formedness and the per-rule doctored
    fire/quiet fixtures — 1-device safe, always run;
  * text-level unit tests of the collective parser in
    ``repro.launch.hlo_analysis`` (replica groups, wire-byte model);
  * the 8-virtual-device checks (sharded-vs-single-process parity,
    the real-artifact lint gate, the replicated-output fire test) —
    subprocesses with ``XLA_FLAGS=--xla_force_host_platform_device_
    count=8`` set BEFORE jax imports, marked slow.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import RULES_BY_ID, collectives, selftest
from repro.launch import hlo_analysis as ha

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PARITY = os.path.join(REPO, "tests", "_spmd_parity_main.py")

SPMD_RULES = (
    "spmd-collective-contract",
    "spmd-model-dim-allgather",
    "spmd-replica-groups",
    "spmd-wire-budget",
    "spmd-sharded-nkd-buffer",
)
SHARDED_ENTRIES = (
    "sharded_one_launch_round",
    "sharded_dynamic_scan",
    "sharded_stacked_mode_b",
)


# ---------------------------------------------------------------- registry

def test_spmd_rules_registered():
    for rid in SPMD_RULES:
        assert rid in RULES_BY_ID, rid
        rule = RULES_BY_ID[rid]
        assert rule.severity == "error"
        assert rule.layer == "hlo"


def test_sharded_entries_registered():
    from repro.analysis.entry_points import entry_points

    entries = entry_points()
    for name in SHARDED_ENTRIES:
        assert name in entries, name
        e = entries[name]
        assert e.min_devices == 8
        assert e.contract is not None
        assert e.contract.axis_size == 8
        # the contract must serialize into the JSON report
        d = json.loads(json.dumps(e.contract.to_dict()))
        assert d["axis_size"] == 8
        assert d["wire_budget_bytes"] > 0
        assert "all-reduce" in d["allowed_kinds"]


@pytest.mark.parametrize("rid", SPMD_RULES)
def test_spmd_rule_fires_on_doctored_fixture(rid):
    """One doctored fire + quiet pair per SPMD rule (the selftest body —
    SystemExit means the rule stopped firing or fired on clean HLO)."""
    getattr(selftest, "test_" + rid.replace("-", "_"))()


# ---------------------------------------------------------------- contracts

def test_round_contract_scales_with_rounds():
    one = collectives.wfagg_round_contract(10, 4, 8, rounds=1)
    three = collectives.wfagg_round_contract(10, 4, 8, rounds=3)
    assert three.wire_budget_bytes == pytest.approx(3 * one.wire_budget_bytes)
    # the per-collective ceiling is O(N*K), not O(rounds)
    assert three.max_collective_bytes == one.max_collective_bytes
    # an f32 (N, K) psum payload fits under the ceiling; a model-dim
    # gather of even one row does not
    assert 4 * 10 * 4 <= one.max_collective_bytes
    assert one.max_collective_bytes < 4 * 50896


def test_stacked_contract_allows_gram():
    c = collectives.stacked_allreduce_contract(6, 8)
    assert c.max_collective_bytes >= 4 * 6 * 6  # f32 (K, K) Gram psum
    assert c.allowed_kinds == ("all-reduce",)


# ---------------------------------------------------------- HLO parsing

def test_parse_replica_groups_forms():
    form, groups, size, n = ha.parse_replica_groups(
        "replica_groups={{0,1,2,3},{4,5,6,7}}", 8)
    assert (form, size, n) == ("list", 4, 2)
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]

    form, groups, size, n = ha.parse_replica_groups(
        "replica_groups=[2,4]<=[8]", 8)
    assert (form, groups, size, n) == ("iota", None, 4, 2)

    form, _, size, _ = ha.parse_replica_groups(
        "source_target_pairs={{0,1},{1,0}}", 8)
    assert (form, size) == ("pairs", 2)

    form, _, size, _ = ha.parse_replica_groups("all-reduce(f32[4] x)", 8)
    assert (form, size) == ("default", 8)


def test_collective_covers_mesh():
    rec = ha.Collective(name="ar", kind="all-reduce", out_bytes=160,
                        group_size=8, n_groups=1,
                        groups=[[0, 1, 2, 3, 4, 5, 6, 7]],
                        group_form="list", wire_bytes=280.0, mult=1.0,
                        line="")
    assert rec.covers_mesh(8) is True
    assert rec.covers_mesh(16) is False
    part = ha.Collective(name="ar", kind="all-reduce", out_bytes=160,
                         group_size=4, n_groups=1, groups=[[0, 1, 2, 3]],
                         group_form="list", wire_bytes=240.0, mult=1.0,
                         line="")
    assert part.covers_mesh(8) is False
    iota = ha.Collective(name="ar", kind="all-reduce", out_bytes=160,
                         group_size=8, n_groups=1, groups=None,
                         group_form="iota", wire_bytes=280.0, mult=1.0,
                         line="")
    assert iota.covers_mesh(8) is True
    dflt = ha.Collective(name="ar", kind="all-reduce", out_bytes=160,
                         group_size=8, n_groups=1, groups=None,
                         group_form="default", wire_bytes=280.0, mult=1.0,
                         line="")
    assert dflt.covers_mesh(8) is None


def test_analyze_collective_table_on_doctored_hlo():
    """The clean SPMD fixture yields exactly one all-reduce record with
    the ring-model wire bytes: 2 * 160 B * 7/8 = 280 B/device."""
    cost = ha.analyze(selftest._SPMD_CLEAN_HLO, n_devices=8)
    assert cost.num_partitions == 8
    assert cost.collectives is not None and len(cost.collectives) == 1
    rec = cost.collectives[0]
    assert rec.kind == "all-reduce"
    assert rec.out_bytes == 4 * 10 * 4
    assert rec.group_size == 8 and rec.covers_mesh(8) is True
    assert rec.wire_bytes == pytest.approx(2 * 160 * 7 / 8)
    assert cost.wire_bytes == pytest.approx(rec.wire_bytes)


def test_contract_cost_memoized():
    from repro.analysis.artifacts import Artifacts

    art = Artifacts.from_hlo(selftest._SPMD_CLEAN_HLO)
    c1 = collectives.contract_cost(art, 8)
    c2 = collectives.contract_cost(art, 8)
    assert c1 is c2
    assert collectives.contract_cost(art, 4) is not c1


# ---------------------------------------------------------- 1-device CLI

def test_cli_skips_sharded_entries_below_min_devices(tmp_path):
    """On fewer than 8 devices the sharded gates record a skip (never a
    silent drop) and the report carries schema_version."""
    import jax

    if len(jax.devices()) >= 8:
        pytest.skip("session already has 8 devices; skip path untestable")
    from repro.analysis.__main__ import SCHEMA_VERSION, main as lint_main

    out = tmp_path / "report.json"
    rc = lint_main(["--entry", "sharded_one_launch_round",
                    "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["schema_version"] == SCHEMA_VERSION
    rec = report["entries"]["sharded_one_launch_round"]
    assert "skipped" in rec and "XLA_FLAGS" in rec["skipped"]


# ------------------------------------------------------- 8-device checks

def _run_8dev(argv, timeout=1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable] + argv, capture_output=True,
                          text=True, env=env, timeout=timeout, cwd=REPO)
    assert proc.returncode == 0, (
        f"exit {proc.returncode}\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["round", "scan", "stacked", "engine",
                                  "gather_fire"])
def test_spmd_parity_8dev(mode):
    out = _run_8dev([PARITY, mode])
    assert f"PARITY_OK:{mode}" in out


@pytest.mark.slow
def test_sharded_lint_gate_8dev(tmp_path):
    """The acceptance gate: lint all three sharded entries on 8 virtual
    devices — zero gate failures, contracts + collective tables in the
    JSON report."""
    out = tmp_path / "lint_report_spmd.json"
    stdout = _run_8dev(["-m", "repro.analysis",
                        "--entry", "sharded_one_launch_round",
                        "--entry", "sharded_dynamic_scan",
                        "--entry", "sharded_stacked_mode_b",
                        "--json", str(out)])
    assert "repro.analysis: OK" in stdout
    report = json.loads(out.read_text())
    assert report["summary"]["ok"] and report["summary"]["n_errors"] == 0
    assert report["meta"]["n_devices"] >= 8
    for name in SHARDED_ENTRIES:
        rec = report["entries"][name]
        assert "skipped" not in rec
        assert rec["contract"]["axis_size"] == 8
        colls = rec["cost"]["collectives"]
        assert colls, f"{name}: no collectives parsed"
        assert all(c["kind"] == "all-reduce" for c in colls)
        wire = sum(c["mult"] * c["wire_bytes"] for c in colls)
        assert 0 < wire <= rec["contract"]["wire_budget_bytes"]
