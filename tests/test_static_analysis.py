"""Tier-1 coverage for the ``repro.analysis`` computation linter.

Two layers:
  * every rule's doctored-fixture self-test (the same code behind
    ``python -m repro.analysis --self-test``) runs as a pytest case, so
    a rule that stops firing breaks CI even if nobody runs the CLI;
  * cheap unit tests of the text-level scanners and the entry-point
    registry's well-formedness that don't build any real round.
"""
import inspect

import pytest

from repro.analysis import (
    RULES,
    RULES_BY_ID,
    parse_suppressions,
    scan_gather_model_dim,
    scan_nkd_buffers,
)
from repro.analysis import selftest


_SELFTESTS = [
    fn for name, fn in sorted(vars(selftest).items())
    if name.startswith("test_") and inspect.isfunction(fn)
]


@pytest.mark.parametrize("check", _SELFTESTS, ids=lambda f: f.__name__)
def test_rule_selftest(check):
    """Each rule fires on its doctored fixture and stays quiet on the
    clean twin (SystemExit signals a broken rule)."""
    check()


def test_every_rule_has_a_selftest():
    covered = {name.replace("test_", "").replace("_", "-")
               for name in (f.__name__ for f in _SELFTESTS)}
    missing = [r.id for r in RULES if r.id not in covered]
    assert not missing, f"rules without a firing self-test: {missing}"


def test_rule_registry_well_formed():
    assert len({r.id for r in RULES}) == len(RULES)
    for r in RULES:
        assert r.severity in ("error", "warning", "info"), r.id
        assert r.layer in ("jaxpr", "hlo", "pallas", "runtime", "config"), r.id
        assert RULES_BY_ID[r.id] is r


def test_entry_registry_well_formed():
    # Import deferred: entry_points() builds nothing until called, but the
    # module pulls in the dfl engine, so keep it out of collection cost.
    from repro.analysis.entry_points import entry_points

    entries = entry_points()
    assert set(entries) >= {
        "one_launch_round", "two_launch_round", "reference_round",
        "dynamic_scan", "stacked_mode_b",
    }
    for name, ep in entries.items():
        assert ep.name == name
        assert ep.expected_launches is None or ep.expected_launches >= 0
        unknown = ep.suppress - {r.id for r in RULES}
        assert not unknown, f"{name} suppresses unknown rules: {unknown}"


def test_scan_nkd_buffers_text_level():
    hlo = (
        "ENTRY main {\n"
        "  %a = f32[10,4,50890]{2,1,0} broadcast()\n"
        "  %b = f32[10,4,64]{2,1,0} broadcast()\n"
        "  %c = f32[10,4,4]{2,1,0} broadcast()\n"
        "}\n"
    )
    assert scan_nkd_buffers(hlo, 10, 4) == [4, 64, 50890]
    # min_d spares the (N, K, K) Alt-WFAgg Gram and small scratch
    assert scan_nkd_buffers(hlo, 10, 4, min_d=65) == [50890]
    assert scan_nkd_buffers(hlo, 7, 3) == []


def test_scan_gather_model_dim_text_level():
    hlo = (
        "ENTRY main {\n"
        '  %g = f32[4,50890]{1,0} gather(%o, %i), offset_dims={1}\n'
        '  %s = f32[4,8]{1,0} gather(%o2, %i2), offset_dims={1}\n'
        "}\n"
    )
    assert len(scan_gather_model_dim(hlo, min_d=25445)) == 1
    assert len(scan_gather_model_dim(hlo, min_d=8)) == 2
    assert scan_gather_model_dim(hlo, min_d=60000) == []


def test_parse_suppressions():
    sup = parse_suppressions(["no-nkd-buffer@reference_round",
                              "no-nkd-buffer@other",
                              "unknown-trip-count"])
    assert sup["unknown-trip-count"] is None  # all entries
    assert sup["no-nkd-buffer"] == {"reference_round", "other"}
    with pytest.raises(ValueError):
        parse_suppressions(["not-a-rule"])
