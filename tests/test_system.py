"""End-to-end behaviour tests: the paper's DFL system + the mode-B
robust-DP trainer, plus hypothesis property tests on WFAgg invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core import aggregators as agg_lib
from repro.core import metrics as met
from repro.core import wfagg as wf
from repro.core.topology import make_topology, paper_topology
from repro.data.synthetic import SyntheticImages, TokenStream
from repro.dfl.engine import DFLConfig, build_round_fn, evaluate, init_dfl_state, run_experiment
from repro.launch.mesh import make_test_mesh


# ---------------------------------------------------------------------------
# DFL engine end-to-end (mode A, the paper's experiment)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def data():
    return SyntheticImages()


@pytest.fixture(scope="module")
def topo():
    return paper_topology()


def test_dfl_round_runs_and_improves(data, topo):
    cfg = DFLConfig(aggregator="wfagg", attack="none", model="mlp")
    out = run_experiment(cfg, topo, data, rounds=3, eval_every=3)
    acc = out["final"]["acc_benign_mean"]
    assert np.isfinite(acc)
    assert acc > 0.3  # 10-class task, random = 0.1


def test_dfl_wfagg_resists_ipm100_where_mean_collapses(data, topo):
    """The paper's central qualitative claim (Table I, IPM-100 row)."""
    accs = {}
    for agg in ("mean", "wfagg"):
        cfg = DFLConfig(aggregator=agg, attack="ipm_100", model="mlp")
        out = run_experiment(cfg, topo, data, rounds=4, eval_every=4)
        accs[agg] = out["final"]["acc_benign_mean"]
    # 4 rounds is enough for the qualitative gap (full collapse of the
    # mean takes the paper's 10 rounds); WFAgg must stay near-perfect.
    assert accs["wfagg"] > 0.9
    assert accs["wfagg"] > accs["mean"] + 0.2


def test_dfl_noise_attack_mean_vs_median(data, topo):
    accs = {}
    for agg in ("mean", "median"):
        cfg = DFLConfig(aggregator=agg, attack="noise", model="mlp")
        out = run_experiment(cfg, topo, data, rounds=4, eval_every=4)
        accs[agg] = out["final"]["acc_benign_mean"]
    assert accs["median"] > accs["mean"]


def test_dfl_centralized_mode(data):
    topo = make_topology(kind="complete")
    cfg = DFLConfig(aggregator="multi_krum", attack="sign_flip", model="mlp",
                    centralized=True)
    out = run_experiment(cfg, topo, data, rounds=3, eval_every=3)
    assert out["final"]["acc_benign_mean"] > 0.3


def test_dfl_temporal_state_progresses(data, topo):
    cfg = DFLConfig(aggregator="wfagg", model="mlp")
    state = init_dfl_state(cfg, topo)
    fn = build_round_fn(cfg, topo, data)
    s1 = fn(state)
    s2 = fn(s1)
    assert int(s2.temporal.t[0]) == 2
    assert int(s2.rnd) == 2
    # no NaNs anywhere in node params
    for leaf in jax.tree.leaves(s2.node_params):
        assert bool(jnp.isfinite(leaf).all())


def test_r2_metric_definition():
    v = jnp.ones((5, 16))
    assert met.r_squared(v) == pytest.approx(1.0)  # identical vectors
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (8, 64))
    r2 = float(met.r_squared(v))
    assert r2 < 0.6  # independent vectors: mean explains little


# ---------------------------------------------------------------------------
# mode-B robust-DP trainer (the production adaptation)
# ---------------------------------------------------------------------------

def _tiny_train(attack: str, method: str, n_malicious: int, steps: int = 4):
    from repro.core.wfagg import WFAggConfig
    from repro.distributed.robust_allreduce import RobustAggConfig
    from repro.train import trainer as tr

    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32)
    mesh = make_test_mesh(data=jax.device_count(), model=1)
    tc = tr.TrainConfig(
        mode="robust_dp",
        agg=RobustAggConfig(method=method,
                            wfagg=WFAggConfig(f=1, transient=1, window=2),
                            chunk_size=4096, sketch_dim=256),
        attack=attack, n_malicious=n_malicious, donate=False, lr=1e-3)
    state = tr.init_train_state(cfg, tc, jax.random.PRNGKey(0), mesh)
    step = tr.build_train_step(cfg, tc, mesh)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)
    losses = []
    with mesh:
        for i in range(steps):
            state, m = step(state, stream.batch(i))
            losses.append(float(m["loss"]))
    return losses, state


@pytest.mark.slow
def test_robust_dp_trainer_loss_decreases():
    losses, state = _tiny_train("none", "wfagg", 0, steps=6)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_robust_dp_trainer_survives_ipm_attack():
    # single-device CPU run: the candidate axis has size 1 when
    # jax.device_count()==1, so the attack is a no-op there; assert
    # finiteness + state advance (the multi-device behaviour is covered
    # by test_robust_allreduce_consensus_identical_output below).
    losses, state = _tiny_train("ipm_100", "wfagg", 1, steps=4)
    assert all(np.isfinite(losses))
    assert int(state.step) == 4


# ---------------------------------------------------------------------------
# property tests: WFAgg invariants (hypothesis)
# ---------------------------------------------------------------------------

K_ST = st.integers(min_value=6, max_value=12)
D_ST = st.integers(min_value=4, max_value=64)


def _updates(key, K, d, spread=1.0):
    return spread * jax.random.normal(jax.random.PRNGKey(key), (K, d))


@settings(max_examples=20, deadline=None)
@given(K=K_ST, d=D_ST, seed=st.integers(0, 2**16), perm_seed=st.integers(0, 2**16))
def test_wfagg_d_permutation_equivariant(K, d, seed, perm_seed):
    """Filter decisions follow the candidates when they are shuffled."""
    u = _updates(seed, K, d)
    perm = jax.random.permutation(jax.random.PRNGKey(perm_seed), K)
    m1 = np.asarray(wf.wfagg_d_select(u, f=2))
    m2 = np.asarray(wf.wfagg_d_select(u[perm], f=2))
    assert m1.sum() == m2.sum() == K - 3
    # ties in distance can swap which duplicate is kept; compare distances
    med = np.median(np.asarray(u), axis=0)
    d1 = np.sort(((np.asarray(u) - med) ** 2).sum(-1)[m1])
    d2 = np.sort(((np.asarray(u[perm]) - med) ** 2).sum(-1)[m2])
    np.testing.assert_allclose(d1, d2, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(K=K_ST, d=D_ST, seed=st.integers(0, 2**16), scale=st.floats(10.0, 1e4))
def test_wfagg_d_rejects_far_outlier(K, d, seed, scale):
    u = np.array(_updates(seed, K, d))
    u[0] = scale * (1.0 + np.abs(u[0]))  # far outlier
    mask = np.asarray(wf.wfagg_d_select(jnp.asarray(u), f=2))
    assert not mask[0]


@settings(max_examples=20, deadline=None)
@given(K=K_ST, d=D_ST, seed=st.integers(0, 2**16))
def test_wfagg_c_rejects_sign_flipped(K, d, seed):
    u = np.array(_updates(seed, K, d)) + 3.0  # common direction offset
    u[1] = -u[1]
    mask = np.asarray(wf.wfagg_c_select(jnp.asarray(u), f=2))
    assert not mask[1]


@settings(max_examples=15, deadline=None)
@given(K=K_ST, d=D_ST, seed=st.integers(0, 2**16),
       alpha=st.floats(0.0, 1.0))
def test_wfagg_e_convexity(K, d, seed, alpha):
    """Output norm bounded by the max input norm (convex combination)."""
    u = _updates(seed, K, d)
    local = jnp.zeros((d,))
    weights = jnp.ones((K,))
    out = wf.wfagg_e(local, u, weights, alpha)
    bound = float(jnp.max(jnp.linalg.norm(u, axis=1)))
    assert float(jnp.linalg.norm(out)) <= bound + 1e-4


@settings(max_examples=15, deadline=None)
@given(K=K_ST, d=D_ST, seed=st.integers(0, 2**16))
def test_wfagg_zero_weights_keeps_local(K, d, seed):
    """If every filter rejects everything, the node keeps its local model."""
    u = _updates(seed, K, d)
    local = jnp.full((d,), 7.0)
    out = wf.wfagg_e(local, u, jnp.zeros((K,)), alpha=0.8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(local), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(K=st.integers(6, 10), d=D_ST, seed=st.integers(0, 2**16))
def test_median_between_minmax(K, d, seed):
    u = _updates(seed, K, d)
    out, _ = agg_lib.median_agg(u)
    lo, hi = np.asarray(u).min(0), np.asarray(u).max(0)
    o = np.asarray(out)
    assert (o >= lo - 1e-6).all() and (o <= hi + 1e-6).all()


@settings(max_examples=15, deadline=None)
@given(K=st.integers(6, 10), d=D_ST, seed=st.integers(0, 2**16),
       eps=st.floats(10.0, 100.0))
def test_krum_never_selects_far_ipm_attacker(K, d, seed, eps):
    u = np.array(_updates(seed, K, d)) + 2.0
    mu = u[2:].mean(0)
    u[0] = u[1] = -eps * mu  # 2 colluding far IPM attackers
    _, sel = agg_lib.krum_agg(jnp.asarray(u), f=2)
    chosen = int(np.asarray(sel).argmax())
    assert chosen >= 2


# ---------------------------------------------------------------------------
# robust_allreduce consensus (multi-device only; skipped on 1 CPU device)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 4, reason="needs >=4 devices")
def test_robust_allreduce_consensus_identical_output():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.robust_allreduce import RobustAggConfig, robust_allreduce

    mesh = make_test_mesh(data=4, model=1)
    cfg = RobustAggConfig(method="wfagg", chunk_size=1024,
                          wfagg=wf.WFAggConfig(f=1, use_temporal=False))
    d = 3000

    def fn(x):
        out, _, info = robust_allreduce(x, "data", cfg, None)
        return out, info["weights"]

    from repro.distributed.sharding import shard_map_compat
    sf = shard_map_compat(fn, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P(), P()), check_vma=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (4 * d,))
    out, w = jax.jit(sf)(x)
    assert out.shape == (d,)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.skipif(jax.device_count() < 4, reason="needs >=4 devices")
def test_stacked_layout_matches_flat_layout():
    """The sharded stacked fast path must reach the same consensus
    (weights + aggregated gradient) as the paper-shaped flat layout."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, robust_allreduce, robust_allreduce_stacked)

    mesh = make_test_mesh(data=4, model=1)
    wcfg = wf.WFAggConfig(f=1, use_temporal=False)
    grads = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (4, 32, 8)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (4, 100)),
    }

    def flat_fn(a, b):
        from jax.flatten_util import ravel_pytree
        flat, unravel = ravel_pytree({"a": a, "b": b})
        cfg = RobustAggConfig(method="wfagg", wfagg=wcfg, chunk_size=64)
        out, _, info = robust_allreduce(flat, "data", cfg, None)
        return unravel(out), info["weights"]

    from repro.distributed.sharding import shard_map_compat
    sf = shard_map_compat(flat_fn, mesh=mesh, in_specs=(P("data"), P("data")),
                          out_specs=(({"a": P(), "b": P()}), P()),
                          check_vma=False)
    (oa_f, w_f) = jax.jit(sf)(grads["a"], grads["b"])

    # stacked path is pure GSPMD — call it directly on the (K, ...) arrays
    cfg_s = RobustAggConfig(method="wfagg", wfagg=wcfg, layout="stacked")
    # candidate axis = dim 0; per-candidate payload keeps its own shape
    (oa_t, _, info_t) = jax.jit(
        lambda g: robust_allreduce_stacked(g, cfg_s, None))(grads)
    w_t = info_t["weights"]
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_t), atol=1e-6)
    # flat leaves keep the per-worker leading (1, ...) payload dim — squeeze
    np.testing.assert_allclose(np.asarray(oa_f["a"])[0], np.asarray(oa_t["a"]),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(oa_f["b"])[0], np.asarray(oa_t["b"]),
                               rtol=2e-5, atol=1e-6)


def test_stacked_attack_matches_distributed_semantics():
    """apply_stacked_attack (vectorized, pure GSPMD) must equal the
    per-worker apply_distributed_attack semantics for the omniscient
    attacks (IPM / ALIE use benign-cohort statistics)."""
    from repro.distributed.robust_allreduce import apply_stacked_attack

    K, d = 8, 64
    g = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    malicious = jnp.zeros((K,), bool).at[1].set(True).at[5].set(True)
    benign = np.asarray(g)[~np.asarray(malicious)]
    mu = benign.mean(0)

    out = apply_stacked_attack({"w": g}, malicious, "ipm_100",
                               jax.random.PRNGKey(1))["w"]
    # rtol 2e-5: the jnp masked-sum mean and the numpy fancy-indexed mean
    # accumulate in different orders; eps=100 amplifies the f32 noise
    np.testing.assert_allclose(np.asarray(out[1]), -100.0 * mu, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(g[0]))

    out = apply_stacked_attack({"w": g}, malicious, "alie",
                               jax.random.PRNGKey(1))["w"]
    sd = benign.std(0)
    np.testing.assert_allclose(np.asarray(out[5]), mu - 0.5 * sd, rtol=1e-4)

    out = apply_stacked_attack({"w": g}, malicious, "sign_flip",
                               jax.random.PRNGKey(1))["w"]
    np.testing.assert_allclose(np.asarray(out[1]), -np.asarray(g[1]))
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(g[2]))
