"""Flight recorder (repro.obs): the decision plane must be a pure
*observer* — verdict bits bit-equal to the masks the aggregation already
computes (reference backend as oracle, across every dynamics scenario
and all three WFAgg backends), model trajectories bit-identical with
telemetry on or off — and the export plane must round-trip its own
schema (JSONL log, Perfetto trace, audit rates on hand-built verdicts).
docs/OBSERVABILITY.md documents the planes; the launch-count/purity
side is pinned statically by the ``dynamic_scan_telemetry`` entry of
``repro.analysis`` (tests/test_static_analysis.py)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wfagg as wf
from repro.core.topology import make_topology
from repro.data.synthetic import SyntheticImages
from repro.dfl.dynamics import SCENARIO_NAMES, make_schedule
from repro.dfl.engine import DFLConfig, run_dynamic_experiment, run_experiment
from repro.obs import decision as obs
from repro.obs import profile as obs_profile
from repro.obs import recorder as obs_recorder
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace

BACKENDS = ("fused", "fused_two_launch", "reference")


# ---------------------------------------------------------------------------
# decision plane: pack/unpack + record semantics
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    masks = {name: jnp.asarray(rng.random((6, 4)) < 0.5)
             for name in obs.BITS}
    v = obs.pack_verdict(masks["mask_d"], masks["mask_c"], masks["mask_t"],
                         masks["valid"], masks["accepted"])
    assert np.asarray(v).dtype == np.uint8
    back = obs.unpack_verdict(np.asarray(v))
    for name in obs.BITS:
        assert np.array_equal(back[name], np.asarray(masks[name])), name


def test_record_from_masks_semantics():
    """Hand-built 3-node slate: normal node, all-rejected node
    (mean-fallback), padded-away node (degree zero)."""
    t = True
    f = False
    mask = jnp.asarray([[t, t, f], [f, f, f], [f, f, f]])
    valid = jnp.asarray([[t, t, t], [t, t, f], [f, f, f]])
    weights = jnp.asarray([[0.5, 0.5, 0.0], [0.0, 0.0, 0.0], [0.0, 0.0, 0.0]])
    rec = obs.record_from_masks(mask, mask, mask, valid, weights)
    assert np.array_equal(np.asarray(rec.accepted), [2, 0, 0])
    assert np.array_equal(np.asarray(rec.mean_fallback), [False, True, False])
    assert np.array_equal(np.asarray(rec.degree_zero), [False, False, True])
    ent = np.asarray(rec.entropy)
    # two equal weights -> log 2; all-rejected / degree-0 -> defined as 0
    np.testing.assert_allclose(ent[0], np.log(2.0), rtol=1e-6)
    assert ent[1] == 0.0 and ent[2] == 0.0
    bits = obs.unpack_verdict(np.asarray(rec.verdict))
    assert np.array_equal(bits["valid"], np.asarray(valid))
    assert np.array_equal(bits["accepted"], np.asarray(weights > 0))


def test_record_uniform_baselines():
    valid = jnp.asarray([[True, True, False], [False, False, False]])
    rec = obs.record_uniform(valid)
    bits = obs.unpack_verdict(np.asarray(rec.verdict))
    # filter bits stay 0 (a report must check BIT_ACCEPTED first)
    for name in ("mask_d", "mask_c", "mask_t"):
        assert not bits[name].any(), name
    assert np.array_equal(bits["accepted"], np.asarray(valid))
    assert np.array_equal(np.asarray(rec.accepted), [2, 0])
    assert np.array_equal(np.asarray(rec.degree_zero), [False, True])
    np.testing.assert_allclose(np.asarray(rec.entropy), [np.log(2.0), 0.0],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# verdict bitmask vs the reference backend's masks, every scenario x backend
# ---------------------------------------------------------------------------

def _scenario_records(scenario, backend, rounds=4, N=8, K=4, d=96):
    """Drive wfagg_batch round by round over a scenario's slates (the
    engine's matrix-prev temporal layout + per-round history realign)
    and collect the DecisionRecord of every round."""
    topo = make_topology(n_nodes=N, degree=K, n_malicious=2, kind="ring",
                         seed=0)
    sched = make_schedule(scenario, topo, rounds, seed=0)
    K = sched.neighbor_idx.shape[-1]  # rewiring may widen the padded slate
    cfg = wf.WFAggConfig(backend=backend, f=1, transient=1, window=2)
    st = wf.TemporalState(
        prev=jnp.zeros((N, d)),
        hist_s=jnp.zeros((N, cfg.window, K)),
        hist_b=jnp.zeros((N, cfg.window, K)),
        count=jnp.zeros((N,), jnp.int32), t=jnp.zeros((N,), jnp.int32))
    recs = []
    for r in range(rounds):
        idx = jnp.asarray(sched.neighbor_idx[r])
        val = jnp.asarray(sched.valid[r], bool)
        if r > 0:
            st = wf.realign_temporal_history(
                st, jnp.asarray(sched.neighbor_idx[r - 1]),
                jnp.asarray(sched.valid[r - 1], bool), idx, val)
        u = jax.random.normal(jax.random.PRNGKey(100 + r), (N, d)) + 0.3
        _, st, info = wf.wfagg_batch(u, u, st, cfg, neighbor_idx=idx,
                                     valid=val)
        recs.append(jax.device_get(obs.record_from_info(info)))
    return recs


@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_verdict_matches_reference_masks_every_scenario(scenario):
    """The packed verdict of EVERY backend must agree bit-for-bit with
    the reference backend's masks on the valid lanes, for every
    dynamics scenario (padded slates, churn, DoS'd nodes included)."""
    per_backend = {b: _scenario_records(scenario, b) for b in BACKENDS}
    ref = per_backend["reference"]
    for b in BACKENDS:
        for r, (rec, rec_ref) in enumerate(zip(per_backend[b], ref)):
            bits = obs.unpack_verdict(np.asarray(rec.verdict))
            ref_bits = obs.unpack_verdict(np.asarray(rec_ref.verdict))
            assert np.array_equal(bits["valid"], ref_bits["valid"]), (b, r)
            valid = bits["valid"]
            for name in ("mask_d", "mask_c", "mask_t", "accepted"):
                assert np.array_equal(bits[name][valid],
                                      ref_bits[name][valid]), \
                    (scenario, b, r, name)
            for field in ("accepted", "mean_fallback", "degree_zero"):
                assert np.array_equal(np.asarray(getattr(rec, field)),
                                      np.asarray(getattr(rec_ref, field))), \
                    (scenario, b, r, field)


def test_record_from_info_reflects_info_masks():
    """record_from_info is a pure repack: the unpacked bits must equal
    the info dict's own masks exactly (valid lanes AND padding)."""
    for scenario in ("static", "eclipse"):
        topo = make_topology(n_nodes=8, degree=4, n_malicious=2, kind="ring",
                             seed=0)
        sched = make_schedule(scenario, topo, 3, seed=0)
        cfg = wf.WFAggConfig(backend="fused", f=1)
        idx = jnp.asarray(sched.neighbor_idx[-1])
        val = jnp.asarray(sched.valid[-1], bool)
        u = jax.random.normal(jax.random.PRNGKey(7), (8, 96)) + 0.3
        _, _, info = wf.wfagg_batch(u, u, None, cfg, neighbor_idx=idx,
                                    valid=val)
        bits = obs.unpack_verdict(np.asarray(obs.record_from_info(info).verdict))
        for name in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(bits[name], np.asarray(info[name])), \
                (scenario, name)
        assert np.array_equal(bits["valid"], np.asarray(info["valid"]))
        assert np.array_equal(
            bits["accepted"],
            np.asarray((info["weights"] > 0) & info["valid"]))


# ---------------------------------------------------------------------------
# telemetry is an observer: bit-identical trajectories on/off
# ---------------------------------------------------------------------------

def _small():
    topo = make_topology(n_nodes=8, degree=4, n_malicious=2, kind="ring",
                         seed=0)
    data = SyntheticImages(seed=0)
    cfg = DFLConfig(aggregator="wfagg", attack="ipm_100", model="mlp",
                    seed=0)
    return cfg, topo, data


def test_trajectory_bit_identical_dynamic():
    cfg, topo, data = _small()
    sched = make_schedule("churn", topo, 3, seed=0)
    off = run_dynamic_experiment(cfg, topo, data, sched, n_test=64)
    on = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                telemetry=True)
    assert np.array_equal(np.asarray(off["series"]["acc_benign_mean"]),
                          np.asarray(on["series"]["acc_benign_mean"]))
    assert np.array_equal(np.asarray(off["final"]["acc_all"]),
                          np.asarray(on["final"]["acc_all"]))
    tel = on["telemetry"]
    R, N, K = 3, topo.n_nodes, sched.neighbor_idx.shape[-1]
    assert tel["verdict"].shape == (R, N, K)
    assert tel["verdict"].dtype == np.uint8
    for key in ("accepted", "mean_fallback", "degree_zero", "entropy"):
        assert tel[key].shape == (R, N), key
    # fallback counters ride the telemetry record in the dynamic engine
    assert len(on["series"]["mean_fallback_count"]) == R
    assert len(on["series"]["degree_zero_count"]) == R
    assert len(on["series"]["accepted_mean"]) == R


def test_trajectory_bit_identical_static():
    cfg, topo, data = _small()
    off = run_experiment(cfg, topo, data, rounds=3, eval_every=3)
    on = run_experiment(cfg, topo, data, rounds=3, eval_every=3,
                        telemetry=True)
    assert np.array_equal(np.asarray(off["final"]["acc_all"]),
                          np.asarray(on["final"]["acc_all"]))
    # static topo arrays are broadcast to (R, ...) so one report path
    # serves both engines
    tel = on["telemetry"]
    assert tel["verdict"].shape[0] == 3
    assert tel["neighbor_idx"].shape == tel["verdict"].shape
    assert tel["malicious"].shape == (3, topo.n_nodes)
    for out in (off, on):
        assert len(out["series"]["mean_fallback_count"]) == 3


def test_dos_scenario_surfaces_degree_zero():
    """The DoS window cuts the victim off entirely — the engine series
    must show degree-0 rounds (what satellite 2 exists for)."""
    cfg, topo, data = _small()
    sched = make_schedule("dos", topo, 4, seed=0)
    assert (np.asarray(sched.valid).sum(axis=-1) == 0).any(), \
        "fixture: dos schedule should DoS someone"
    out = run_dynamic_experiment(cfg, topo, data, sched, n_test=64,
                                 telemetry=True)
    assert sum(out["series"]["degree_zero_count"]) > 0


def test_centralized_telemetry_rejected():
    topo = make_topology(n_nodes=8, degree=4, n_malicious=2,
                         kind="complete", seed=0)
    cfg = DFLConfig(aggregator="mean", attack="none", model="mlp",
                    centralized=True)
    with pytest.raises(NotImplementedError):
        run_experiment(cfg, topo, SyntheticImages(seed=0), rounds=1,
                       telemetry=True)


# ---------------------------------------------------------------------------
# mode B: the all-reduce threads the same record
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_stacked_allreduce_record(backend):
    from repro.distributed.robust_allreduce import (
        RobustAggConfig, init_tree_agg_state, robust_allreduce_stacked)

    K = 6
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 24, 6)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (K, 80))}
    wcfg = wf.WFAggConfig(f=1, transient=1, window=2)
    cfg = RobustAggConfig(method="wfagg", wfagg=wcfg, layout="stacked",
                          backend=backend)
    state = init_tree_agg_state(cfg, K, jax.tree.map(lambda x: x[0], g))
    for r in range(3):
        gr = jax.tree.map(lambda x: x + 0.1 * r, g)
        _, state, info = robust_allreduce_stacked(gr, cfg, state)
        assert "record" in info, backend
        rec = info["record"]
        bits = obs.unpack_verdict(np.asarray(rec.verdict))
        assert bits["valid"].all()  # mode B has no padded slate
        for name in ("mask_d", "mask_c", "mask_t"):
            assert np.array_equal(bits[name].ravel(),
                                  np.asarray(info[name]).ravel()), (r, name)
        assert np.array_equal(bits["accepted"].ravel(),
                              np.asarray(info["weights"] > 0).ravel())


# ---------------------------------------------------------------------------
# export plane: audit rates, attribution, JSONL schema, Perfetto trace
# ---------------------------------------------------------------------------

def _synthetic_telemetry():
    """1 round, 2 receiving nodes, K=2, 4-node system, node 3 malicious.
    Filter D catches both attacker edges + 1/2 benign; C accepts all;
    T rejects everything (transient-style blanket abstention)."""
    t, f = True, False
    mask_d = jnp.asarray([[[f, f], [t, f]]])   # (R=1, N=2, K=2)
    mask_c = jnp.ones((1, 2, 2), bool)
    mask_t = jnp.zeros((1, 2, 2), bool)
    valid = jnp.ones((1, 2, 2), bool)
    accepted = mask_d & mask_c
    verdict = obs.pack_verdict(mask_d, mask_c, mask_t, valid, accepted)
    return {
        "verdict": np.asarray(verdict),
        "neighbor_idx": np.asarray([[[1, 3], [0, 3]]]),
        "valid": np.ones((1, 2, 2), bool),
        "malicious": np.asarray([[False, False, False, True]]),
        "accepted": np.asarray(accepted.sum(-1), np.int32),
        "mean_fallback": np.zeros((1, 2), bool),
        "degree_zero": np.zeros((1, 2), bool),
        "entropy": np.zeros((1, 2), np.float32),
    }


def test_filter_rates_exact():
    tel = _synthetic_telemetry()
    rates = obs_report.telemetry_rates(tel)
    np.testing.assert_array_equal(rates["n_attacker_edges"], [2.0])
    np.testing.assert_array_equal(rates["n_benign_edges"], [2.0])
    # D rejected both attacker edges and one of two benign edges
    assert rates["d"]["true_catch"][0] == 1.0
    assert rates["d"]["false_pos"][0] == 0.5
    # C rejected nothing; T rejected everything
    assert rates["c"]["true_catch"][0] == 0.0
    assert rates["c"]["false_pos"][0] == 0.0
    assert rates["t"]["true_catch"][0] == 1.0
    assert rates["t"]["false_pos"][0] == 1.0
    # final = the accepted bit (d & c here)
    assert rates["final"]["true_catch"][0] == 1.0
    assert rates["final"]["false_pos"][0] == 0.5


def test_attribution_margin_rule():
    tel = _synthetic_telemetry()
    attr = obs_report.attribution(obs_report.telemetry_rates(tel))
    # D: margin 0.5; C: 0; T: 0 (catches all by rejecting all) -> D carries
    assert attr["carried_by"] == "d"
    assert attr["d"]["margin"] == 0.5
    assert attr["t"]["margin"] == 0.0
    # blanket abstention alone must NOT claim credit
    v = obs.unpack_verdict(tel["verdict"])
    v["mask_d"][:] = True  # D now accepts everything too
    tel2 = dict(tel, verdict=np.asarray(obs.pack_verdict(
        jnp.asarray(v["mask_d"]), jnp.asarray(v["mask_c"]),
        jnp.asarray(v["mask_t"]), jnp.asarray(v["valid"]),
        jnp.asarray(v["accepted"]))))
    attr2 = obs_report.attribution(obs_report.telemetry_rates(tel2))
    assert attr2["carried_by"] is None


def test_rates_nan_without_attackers():
    tel = _synthetic_telemetry()
    tel["malicious"] = np.zeros((1, 4), bool)
    rates = obs_report.telemetry_rates(tel)
    assert np.isnan(rates["d"]["true_catch"][0])
    attr = obs_report.attribution(rates)
    assert attr["carried_by"] is None


def test_event_stream_schema_roundtrip(tmp_path):
    tel = _synthetic_telemetry()
    events = obs_report.events_from_telemetry(
        tel, dict(aggregator="wfagg", attack="unit", scenario="static",
                  backend="fused"))
    assert obs_recorder.validate_events(events, strict=True) == []
    path = str(tmp_path / "flight.jsonl")
    obs_recorder.write_events(events, path)
    back = obs_recorder.read_events(path)
    assert back == json.loads(json.dumps(events))  # jsonable + stable
    # stream-level checks actually fire
    assert obs_recorder.validate_events(events[1:])  # no run_meta first
    doctored = [dict(ev) for ev in events]
    doctored[1]["verdict"] = [[1]]  # wrong (N, K) shape
    assert any("verdict" in e for e in obs_recorder.validate_events(doctored))
    with pytest.raises(ValueError):
        obs_recorder.validate_events(doctored, strict=True)


def test_flight_recorder_streams_jsonl(tmp_path):
    path = str(tmp_path / "rec.jsonl")
    with obs_recorder.FlightRecorder(path) as rec:
        rec.emit("run_meta", n_nodes=2, width=2, rounds=1,
                 aggregator="wfagg", attack="none", scenario="static",
                 backend="fused")
        rec.emit("round_timing", round=1, wall_s=0.5, kind="compile")
        with pytest.raises(ValueError):
            rec.emit("round_timing", round=2, wall_s=0.5, kind="bogus")
    assert len(obs_recorder.read_events(path)) == 2


def test_perfetto_trace_structure(tmp_path):
    tel = _synthetic_telemetry()
    events = obs_report.events_from_telemetry(
        tel, dict(aggregator="wfagg", attack="unit", scenario="static",
                  backend="fused"))
    path = str(tmp_path / "trace.json")
    obs_trace.write_trace(events, path)
    with open(path) as f:
        trace = json.load(f)
    tes = trace["traceEvents"]
    assert tes and all(ev["ph"] in ("X", "C", "M") and "pid" in ev
                       for ev in tes)
    slices = [ev for ev in tes if ev["ph"] == "X"]
    assert len(slices) == 1  # one round
    assert all(ev["dur"] > 0 for ev in slices)
    ts = [ev["ts"] for ev in tes if ev["ph"] in ("X", "C")]
    assert ts == sorted(ts)


def test_render_audit_smoke():
    tel = _synthetic_telemetry()
    events = obs_report.events_from_telemetry(
        tel, dict(aggregator="wfagg", attack="unit", scenario="static",
                  backend="fused"))
    text = obs_report.render_audit(events)
    assert "true-catch" in text and "carried by" in text.lower()


# ---------------------------------------------------------------------------
# timing plane + microbench methodology (satellite 1)
# ---------------------------------------------------------------------------

def test_time_compile_steady():
    fn = jax.jit(lambda x: (x * 2.0).sum())
    x = jnp.ones((256,))
    res = obs_profile.time_compile_steady(fn, x, reps=3)
    assert res.compile_s > 0 and res.steady_s > 0
    assert len(res.steady_all_s) == 3
    assert res.steady_s == sorted(res.steady_all_s)[1]  # the median


def test_round_traffic_bytes_joins_memory_passes():
    wcfg = wf.WFAggConfig(backend="fused")
    N, K, d = 20, 8, 4096
    got = obs_profile.round_traffic_bytes(wcfg, N, K, d)
    passes = wf.memory_passes(wcfg, include_gather=True, indexed=True)
    assert got == passes * N * K * d * 4
    assert obs_profile.achieved_bytes_per_s(got, 2.0) == got / 2.0


def test_microbench_timeit_median():
    from benchmarks.agg_microbench import _timeit
    fn = jax.jit(lambda x: x + 1.0)
    comp_s, med_s = _timeit(fn, jnp.ones((64,)), reps=3)
    assert comp_s > 0 and med_s > 0
